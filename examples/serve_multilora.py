"""End-to-end serving driver (the paper's kind of workload): a MAF-style
skewed multi-tenant trace served by one CaraServe instance with batched
requests and real continuous-batching numerics, compared against the
on-demand baseline on the timeline plane.

  PYTHONPATH=src python examples/serve_multilora.py [--requests 20]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.traces import gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--adapters", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("llama2-7b").smoke()
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(args.adapters, cfg.name, rng,
                                 ranks=(2, 4, 8))
    reqs = gen.maf_trace(adapters, rps=50.0, duration_s=30.0,
                         vocab=cfg.vocab, seed=1, max_prompt=24, max_out=10
                         )[: args.requests]

    results = {}
    for mode in ("caraserve", "ondemand"):
        srv = InferenceServer(cfg, mode=mode, kernel="bgmv", max_batch=4,
                              cache_slots=64, numerics=True, seed=0)
        for ad in adapters:
            srv.register_adapter(ad)
        results[mode] = srv.run(reqs)
        print(f"\n== {mode} ==")
        for k in ("ttft_mean", "ttft_p99", "tpt_mean", "latency_mean",
                  "slo_attainment", "cold_starts", "assisted"):
            v = results[mode][k]
            print(f"  {k:16s} {v:.3f}" if isinstance(v, float)
                  else f"  {k:16s} {v}")

    speedup = results["ondemand"]["ttft_mean"] / \
        results["caraserve"]["ttft_mean"]
    print(f"\nCaraServe TTFT speedup over on-demand loading: {speedup:.2f}x "
          f"(paper sec 7.2 reports up to ~4.5x on TTFT at RPS 9)")


if __name__ == "__main__":
    main()
