"""Train a ~100M-param model for a few hundred steps, then LoRA-fine-tune an
adapter on top and serve it — the full substrate loop. Uses the
mamba2-130m-class dense sibling at reduced width by default; pass --full for
the real 130M config (slower on CPU).

  PYTHONPATH=src python examples/train_lora.py --steps 200
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.data.pipeline import DataConfig, packed_batches
from repro.models import model
from repro.models.param import split
from repro.serving.request import Request
from repro.training import checkpoint, optim, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lora-steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the real mamba2-130m config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m") if args.full \
        else get_config("mamba2-130m").smoke()
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")

    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    ocfg = optim.AdamWConfig(lr=3e-3, warmup_steps=args.steps // 10,
                             total_steps=args.steps, weight_decay=0.01)
    state = optim.init(params)
    step_fn = jax.jit(train.make_train_step(cfg, ocfg, accum=1))
    data = packed_batches(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                     batch=args.batch, seed=0))
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, m = step_fn(params, state, batch)
        if step % 25 == 0 or step == 1:
            print(f"  base step {step:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time() - t0) / step:.2f}s/step)")
    checkpoint.save(checkpoint.step_path(args.ckpt_dir, args.steps),
                    params, step=args.steps)
    print(f"base training done; checkpoint at {args.ckpt_dir}")

    # LoRA fine-tune on a "domain" data slice (different seed = new topics)
    adapter = train.init_lora_adapter(cfg, rank=4, rng=jax.random.PRNGKey(7))
    lcfg = optim.AdamWConfig(lr=1e-2, warmup_steps=5,
                             total_steps=args.lora_steps, weight_decay=0.0)
    lstate = optim.init(adapter)
    lstep = jax.jit(train.make_lora_train_step(cfg, lcfg, rank=4))
    domain = packed_batches(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                       batch=args.batch, seed=99))
    for step in range(1, args.lora_steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(domain).items()}
        adapter, lstate, m = lstep(adapter, lstate, params, batch)
        if step % 25 == 0 or step == 1:
            print(f"  lora step {step:4d} loss {float(m['loss']):.4f}")

    # serve the freshly trained adapter
    srv = InferenceServer(cfg, mode="caraserve", max_batch=2,
                          cache_slots=64, numerics=True, params=params)
    srv.register_adapter(AdapterSpec("tuned", rank=4, base_model=cfg.name))
    srv.store._weights["tuned"] = {
        t: {"a": np.asarray(adapter[t]["a"]),
            "b": np.asarray(adapter[t]["b"])} for t in adapter}
    prompt = np.arange(12, dtype=np.int32) % cfg.vocab
    srv.run([Request(rid=0, adapter_uid="tuned", prompt=prompt,
                     max_new_tokens=8, arrival_ms=0.0)])
    print("served tokens from the tuned adapter:",
          srv.states[0].generated)


if __name__ == "__main__":
    main()
