"""Cluster-scale scheduling demo (paper sec 7.5): 16 inference servers behind
the rank-aware scheduler vs baselines on a skewed MAF-style workload, under
a chosen adapter placement (full replication, hash sharding, rank-balanced
bin packing, or popularity-aware k-way replication with rebalance).

  PYTHONPATH=src python examples/cluster_sim.py [--servers 16] [--rps 80]
      [--placement full|hash|rank_balanced|popularity] [--rebalance-ms 500]
      [--link-policy fifo|priority|preempt]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.perf_model import ServerPerfModel
from repro.core.placement import make_placement_policy
from repro.core.scheduler import make_scheduler
from repro.traces import gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=16)
    ap.add_argument("--rps", type=float, default=80.0)
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--kernel", default="bgmv", choices=["bgmv", "mbgmv"])
    ap.add_argument("--placement", default="full",
                    choices=["full", "hash", "rank_balanced", "popularity"])
    ap.add_argument("--rebalance-ms", type=float, default=None,
                    help="popularity-EWMA rebalance period (off by default)")
    ap.add_argument("--link-policy", default="fifo",
                    choices=["fifo", "priority", "preempt"],
                    help="host-link scheduling policy for adapter uploads "
                         "(demand vs speculative prefetch)")
    args = ap.parse_args()

    cfg = get_config("llama2-7b")
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(256, cfg.name, rng)
    perf = ServerPerfModel(cfg, kernel=args.kernel)
    slo = 1.5 * perf.dec_perf([64] * 16)
    reqs = gen.maf_trace(adapters, rps=args.rps, duration_s=args.duration,
                         vocab=100, seed=1, slo_tpt_ms=slo)
    prior = gen.trace_popularity(reqs)
    print(f"{len(reqs)} requests over {args.duration}s, "
          f"{args.servers} servers, SLO={slo:.1f} ms/token "
          f"({args.kernel} backend, {args.placement} placement)\n")
    print(f"{'policy':12s} {'SLO':>7s} {'tpt(ms)':>9s} {'p99':>9s} "
          f"{'miss':>5s} {'repl':>5s}")
    for policy in ("rank_aware", "most_idle", "first_fit", "random"):
        placement = make_placement_policy(args.placement).assign(
            adapters, args.servers, popularity=prior)
        servers = [InferenceServer(cfg, mode="caraserve", kernel=args.kernel,
                                   max_batch=16, numerics=False,
                                   link_policy=args.link_policy)
                   for _ in range(args.servers)]
        sched = make_scheduler(policy, perf, slo_ms=slo) \
            if policy == "rank_aware" else make_scheduler(policy)
        cl = Cluster(servers, sched, placement=placement, specs=adapters,
                     rebalance_every_ms=args.rebalance_ms)
        out, _ = cl.run(reqs)
        print(f"{policy:12s} {out['slo_attainment']:7.3f} "
              f"{out['tpt_mean']:9.2f} {out['tpt_p99']:9.2f} "
              f"{cl.placement_stats['miss_installs']:5d} "
              f"{cl.placement.total_replicas():5d}")


if __name__ == "__main__":
    main()
