"""Quickstart: spin up a CaraServe inference server on a reduced Llama-2
config (CPU-runnable), register heterogeneous LoRA adapters, and serve a few
requests with real numerics.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.serving.request import Request


def main():
    cfg = get_config("llama2-7b").smoke()
    server = InferenceServer(cfg, mode="caraserve", kernel="bgmv",
                             max_batch=4, cache_slots=64, numerics=True)

    # three tenants with different LoRA ranks (heterogeneous batch)
    for uid, rank in (("assistant", 8), ("summarizer", 4), ("coder", 2)):
        server.register_adapter(AdapterSpec(uid, rank=rank,
                                            base_model=cfg.name))

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, adapter_uid=uid,
                prompt=rng.integers(0, cfg.vocab, 8 + i).astype(np.int32),
                max_new_tokens=8, arrival_ms=float(5 * i))
        for i, uid in enumerate(["assistant", "summarizer", "coder",
                                 "assistant"])
    ]
    metrics = server.run(reqs)

    print("\nper-request generations:")
    for st in server.states:
        print(f"  req {st.req.rid} [{st.req.adapter_uid:10s}] "
              f"cold={st.cold_start} assisted={st.assist_used} "
              f"ttft={st.ttft_ms():.2f}ms tokens={st.generated}")
    print("\nsummary:", {k: round(v, 3) if isinstance(v, float) else v
                         for k, v in metrics.items()})


if __name__ == "__main__":
    main()
