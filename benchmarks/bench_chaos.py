"""Chaos bench: the failure-recovery plane under a scripted fault schedule.

One MAF trace drives two timing-plane cluster arms — fault-free and chaos
(a mid-run server crash + restart, fleet-wide flaky-upload windows, one
browned-out link; core/faults.chaos_schedule) — plus a small numerics arm
that crashes a server mid-decode and checks the recovered requests decode
token-for-token identically to the unfailed run (crash failover rides the
PR-6 drop-and-recompute path, so recovery is a replay, not an
approximation).

Acceptance (asserted, then gated in CI via tools/bench_check.py):
  * zero lost requests — every submitted rid either completes or is
    explicitly shed (`n + shed == submitted`);
  * the crash actually drained work and survivors adopted it
    (failovers > 0) and flaky uploads actually retried (retries > 0);
  * the CPU-assist fault shield engaged — decode rows whose adapter
    upload was mid-retry kept emitting tokens on the host path
    (assist_shield_tokens > 0);
  * SLO attainment under chaos dips by at most MAX_SLO_DIP vs fault-free
    (graceful degradation, not collapse);
  * recovered requests' tokens match the fault-free run exactly.
"""
import argparse
import sys

from benchmarks.common import (cluster_fault_stats, emit, write_bench_json)
from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.faults import FaultEvent, FaultPlane, chaos_schedule
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.traces.gen import maf_trace, make_adapters

import numpy as np

N_SERVERS = 4
# chaos may cost at most this much absolute SLO attainment vs fault-free
MAX_SLO_DIP = 0.12


def build_cluster(cfg, adapters, perf, slo, faults=None, shed="none"):
    servers = []
    for _ in range(N_SERVERS):
        s = InferenceServer(cfg, mode="caraserve", kernel="bgmv",
                            max_batch=8, numerics=False,
                            link_policy="priority")
        for ad in adapters:
            s.register_adapter(ad)
        servers.append(s)
    sched = make_scheduler("rank_aware", perf, slo_ms=slo)
    return Cluster(servers, sched, faults=faults, shed_policy=shed)


def run_timing_arms(smoke):
    cfg = get_config("llama2-7b")
    rng = np.random.default_rng(0)
    adapters = make_adapters(16, cfg.name, rng)
    perf = ServerPerfModel(cfg, kernel="bgmv")
    slo = 1.5 * perf.dec_perf([64] * 8)
    dur = 4.0 if smoke else 8.0
    reqs = maf_trace(adapters, rps=30, duration_s=dur, vocab=100, seed=1,
                     slo_tpt_ms=slo)
    span = reqs[-1].arrival_ms

    free_cl = build_cluster(cfg, adapters, perf, slo)
    free_out, _ = free_cl.run(reqs)

    faults = FaultPlane(chaos_schedule(N_SERVERS, span, seed=7,
                                       downtime_ms=span * 0.2), seed=7)
    chaos_cl = build_cluster(cfg, adapters, perf, slo,
                             faults=faults, shed="slo")
    chaos_out, chaos_states = chaos_cl.run(reqs)
    cf = cluster_fault_stats(chaos_cl)

    # --- acceptance: zero lost ------------------------------------------
    assert chaos_out["n"] + chaos_out["shed"] == len(reqs), \
        (chaos_out["n"], chaos_out["shed"], len(reqs))
    assert sorted(s.req.rid for s in chaos_states) \
        == sorted(r.rid for r in reqs)
    for s in chaos_states:
        if not s.shed:
            assert len(s.generated) == s.req.max_new_tokens, \
                (s.req.rid, s.phase)
    # --- the faults actually bit, and every recovery path engaged -------
    assert cf["cluster_crashes"] >= 1 and cf["cluster_restarts"] >= 1, cf
    assert cf["cluster_failovers"] > 0, cf
    assert chaos_out["failovers"] == cf["cluster_failovers"]
    assert cf["upload_failures"] > 0 and cf["retries"] > 0, cf
    assert cf["assist_shield_tokens"] > 0, cf   # CPU-assist fault shield
    # --- graceful degradation, not collapse -----------------------------
    dip = free_out["slo_attainment"] - chaos_out["slo_attainment"]
    assert dip <= MAX_SLO_DIP, (free_out["slo_attainment"],
                                chaos_out["slo_attainment"])

    for label, out in (("faultfree", free_out), ("chaos", chaos_out)):
        emit(f"chaos/{label}", out["latency_p99"] * 1e3,
             f"slo={out['slo_attainment']:.3f};n={out['n']};"
             f"shed={out['shed']};failovers={out['failovers']}")
    return {
        "n_requests": len(reqs),
        "faultfree": free_out,
        "chaos": chaos_out,
        "fault_stats": cf,
        "fault_log_len": len(faults.log),
        "slo_dip": dip,
    }


def run_parity_arm():
    """Crash a numerics server mid-decode: every recovered request must
    finish with exactly the tokens the unfailed run produced (recompute
    failover replays prompt + generated-so-far, then greedy decode takes
    the same path on the identically-seeded adopting server)."""
    cfg = get_config("llama2-7b").smoke()
    rng = np.random.default_rng(5)
    adapters = make_adapters(4, cfg.name, rng, uniform_rank=8)

    def build(faults=None):
        servers = []
        for _ in range(2):
            s = InferenceServer(cfg, mode="cached", max_batch=4,
                                numerics=True, seed=0, pipeline="fused")
            for ad in adapters:
                s.register_adapter(ad)
            servers.append(s)
        return Cluster(servers, make_scheduler("most_idle"),
                       faults=faults, engine="events")

    from repro.serving.request import Request
    reqs = []
    for i in range(6):
        prompt = rng.integers(0, cfg.vocab, 12 + 2 * i).astype(np.int32)
        reqs.append(Request(rid=i, adapter_uid=adapters[i % 4].uid,
                            prompt=prompt, max_new_tokens=12,
                            arrival_ms=5.0 * i))
    _, free_states = build().run(reqs)
    want = {s.req.rid: list(s.generated) for s in free_states}

    # crash server 1 while it is mid-decode; restart it shortly after
    faults = FaultPlane([FaultEvent(20.0, "crash", 1),
                         FaultEvent(60.0, "restart", 1)], seed=3)
    cl = build(faults)
    out, states = cl.run(reqs)
    got = {s.req.rid: list(s.generated) for s in states}
    assert out["n"] == len(reqs)
    assert out["recovered"] > 0, "crash drained no live requests"
    assert got == want, "recovered requests diverged from fault-free run"
    return {"n_requests": len(reqs), "recovered": out["recovered"],
            "failovers": out["failovers"]}


def run(smoke=False):
    doc = {"smoke": smoke}
    doc["timing"] = run_timing_arms(smoke)
    doc["parity"] = run_parity_arm()
    # surface the gated scalars at the top level for bench_check paths
    doc["slo_attainment_chaos"] = doc["timing"]["chaos"]["slo_attainment"]
    doc["slo_dip"] = doc["timing"]["slo_dip"]
    doc["failovers"] = doc["timing"]["fault_stats"]["cluster_failovers"]
    doc["assist_shield_tokens"] = \
        doc["timing"]["fault_stats"]["assist_shield_tokens"]
    write_bench_json("chaos", doc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
