"""Paper Fig 18: single-CPU LoRA prefill ceiling and profiling-guided
multi-core parallelization (analytic host model + one measured host GEMM)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us
from repro.configs.base import get_config
from repro.core.timing import TimingModel


def run():
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    # Fig 18-left: SINGLE-core compute time grows with prompt length...
    unit = tm._lora_bytes_per_token_rank()
    for tokens in (16, 32, 64, 128, 256):
        t1 = tokens * 64 * unit / tm.hw.cpu_core_flops * 1e3
        emit(f"host_parallel/single_core_{tokens}tok", t1 * 1e3, "1 core")
    # ...while profiling-guided parallelization keeps latency flat (Fig 18-
    # right): ceil(tokens/16) cores, each within its profiled ceiling
    for tokens in (16, 64, 256):
        cores = tm.cpu_cores_for(tokens)
        ms = tm.cpu_lora_prefill_ms(tokens, 64)
        emit(f"host_parallel/parallel_{tokens}tok", ms * 1e3,
             f"cores={cores};flat-by-design")
    # Fig 18-right: 128-token prefill, parallelization speedup vs 1 core
    one_core = tm.hw.cpu_max_tokens_per_core
    t1 = 128 * 64 * tm._lora_bytes_per_token_rank() / tm.hw.cpu_core_flops
    t8 = tm.cpu_lora_prefill_ms(128, 64) / 1e3
    emit("host_parallel/speedup_128tok", t8 * 1e6,
         f"single_core={t1 * 1e6:.0f}us;speedup={t1 / t8:.2f}x")
    # measured host GEMM slice (16 tokens x A matrix), real wall-clock
    x = jnp.ones((16, 4096))
    a = jnp.ones((4096, 64))
    f = jax.jit(lambda: (x @ a))
    t = time_us(lambda: jax.block_until_ready(f()), iters=50)
    emit("host_parallel/measured_16tok_gemm", t, "per-layer xA slice")


if __name__ == "__main__":
    run()
