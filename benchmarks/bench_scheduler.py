"""Paper Figs 19/20: scheduler SLO attainment + time-per-token at cluster
scale. Fig 19: 60-instance simulation with MBGMV and BGMV backends; Fig 20:
8-instance "testbed" (CACHED backend, as in the paper)."""
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.traces import gen

POLICIES = ("rank_aware", "most_idle", "first_fit", "random")


def sim(cfg, kernel, n_servers, rps, duration, n_adapters, mode, tag,
        seed=0, max_batch=16, slo_ranks=64, slo_scale=1.5):
    rng = np.random.default_rng(seed)
    adapters = gen.make_adapters(n_adapters, cfg.name, rng)
    perf = ServerPerfModel(cfg, kernel=kernel)
    slo = slo_scale * perf.dec_perf([slo_ranks] * max_batch)
    reqs = gen.maf_trace(adapters, rps=rps, duration_s=duration, vocab=100,
                         seed=seed + 1, slo_tpt_ms=slo)
    for policy in POLICIES:
        servers = []
        for _ in range(n_servers):
            s = InferenceServer(cfg, mode=mode, kernel=kernel,
                                max_batch=max_batch, numerics=False)
            for ad in adapters:
                s.register_adapter(ad)
            servers.append(s)
        sched = make_scheduler(policy, perf, slo_ms=slo) \
            if policy == "rank_aware" else make_scheduler(policy)
        out, _ = Cluster(servers, sched).run(reqs)
        emit(f"scheduler/{tag}_{policy}", out["tpt_mean"] * 1e3,
             f"slo={out['slo_attainment']:.3f};"
             f"tpt_p99={out['tpt_p99']:.1f}ms;n={out['n']}")


def run():
    cfg = get_config("llama2-7b")
    # Fig 19: 60 instances at the paper's aggregate load (RPS ~ 340)
    sim(cfg, "mbgmv", n_servers=60, rps=340, duration=8, n_adapters=512,
        mode="caraserve", tag="fig19_mbgmv_60inst")
    sim(cfg, "bgmv", n_servers=60, rps=340, duration=8, n_adapters=512,
        mode="caraserve", tag="fig19_bgmv_60inst")
    # contended regime (~95% decode capacity): where rank-awareness shows
    sim(cfg, "bgmv", n_servers=60, rps=500, duration=8, n_adapters=512,
        mode="caraserve", tag="fig19_bgmv_contended", slo_ranks=32,
        slo_scale=1.3)
    # Fig 20: 8-instance testbed, CACHED backend
    sim(cfg, "bgmv", n_servers=8, rps=60, duration=15, n_adapters=128,
        mode="cached", tag="fig20_testbed_8inst")


if __name__ == "__main__":
    run()
