# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback

from benchmarks import (bench_ablation, bench_cold_start, bench_e2e,
                        bench_host_parallel, bench_invocation, bench_kernels,
                        bench_perf_model, bench_placement, bench_roofline,
                        bench_scheduler)

ALL = {
    "cold_start": bench_cold_start.run,     # paper Fig 3
    "ablation": bench_ablation.run,         # paper sec 4.2 "57.9%"
    "kernels": bench_kernels.run,           # paper Fig 4
    "perf_model": bench_perf_model.run,     # paper Fig 9
    "e2e": bench_e2e.run,                   # paper Figs 10/13/14/15
    "invocation": bench_invocation.run,     # paper Figs 8/16/17
    "host_parallel": bench_host_parallel.run,  # paper Fig 18
    "scheduler": bench_scheduler.run,       # paper Figs 19/20
    "placement": bench_placement.run,       # sharded adapter placement
    "roofline": bench_roofline.run,         # EXPERIMENTS.md sec Roofline
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for n in names:
        try:
            ALL[n]()
        except Exception:
            failed.append(n)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
