"""Chunked prefill vs monolithic prefill under prefill/decode interference.

A bimodal short/long MAF trace (traces/gen.bimodal_prompt_trace) drives one
timing-plane server per arm: monolithic prefill (chunk_budget=0) against
chunk budgets 64/128/256. All arms consume the *same* trace, so total token
work is identical; the acceptance gate is the paper-motivating claim that
chunking strictly beats monolithic prefill on P99 inter-token latency (the
resident decode batch no longer stalls behind a whole long prompt) while
giving up almost nothing on simulated tokens/s (>= EQUAL_TPS_FRAC, i.e.
equal throughput up to per-chunk step overhead).

Throughput here is *simulated* tokens/s — decode tokens over virtual-clock
makespan — so the numbers are deterministic and CI can gate on them
(tools/bench_check.py against benchmarks/baselines/bench_chunked.json).
"""
import argparse
import sys

from benchmarks.common import emit, itl_stats, write_bench_json
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.traces.gen import bimodal_prompt_trace, make_adapters

import numpy as np

CHUNKS = (0, 64, 128, 256)      # 0 = monolithic baseline
# chunking must cost < 5% simulated tokens/s vs monolithic at equal work
EQUAL_TPS_FRAC = 0.95


def run_arm(cfg, reqs, adapters, chunk_budget, max_batch, avg_ctx):
    srv = InferenceServer(cfg, mode="cached", numerics=False,
                          max_batch=max_batch, avg_ctx=avg_ctx,
                          pool_slots=len(adapters),
                          chunk_budget=chunk_budget)
    for ad in adapters:
        srv.register_adapter(ad)
    out = srv.run(reqs)
    assert out["n"] == len(reqs), (chunk_budget, out["n"], len(reqs))
    dec_tokens = sum(len(st.generated) for st in srv.states)
    itl = itl_stats(srv)
    return {
        "chunk_budget": chunk_budget,
        "sim_tps": dec_tokens * 1e3 / srv.clock,
        "makespan_ms": float(srv.clock),
        "dec_tokens": dec_tokens,
        "ttft_p50_ms": out["ttft_p50"],
        "ttft_p99_ms": out["ttft_p99"],
        "latency_p99_ms": out["latency_p99"],
        "itl": itl,
    }


def run(smoke: bool = False):
    cfg = get_config("llama2-7b")
    rng = np.random.default_rng(0)
    adapters = make_adapters(8, cfg.name, rng, uniform_rank=16)
    max_batch, avg_ctx = 16, 512
    if smoke:
        chunks, rps, dur = (0, 128), 24.0, 4.0
    else:
        chunks, rps, dur = CHUNKS, 24.0, 12.0
    reqs = bimodal_prompt_trace(adapters, rps, dur, cfg.vocab, seed=7,
                                long_frac=0.2, short_prompt=64,
                                long_prompt=512, max_prompt=2048,
                                max_out=96)
    n_long = sum(r.prompt_len >= 512 for r in reqs)
    doc = {"smoke": smoke, "n_requests": len(reqs), "n_long": n_long,
           "rps": rps, "duration_s": dur, "max_batch": max_batch,
           "arms": {}}
    arms = {}
    for cb in chunks:
        r = run_arm(cfg, reqs, adapters, cb, max_batch, avg_ctx)
        arms[cb] = r
        name = "monolithic" if cb == 0 else f"chunk{cb}"
        doc["arms"][name] = r
        emit(f"chunked/{name}", r["itl"]["itl_p99_ms"] * 1e3,
             f"itl_p99={r['itl']['itl_p99_ms']:.2f}ms;"
             f"itl_p50={r['itl']['itl_p50_ms']:.2f}ms;"
             f"tps={r['sim_tps']:.1f};ttft_p99={r['ttft_p99_ms']:.1f}ms")

    # --- acceptance ------------------------------------------------------
    mono = arms[0]
    assert n_long > 0, "trace generated no long prompts"
    for cb, r in arms.items():
        if cb == 0:
            continue
        # the tentpole claim: chunked prefill strictly beats monolithic on
        # P99 inter-token latency (decode no longer stalls behind a whole
        # long prompt)...
        assert r["itl"]["itl_p99_ms"] < mono["itl"]["itl_p99_ms"], \
            (cb, r["itl"], mono["itl"])
        # ...at (near-)equal total tokens/s: same trace, same token work,
        # makespan within the per-chunk overhead budget
        assert r["sim_tps"] >= EQUAL_TPS_FRAC * mono["sim_tps"], \
            (cb, r["sim_tps"], mono["sim_tps"])
    doc["itl_p99_improvement"] = {
        f"chunk{cb}": mono["itl"]["itl_p99_ms"] / r["itl"]["itl_p99_ms"]
        for cb, r in arms.items() if cb != 0}
    write_bench_json("chunked", doc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two arms, short trace (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
