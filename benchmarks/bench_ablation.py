"""Paper sec 4.2 ablation ("putting it altogether ... reduce the prefill
latency by 57.9%"): contribution of each CPU-assist mechanism to the
cold-start prefill path, by disabling them one at a time in the timing model.

  full      = overlap + multi-core + shared-memory + sync-free
  -parallel = single host core (no profiling-guided parallelization, Fig 18)
  -shm      = socket-style IPC per prefill (+~0.3 ms/layer, Fig 17)
  -syncfree = blocking per-layer sync (+~0.4 ms/layer, Fig 8/16)
  none      = ONDMD (serial load + device prefill)
"""
import dataclasses

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.cold_start import ColdStartManager
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import Hardware, TimingModel


def plan_for(hw, mode, rank=64, tokens=128):
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg, hw)
    store = HostLoRAStore(cfg)
    store.register(AdapterSpec("u", rank=rank, base_model=cfg.name),
                   materialize=False)
    pool = DevicePool(cfg, materialize=False)
    return ColdStartManager(tm, store, pool, mode).admit("u", 0.0, tokens)


def run():
    base = Hardware()
    variants = {
        "full": base,
        "minus_parallel": dataclasses.replace(
            base, cpu_max_tokens_per_core=10 ** 9),     # 1 core
        "minus_shm": dataclasses.replace(
            base, invoke_overhead_ms=base.invoke_overhead_ms + 0.3 * 32),
        "minus_syncfree": dataclasses.replace(
            base, sync_per_layer_ms=base.sync_per_layer_ms + 0.4),
    }
    ond = plan_for(base, "ondemand").prefill_ms
    emit("ablation/ondemand_prefill", ond * 1e3, "serial load+prefill")
    for name, hw in variants.items():
        pre = plan_for(hw, "caraserve").prefill_ms
        emit(f"ablation/{name}", pre * 1e3,
             f"vs_ondemand=-{(1 - pre / ond) * 100:.1f}%")


if __name__ == "__main__":
    run()
