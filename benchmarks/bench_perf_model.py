"""Paper Fig 9: linear performance-model fits for BGMV/MBGMV with R^2."""
from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.perf_model import profile_and_fit


def run():
    for arch in ("llama2-7b", "llama2-13b"):
        cfg = get_config(arch)
        for kernel in ("bgmv", "mbgmv"):
            m, (xs, ys) = profile_and_fit(cfg, kernel, noise=0.02, seed=0)
            emit(f"perf_model/{arch}_{kernel}", m.alpha * 1e3,
                 f"r2={m.r2:.3f};beta_ms={m.beta:.3f};n={len(xs)}")


if __name__ == "__main__":
    run()
