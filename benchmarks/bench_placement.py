"""Adapter placement at cluster scale: full replication (the seed's
memory-unconstrained oracle) vs hash sharding vs popularity-aware k-way
replication under the skewed MAF trace (paper Fig 12 shape).

Reports p50/p99 first-token latency and SLO attainment per policy and
checks the placement plane's two load-bearing properties:

* popularity-aware replication beats popularity-blind hash placement on
  SLO attainment under skew (hot adapters' traffic can be spread);
* the register-on-miss path fires (hash concentrates a hot adapter on one
  server; once every replica is SLO-saturated the cluster installs a new
  replica on the fly) and the event loop still drains every request.

``--smoke`` runs a tiny trace with all four schedulers x two placements —
the CI cluster-smoke job (minutes, not the full tier-1 run).
"""
import argparse

import numpy as np

from benchmarks.common import (cluster_itl_stats, cluster_oversub_stats,
                               emit, write_bench_json)
from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.perf_model import ServerPerfModel
from repro.core.placement import make_placement_policy
from repro.core.scheduler import make_scheduler
from repro.traces import gen

PLACEMENTS = ("full", "hash", "popularity")
SCHEDULERS = ("rank_aware", "most_idle", "first_fit", "random")


def _servers(cfg, n, kernel, max_batch, mode="caraserve"):
    # built bare: the Cluster registers each server's shard per placement
    return [InferenceServer(cfg, mode=mode, kernel=kernel,
                            max_batch=max_batch, numerics=False)
            for _ in range(n)]


def _policy(name, n_servers):
    if name == "full":
        return make_placement_policy("full")
    if name == "hash":
        return make_placement_policy("hash", replication=1)
    return make_placement_policy("popularity", spread=2.0,
                                 max_replicas=max(2, n_servers // 2))


def run_one(cfg, perf, adapters, reqs, placement_name, scheduler_name,
            n_servers, kernel, max_batch, slo, rebalance_every_ms=None):
    prior = gen.trace_popularity(reqs)
    pl = _policy(placement_name, n_servers).assign(adapters, n_servers,
                                                   popularity=prior)
    servers = _servers(cfg, n_servers, kernel, max_batch)
    sched = make_scheduler(scheduler_name, perf, slo_ms=slo) \
        if scheduler_name == "rank_aware" else make_scheduler(scheduler_name)
    cl = Cluster(servers, sched, placement=pl, specs=adapters,
                 rebalance_every_ms=rebalance_every_ms)
    out, _ = cl.run(reqs)
    assert out["n"] == len(reqs), \
        (placement_name, scheduler_name, out["n"], len(reqs))
    return out, cl


def run(smoke: bool = False):
    cfg = get_config("llama2-7b")
    kernel = "bgmv"
    perf = ServerPerfModel(cfg, kernel=kernel)
    if smoke:
        n_servers, n_adapters, max_batch = 4, 16, 8
        rps, duration = 40, 2
    else:
        n_servers, n_adapters, max_batch = 8, 64, 16
        rps, duration = 80, 8
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(n_adapters, cfg.name, rng)
    slo = 1.4 * perf.dec_perf([48] * max_batch)
    reqs = gen.maf_trace(adapters, rps=rps, duration_s=duration, vocab=100,
                         seed=1, slo_tpt_ms=slo)

    if smoke:        # all schedulers x two placements: routing smoke only
        for pl_name in ("full", "hash"):
            for sc_name in SCHEDULERS:
                out, cl = run_one(cfg, perf, adapters, reqs, pl_name,
                                  sc_name, n_servers, kernel, max_batch,
                                  slo)
                emit(f"placement/smoke_{pl_name}_{sc_name}",
                     out["ttft_p50"] * 1e3,
                     f"slo={out['slo_attainment']:.3f};n={out['n']};"
                     f"miss={cl.placement_stats['miss_installs']}")
        # register-on-miss smoke: take down the hottest adapter's only
        # replica — the cluster must reroute with on-the-fly installs
        prior = gen.trace_popularity(reqs)
        pl = _policy("hash", n_servers).assign(adapters, n_servers,
                                               popularity=prior)
        cl = Cluster(_servers(cfg, n_servers, kernel, max_batch),
                     make_scheduler("most_idle"), placement=pl,
                     specs=adapters)
        for i in pl.hosts(max(prior, key=prior.get)):
            cl.set_down(i)
        out, _ = cl.run(reqs)
        assert out["n"] == len(reqs)
        assert cl.placement_stats["miss_installs"] > 0, \
            "register-on-miss path never fired in smoke"
        emit("placement/smoke_miss_path", out["ttft_p50"] * 1e3,
             f"miss={cl.placement_stats['miss_installs']};n={out['n']}")
        write_bench_json("placement", {
            "smoke": True, "n_servers": n_servers,
            "miss_installs": cl.placement_stats["miss_installs"],
            "ttft_p50_ms": out["ttft_p50"],
            "slo_attainment": out["slo_attainment"],
            "preempt": cluster_oversub_stats(cl),
            "itl": cluster_itl_stats(cl)})
        return

    res = {}
    for pl_name in PLACEMENTS:
        # full replication is the static memory-unconstrained oracle — no
        # rebalance (it would only trim replicas the baseline is defined by)
        every = None if pl_name == "full" else 500.0
        out, cl = run_one(cfg, perf, adapters, reqs, pl_name, "rank_aware",
                          n_servers, kernel, max_batch, slo,
                          rebalance_every_ms=every)
        res[pl_name] = (out, cl)
        emit(f"placement/maf_{pl_name}", out["ttft_p50"] * 1e3,
             f"slo={out['slo_attainment']:.3f};"
             f"ttft_p50={out['ttft_p50']:.1f}ms;"
             f"ttft_p99={out['ttft_p99']:.1f}ms;"
             f"miss={cl.placement_stats['miss_installs']};"
             f"adds={cl.placement_stats['replica_adds']};"
             f"drops={cl.placement_stats['replica_drops']};"
             f"replicas={cl.placement.total_replicas()};n={out['n']}")

    # acceptance: replicating the hot adapters must pay off under skew, and
    # sharded placements must exercise register-on-miss without deadlock
    slo_hash = res["hash"][0]["slo_attainment"]
    slo_pop = res["popularity"][0]["slo_attainment"]
    misses = sum(cl.placement_stats["miss_installs"]
                 for _, cl in (res["hash"], res["popularity"]))
    assert slo_pop >= slo_hash, (slo_pop, slo_hash)
    assert misses > 0, "register-on-miss path never fired"
    write_bench_json("placement", {
        "smoke": False, "n_servers": n_servers,
        "arms": {name: {
            "ttft_p50_ms": out["ttft_p50"], "ttft_p99_ms": out["ttft_p99"],
            "slo_attainment": out["slo_attainment"],
            "latency_p50_ms": out["latency_p50"],
            "miss_installs": cl.placement_stats["miss_installs"],
            "replica_adds": cl.placement_stats["replica_adds"],
            "replica_drops": cl.placement_stats["replica_drops"],
            "preempt": cluster_oversub_stats(cl),
            "itl": cluster_itl_stats(cl)}
            for name, (out, cl) in res.items()}})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, all schedulers x two placements")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
