"""Device-resident decode pipeline: per-step vs fused vs megastep decode
(ROADMAP item "Async/pipelined numerics").

Three arms over the same decode-heavy trace (all requests arrive at once,
short prompts, long outputs, cached adapters — the decode loop dominates):

* **perstep** — the pre-pipeline baseline: host-built token/position
  arrays uploaded every iteration, sampling off the full logits tensor,
  synchronous readback (`pipeline="perstep"`).
* **fused**   — on-device sampling, device-resident last-token/position
  buffers, async readback; zero host→device transfers per steady-state
  iteration (`pipeline="fused"`, megastep disabled).
* **megastep** — fused + K iterations per jit call via `lax.scan` when
  the engine's event horizon allows (`megastep=8`).

Each arm reports decode tokens/s (wall clock over a timed run after a
same-shape warmup run has paid all compilation) and the host-link
crossing counts from `NumericsBackend.transfer_stats`.

Acceptance (asserted below, both full and --smoke):

* the fused/megastep h2d count does not scale with decode steps, while
  perstep pays >= 3 uploads per iteration (and one blocking readback);
* the best device-resident arm (fused or megastep) beats perstep on
  decode tokens/s.

``--smoke`` runs one batch size on the bgmv kernel — the CI
cluster-smoke job.
"""
import argparse
import time

import numpy as np

from benchmarks.common import (emit, itl_stats, oversub_stats,
                               write_bench_json)
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.traces import gen  # noqa: F401  (import parity with peers)
from repro.serving.request import Request

ARMS = (("perstep", "perstep", 0), ("fused", "fused", 0),
        ("megastep", "fused", 8))


def make_reqs(n, vocab, max_new, t0, rng, rid0=0):
    return [Request(rid=rid0 + i, adapter_uid=f"ad{i % 4}",
                    prompt=rng.integers(0, vocab, 6).astype(np.int32),
                    max_new_tokens=max_new, arrival_ms=t0)
            for i in range(n)]


def run_arm(cfg, kernel, batch, max_new, pipeline, megastep):
    srv = InferenceServer(cfg, mode="cached", kernel=kernel,
                          max_batch=batch, cache_slots=64, numerics=True,
                          seed=0, pipeline=pipeline, megastep=megastep)
    for i in range(4):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
    rng = np.random.default_rng(0)
    # warmup run with the same shapes pays every jit compilation
    srv.run(make_reqs(batch, cfg.vocab, max_new, 0.0, rng))
    n_warm = len(srv.states)
    pre = dict(srv.backend.transfer_stats)
    t0 = time.perf_counter()
    srv.run(make_reqs(batch, cfg.vocab, max_new, srv.clock + 1.0, rng,
                      rid0=100))
    wall_s = time.perf_counter() - t0
    states = srv.states[n_warm:]
    assert all(len(st.generated) == max_new for st in states)
    dec_tokens = sum(len(st.generated) - 1 for st in states)
    stats = {k: srv.backend.transfer_stats[k] - pre[k] for k in pre}
    return {"tps": dec_tokens / wall_s, "wall_s": wall_s,
            "dec_tokens": dec_tokens, "preempt": oversub_stats(srv),
            "itl": itl_stats(srv), **stats}


def run(smoke: bool = False):
    cfg = get_config("llama2-7b").smoke()
    if smoke:
        kernels, batches, max_new = ("bgmv",), (4,), 24
    else:
        kernels, batches, max_new = ("bgmv", "mbgmv"), (2, 8), 48

    doc = {"smoke": smoke, "max_new": max_new, "arms": {}}
    for kernel in kernels:
        for batch in batches:
            res = {}
            for name, pipeline, mega in ARMS:
                r = run_arm(cfg, kernel, batch, max_new, pipeline, mega)
                res[name] = r
                doc["arms"][f"{kernel}_b{batch}_{name}"] = {
                    k: r[k] for k in ("tps", "wall_s", "dec_tokens",
                                      "decode_steps", "megasteps", "h2d",
                                      "h2d_bytes", "d2h", "d2h_bytes",
                                      "preempt", "itl")}
                emit(f"pipeline/{kernel}_b{batch}_{name}", r["tps"],
                     f"tok_s={r['tps']:.1f};steps={r['decode_steps']};"
                     f"megasteps={r['megasteps']};h2d={r['h2d']};"
                     f"d2h={r['d2h']};h2d_bytes={r['h2d_bytes']};"
                     f"n_tok={r['dec_tokens']}")

            # --- acceptance ------------------------------------------------
            per, fus, meg = res["perstep"], res["fused"], res["megastep"]
            # perstep pays >= 3 uploads + 1 readback per decode iteration
            assert per["h2d"] >= 3 * per["decode_steps"], per
            assert per["d2h"] >= per["decode_steps"], per
            # device-resident paths: uploads are event-bound, not step-bound
            for r in (fus, meg):
                assert r["decode_steps"] >= max_new - 1, r
                assert r["h2d"] < per["h2d"] / 3, (r, per)
                assert r["h2d"] <= 4 + 2 * batch + 8, r   # events only
            # megastep actually fused iterations
            assert meg["megasteps"] > 0 and meg["megastep_iters"] >= 2
            # the pipeline beats the per-step baseline on decode tokens/s
            best = max(fus["tps"], meg["tps"])
            assert best > per["tps"], \
                (kernel, batch, best, per["tps"])
    write_bench_json("pipeline", doc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep for the CI cluster-smoke job")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
