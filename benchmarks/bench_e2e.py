"""Paper Figs 10/13/14: end-to-end serving on one instance — CACHED / ONDMD /
S-LoRA / CARASERVE over synthetic Poisson and MAF-scaled workloads; TTFT,
time-per-token, request latency (mean + p50/p99)."""
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.traces import gen

BASELINES = [("cached", "bgmv"), ("ondemand", "bgmv"), ("slora", "mbgmv"),
             ("caraserve", "bgmv")]


def one(cfg, mode, kernel, reqs, adapters, tag):
    srv = InferenceServer(cfg, mode=mode, kernel=kernel, max_batch=16,
                          numerics=False)
    for ad in adapters:
        srv.register_adapter(ad)
    out = srv.run(reqs)
    emit(f"e2e/{tag}_{mode}_ttft", out["ttft_mean"] * 1e3,
         f"p50={out['ttft_p50']:.1f}ms;p99={out['ttft_p99']:.1f}ms")
    emit(f"e2e/{tag}_{mode}_tpt", out["tpt_mean"] * 1e3,
         f"p50={out['tpt_p50']:.1f}ms;p99={out['tpt_p99']:.1f}ms")
    emit(f"e2e/{tag}_{mode}_latency", out["latency_mean"] * 1e3,
         f"p50={out['latency_p50']:.1f}ms;n={out['n']}")
    return out


def run():
    cfg = get_config("llama2-7b")
    rng = np.random.default_rng(0)
    # Fig 10: synthetic, RPS=9, rank 64, distinct adapters (all cold)
    adapters = gen.make_adapters(600, cfg.name, rng, uniform_rank=64)
    reqs = gen.synthetic_trace(adapters, rps=9, duration_s=45, vocab=100,
                               seed=1)
    for mode, kern in BASELINES:
        one(cfg, mode, kern, reqs, adapters, "fig10_rps9_r64")
    # Fig 13: sensitivity — rank 32 @ rps 9, rank 64 @ rps 6
    adapters32 = gen.make_adapters(600, cfg.name, rng, uniform_rank=32)
    reqs32 = gen.synthetic_trace(adapters32, rps=9, duration_s=45, vocab=100,
                                 seed=2)
    for mode, kern in BASELINES:
        one(cfg, mode, kern, reqs32, adapters32, "fig13_rps9_r32")
    reqs6 = gen.synthetic_trace(adapters, rps=6, duration_s=45, vocab=100,
                                seed=3)
    for mode, kern in BASELINES:
        one(cfg, mode, kern, reqs6, adapters, "fig13_rps6_r64")
    # Fig 14: MAF-scaled, growing adapter counts (load scales with count)
    for n_adapt, rps in ((128, 1.5), (256, 3.6), (512, 7.7)):
        ads = gen.make_adapters(n_adapt, cfg.name, rng, uniform_rank=64)
        mreqs = gen.maf_trace(ads, rps=rps, duration_s=45, vocab=100,
                              seed=4)
        for mode, kern in BASELINES:
            one(cfg, mode, kern, mreqs, ads, f"fig14_n{n_adapt}")
    # Fig 15 / Table 2: multi-chip TP instances (13B on 2 chips, 70B on 4)
    from repro.core.timing import Hardware
    for arch, chips in (("llama2-13b", 2), ("llama2-70b", 4)):
        tcfg = get_config(arch)
        hw = Hardware(chips=chips)
        ads = gen.make_adapters(400, tcfg.name, rng, uniform_rank=64)
        treqs = gen.synthetic_trace(ads, rps=6, duration_s=45, vocab=100,
                                    seed=5)
        for mode, kern in (("cached", "bgmv"), ("ondemand", "bgmv"),
                           ("caraserve", "bgmv")):
            srv = InferenceServer(tcfg, mode=mode, kernel=kern, max_batch=16,
                                  numerics=False, hw=hw)
            for ad in ads:
                srv.register_adapter(ad)
            out = srv.run(treqs)
            emit(f"e2e/fig15_{arch}_tp{chips}_{mode}",
                 out["latency_mean"] * 1e3,
                 f"ttft={out['ttft_mean']:.1f}ms;n={out['n']}")


if __name__ == "__main__":
    run()
