import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def write_bench_json(name: str, payload: dict, out_dir: str = None):
    """Write BENCH_<name>.json so the perf trajectory is machine-readable
    across PRs (tokens/s, TTFT, SLO, h2d counts, ...). `payload` should be
    a plain dict of metrics; the emitted CSV rows so far are attached under
    "rows" for free. Returns the path."""
    path = os.path.join(out_dir or os.environ.get("BENCH_OUT_DIR", "."),
                        f"BENCH_{name}.json")
    doc = dict(payload)
    doc.setdefault("bench", name)
    doc["rows"] = [{"name": n, "value_us": v, "derived": d}
                   for n, v, d in ROWS]
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=float)
        f.write("\n")
    print(f"wrote {path}", flush=True)
    return path


def oversub_stats(srv) -> dict:
    """Preemption / KV over-subscription telemetry of one InferenceServer
    for BENCH_*.json (all-zero on dense layouts and never-preempting runs).
    Keys: preemptions, swap_preemptions, recompute_preemptions,
    swapped_pages, recompute_tokens, grown_pages, peak_oversub."""
    d = {k: int(v) for k, v in srv.preempt_stats.items()}
    d["peak_oversub"] = float(srv.peak_oversub)
    return d


def cluster_oversub_stats(cluster) -> dict:
    """Aggregate oversub_stats over a Cluster: counters sum, peak_oversub
    takes the per-server max (a ratio — summing it is meaningless)."""
    agg = {}
    for srv in cluster.servers:
        for k, v in oversub_stats(srv).items():
            if k == "peak_oversub":
                agg[k] = max(agg.get(k, 0.0), v)
            else:
                agg[k] = agg.get(k, 0) + v
    return agg


def fault_stats(srv) -> dict:
    """Failure-plane telemetry of one InferenceServer for BENCH_*.json:
    crash/restart/drain counters from the engine, upload failure/retry/
    cancel counters from the link tracker, and admission-level shedding.
    All-zero on fault-free runs — the counters exist in every BENCH doc so
    the trajectory is comparable across PRs."""
    d = {k: int(v) for k, v in srv.fault_stats.items()}
    tr = srv.cold.tracker.stats
    for k in ("upload_failures", "retries", "prefetch_dropped",
              "crash_canceled"):
        d[k] = int(tr[k])
    d["admission_shed"] = int(srv.admission.shed_count)
    return d


def cluster_fault_stats(cluster) -> dict:
    """Aggregate fault_stats over a Cluster (counters sum) plus the
    cluster-level failover/shed ledger under a `cluster_` prefix."""
    agg = {}
    for srv in cluster.servers:
        for k, v in fault_stats(srv).items():
            agg[k] = agg.get(k, 0) + v
    for k, v in cluster.fault_stats.items():
        agg[f"cluster_{k}"] = int(v)
    return agg


def itl_stats(srv) -> dict:
    """Inter-token-latency percentiles of one InferenceServer for
    BENCH_*.json: n_gaps, itl_mean_ms, itl_p50_ms, itl_p99_ms."""
    return srv.itl_stats()


def cluster_itl_stats(cluster) -> dict:
    """ITL percentiles pooled across every server of a Cluster (gaps are
    pooled, not averaged — a percentile of percentiles is meaningless)."""
    from repro.serving.request import itl_percentiles
    return itl_percentiles(g for srv in cluster.servers
                           for g in srv.itl_samples())


def time_us(fn, iters=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6
