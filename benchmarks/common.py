import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def time_us(fn, iters=5, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6
