"""Paper Figs 8/16/17 micro-benchmarks, mapped to their JAX analogues:

* sync-free invocation (Fig 8/16): issuing dependent device work WITHOUT a
  host sync between steps (XLA async dispatch) vs an explicit blocking sync
  per layer — the same queue-stall the paper's fused memcpy+signal removes.
* shared-memory transfer (Fig 17): zero-copy ndarray views between producer
  and N consumers vs pickle-serialized message passing (socket-style IPC).
"""
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us


def run():
    # sync-free invocation
    x = jnp.ones((256, 256))
    w = jnp.ones((256, 256)) * 0.01
    mm = jax.jit(lambda a: a @ w)

    def chain_async():
        y = x
        for _ in range(32):                 # 32 "layers" (llama2-7B)
            y = mm(y)
        jax.block_until_ready(y)

    def chain_synced():
        y = x
        for _ in range(32):
            y = mm(y)
            jax.block_until_ready(y)        # explicit per-layer sync
    t_async = time_us(chain_async, iters=20)
    t_sync = time_us(chain_synced, iters=20)
    emit("invocation/async_dispatch_32layers", t_async,
         f"synced={t_sync:.0f}us;speedup={t_sync / t_async:.2f}x")

    # shared memory vs serialize (Fig 17): 16 tokens x 4096 to N receivers
    payload = np.ones((16, 4096), np.float32)
    for n_recv in (1, 4, 8):
        def shm():
            views = [payload[:] for _ in range(n_recv)]   # zero-copy views
            return sum(v[0, 0] for v in views)

        def socket_style():
            outs = []
            for _ in range(n_recv):
                outs.append(pickle.loads(pickle.dumps(payload)))
            return outs[0][0, 0]
        t_shm = time_us(shm, iters=50)
        t_sock = time_us(socket_style, iters=50)
        emit(f"invocation/shm_{n_recv}recv", t_shm,
             f"socket={t_sock:.0f}us;speedup={t_sock / max(t_shm, 0.01):.0f}x")


if __name__ == "__main__":
    run()
