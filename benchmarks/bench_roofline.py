"""Roofline table reader: aggregates the dry-run JSONs (launch/dryrun.py)
into the EXPERIMENTS.md sec Roofline rows. Does not compile anything itself —
run the dry-run first; missing combos are reported as such."""
import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/missing", 0.0, "run launch/dryrun.py first")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec["status"] != "ok":
            emit(f"roofline/{tag}", 0.0, rec["status"])
            continue
        r = rec["roofline"]
        dom_t = r[f"{r['dominant']}_s"]
        ratio = rec.get("useful_flops_ratio")
        emit(f"roofline/{tag}", dom_t * 1e6,
             f"dom={r['dominant']};c={r['compute_s']:.4f}s;"
             f"m={r['memory_s']:.4f}s;x={r['collective_s']:.4f}s;"
             f"useful={ratio:.3f};fits16g={rec.get('fits_16g')}")


if __name__ == "__main__":
    run()
