"""Paper Fig 3: (right) adapter load latency vs rank; (left) cold-start share
of request serving time vs aggregate load, ONDMD vs CARASERVE."""
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.core.timing import TimingModel
from repro.serving.request import Request
from repro.traces import gen


def run():
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    # Fig 3-right: load latency vs rank
    for rank in (8, 16, 32, 64):
        ms = tm.load_ms(AdapterSpec("x", rank, cfg.name).nbytes(cfg))
        emit(f"cold_start/load_ms_rank{rank}", ms * 1e3,
             f"load={ms:.2f}ms")
    # Fig 3-left: cold-start share vs RPS (512 adapters, MAF-skewed)
    for rps in (3.0, 6.0, 9.0):
        for mode in ("ondemand", "caraserve"):
            srv = InferenceServer(cfg, mode=mode, max_batch=16,
                                  numerics=False)
            rng = np.random.default_rng(0)
            adapters = gen.make_adapters(512, cfg.name, rng, uniform_rank=64)
            for ad in adapters:
                srv.register_adapter(ad)
            reqs = gen.maf_trace(adapters, rps=rps, duration_s=30,
                                 vocab=100, seed=1)
            out = srv.run(reqs)
            load_ms = tm.load_ms(adapters[0].nbytes(cfg))
            total = sum(s.latency_ms() for s in srv.states if s.finish_ms)
            share = load_ms * out["cold_starts"] / max(total, 1e-9)
            emit(f"cold_start/share_{mode}_rps{rps:g}", out["ttft_mean"] * 1e3,
                 f"cold_share={share:.3f};colds={out['cold_starts']}/{out['n']}")
    run_prefetch()


def run_prefetch():
    """Beyond-paper: prefetching x mode matrix on the skewed MAF trace."""
    cfg = get_config("llama2-7b")
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(64, cfg.name, rng, uniform_rank=64)
    reqs = gen.maf_trace(adapters, rps=8, duration_s=30, vocab=100, seed=1)
    for mode in ("ondemand", "caraserve"):
        for pf in (False, True):
            srv = InferenceServer(cfg, mode=mode, max_batch=16,
                                  numerics=False, prefetch=pf,
                                  pool_slots=24)
            for ad in adapters:
                srv.register_adapter(ad)
            out = srv.run(reqs)
            emit(f"cold_start/prefetch_{mode}_{'on' if pf else 'off'}",
                 out["ttft_mean"] * 1e3,
                 f"colds={out['cold_starts']}/{out['n']}")


def run_contention():
    """LoadTracker link contention: mean TTFT of K simultaneous cold starts
    (rank 64) per mode — grows with K for cold paths, flat for CACHED."""
    cfg = get_config("llama2-7b")
    for mode in ("cached", "caraserve", "ondemand"):
        for k in (1, 2, 4, 8, 16):
            srv = InferenceServer(cfg, mode=mode, max_batch=16,
                                  numerics=False)
            for i in range(k):
                srv.register_adapter(AdapterSpec(f"ad{i}", rank=64,
                                                 base_model=cfg.name))
            reqs = [Request(rid=i, adapter_uid=f"ad{i}",
                            prompt=np.zeros(128, np.int32),
                            max_new_tokens=4, arrival_ms=0.0)
                    for i in range(k)]
            out = srv.run(reqs)
            emit(f"cold_start/contention_{mode}_k{k}",
                 out["ttft_mean"] * 1e3,
                 f"ttft={out['ttft_mean']:.1f}ms;flipped={out['flipped']}")


if __name__ == "__main__":
    run()
    run_contention()
