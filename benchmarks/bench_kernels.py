"""Paper Fig 4: decoding-latency scaling of BGMV (max-rank law) vs MBGMV
(sum-rank law). Wall-clock measured on the interpret-mode kernels at reduced
size (the law is structural: grid-step counts), plus the analytic v5e cost at
paper scale. Emits BENCH_kernels.json with tokens/s equivalents and the
static per-kernel VMEM footprints from the kernel verifier
(`repro.analysis.kernel_model`), so the perf trajectory and the VMEM
headroom are machine-readable across PRs.

``--smoke`` shrinks the measured sweep for the CI arm.
"""
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_us, write_bench_json
from repro.configs.base import get_config
from repro.core.timing import TimingModel
from repro.kernels.bgmv import bgmv
from repro.kernels.mbgmv import mbgmv


def run(smoke: bool = False):
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    tokens_per_s = {}
    # analytic law at target scale (v5e): batches of heterogeneous ranks
    for bs in (8,) if smoke else (8, 16, 32):
        hetero = [8] * (bs - 1) + [64]
        homo = [64] * bs
        for kern in ("bgmv", "mbgmv"):
            t_het = tm.lora_decode_ms(hetero, kern)
            t_hom = tm.lora_decode_ms(homo, kern)
            emit(f"kernels/{kern}_bs{bs}_hetero", t_het * 1e3,
                 f"homo={t_hom * 1e3:.1f}us;ratio={t_het / t_hom:.3f}")
            # one decode step serves `bs` tokens: the analytic ms/step is a
            # per-batch tokens/s figure on the modeled v5e
            tokens_per_s[f"{kern}_bs{bs}_hetero"] = bs / (t_het * 1e-3)
            tokens_per_s[f"{kern}_bs{bs}_homo"] = bs / (t_hom * 1e-3)
    # measured grid-work scaling (interpret mode, reduced dims)
    slots, d_in, d_out, r_max = 8, 512, 512, 64
    if smoke:
        d_in = d_out = 256
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    ranks64 = jnp.full((slots,), 64, jnp.int32)
    ranks8 = jnp.full((slots,), 8, jnp.int32)
    a = jax.random.normal(ks[0], (slots, d_in, r_max))
    b = jax.random.normal(ks[1], (slots, r_max, d_out))
    x = jnp.ones((8, d_in))
    idx = jnp.arange(8) % slots
    f_b = jax.jit(lambda: bgmv(x, a, b, idx))
    f_m64 = jax.jit(lambda: mbgmv(x, a, b, idx, ranks64))
    f_m8 = jax.jit(lambda: mbgmv(x, a, b, idx, ranks8))
    iters = 2 if smoke else 5
    t_b = time_us(lambda: jax.block_until_ready(f_b()), iters=iters)
    t64 = time_us(lambda: jax.block_until_ready(f_m64()), iters=iters)
    t8 = time_us(lambda: jax.block_until_ready(f_m8()), iters=iters)
    # NOTE: interpret mode executes the kernel body in Python, so wall-clock
    # here is dominated by grid-iteration overhead, not the skipped MXU work;
    # the rank laws themselves are the analytic rows above + the grid-step
    # counts below (what a real TPU would execute)
    emit("kernels/measured_bgmv_r64", t_b, "interpret-mode wall-clock")
    emit("kernels/measured_mbgmv_r64", t64, "interpret-mode wall-clock")
    emit("kernels/measured_mbgmv_r8", t8, "interpret-mode wall-clock")
    live64 = 8 * (64 // 16)
    live8 = 8 * (8 // 16 + 1)
    emit("kernels/gridwork_mbgmv_r64_vs_r8", live64 / live8,
         f"live_rank_blocks {live64} vs {live8}: sum-rank law on TPU")

    # static VMEM footprints from the kernel verifier's symbolic models —
    # per-grid-step bytes under double buffering, the headroom the real-TPU
    # run will see
    from repro.analysis import kernel_model, kernel_verify
    vmem = {}
    case = kernel_model.case_from_config(cfg)
    for m in kernel_model.build_models(case):
        fp = m.vmem_footprint()
        vmem[m.name] = fp
        emit(f"kernels/vmem_{m.name}", float(fp["total_bytes"]),
             f"bytes/grid-step (budget {kernel_verify.VMEM_BUDGET_BYTES})")

    write_bench_json("kernels", {
        "arch": cfg.name,
        "smoke": smoke,
        "tokens_per_s": tokens_per_s,
        "vmem_budget_bytes": kernel_verify.VMEM_BUDGET_BYTES,
        "vmem_footprints": vmem,
        "interpret_us": {"bgmv_r64": t_b, "mbgmv_r64": t64,
                         "mbgmv_r8": t8},
    })


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
