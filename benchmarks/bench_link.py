"""Host-link scheduling under mixed prefetch/demand traffic: fifo vs
priority vs preempt on cold-start first-token latency and SLO attainment
(ROADMAP item "prefetch/demand link-sharing policies").

One server with the popularity-EWMA prefetcher enabled serves a drifting
MAF trace: the hot set keeps moving, so the prefetcher keeps speculative
uploads on the link exactly while tail/new-phase adapters cold-start on
demand. Under `fifo` a demand upload queues behind up to PREFETCH_PER_TICK
speculative transfers; `priority` lets it jump the queue; `preempt`
additionally cancels queued prefetch outright (reclaiming link time and
device slots).

Two arms:

* **slora** (acceptance): S-LoRA-style on-demand loading — the adapter
  upload is on the first-token path, so link scheduling lands directly in
  cold-start TTFT and SLO attainment. This is the host→device paging
  policy S-LoRA leaves unspecified, made concrete and measured.
* **caraserve** (reported): CPU-assist hides the upload from the *first*
  token by design (paper Fig 1/7), so the link policy moves decode
  readiness / latency instead of TTFT; the preempt invariant still holds.

Acceptance (asserted below, both full and --smoke, slora arm):

* `priority` or `preempt` strictly improves mean cold-start TTFT *and*
  SLO attainment over `fifo` (and neither is worse on cold TTFT);
* a demand upload is never delayed by a queued prefetch under `preempt`
  (`LoadTracker.stats["demand_delayed_by_prefetch"] == 0`), while `fifo`
  does delay some (the bench is actually exercising link contention).

``--smoke`` runs a smaller trace — the CI cluster-smoke job.
"""
import argparse

import numpy as np

from benchmarks.common import (emit, itl_stats, oversub_stats,
                               write_bench_json)
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.perf_model import ServerPerfModel
from repro.traces import gen

POLICIES = ("fifo", "priority", "preempt")


def run_one(cfg, adapters, reqs, mode, policy, max_batch, pool_slots):
    srv = InferenceServer(cfg, mode=mode, max_batch=max_batch,
                          numerics=False, prefetch=True,
                          pool_slots=pool_slots, link_policy=policy)
    for ad in adapters:
        srv.register_adapter(ad)
    out = srv.run(reqs)
    assert out["n"] == len(reqs), (mode, policy, out["n"], len(reqs))
    cold = [s for s in srv.states if s.cold_start]
    cold_ttft = float(np.mean([s.ttft_ms() for s in cold])) if cold else 0.0
    return {
        "out": out,
        "cold_ttft_mean": cold_ttft,
        "n_cold": len(cold),
        "link": dict(srv.cold.tracker.stats),
        "preempt": oversub_stats(srv),
        "itl": itl_stats(srv),
    }


def run(smoke: bool = False):
    cfg = get_config("llama2-7b")
    perf = ServerPerfModel(cfg, kernel="bgmv")
    max_batch, pool_slots = 16, 20
    if smoke:
        n_adapters, rps, duration, phases = 128, 14, 8, 6
    else:
        n_adapters, rps, duration, phases = 128, 14, 12, 8
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(n_adapters, cfg.name, rng, uniform_rank=64)
    slo = 2.5 * perf.dec_perf([64] * max_batch)
    # short outputs keep the decode plane comfortably under capacity, so
    # SLO misses trace back to upload stalls — the quantity under test
    reqs = gen.drifting_maf_trace(adapters, rps=rps, duration_s=duration,
                                  vocab=100, seed=1, n_phases=phases,
                                  zipf_a=1.1, max_out=12, slo_tpt_ms=slo)

    res = {}
    doc = {"smoke": smoke, "n_adapters": n_adapters, "rps": rps,
           "arms": {}}
    for mode in ("slora", "caraserve"):
        for policy in POLICIES:
            r = run_one(cfg, adapters, reqs, mode, policy, max_batch,
                        pool_slots)
            res[(mode, policy)] = r
            lk = r["link"]
            doc["arms"][f"{mode}_{policy}"] = {
                "cold_ttft_ms": r["cold_ttft_mean"],
                "ttft_mean_ms": r["out"]["ttft_mean"],
                "slo_attainment": r["out"]["slo_attainment"],
                "latency_mean_ms": r["out"]["latency_mean"],
                "n_cold": r["n_cold"], "link": lk,
                "preempt": r["preempt"]}
            emit(f"link/{mode}_{policy}", r["cold_ttft_mean"] * 1e3,
                 f"cold_ttft={r['cold_ttft_mean']:.1f}ms;"
                 f"slo={r['out']['slo_attainment']:.3f};"
                 f"lat={r['out']['latency_mean']:.1f}ms;"
                 f"cold={r['n_cold']};prefetch={lk['prefetch']};"
                 f"promoted={lk['promoted']};preempted={lk['preempted']};"
                 f"delayed={lk['demand_delayed_by_prefetch']};"
                 f"n={r['out']['n']}")

    # --- acceptance (slora arm: upload on the first-token path) -----------
    fifo = res[("slora", "fifo")]
    # the bench must actually exercise prefetch/demand contention
    assert fifo["link"]["demand_delayed_by_prefetch"] > 0, \
        "no demand upload ever queued behind a prefetch under fifo — " \
        "the trace is not exercising link contention"
    # preempt guarantee: a demand upload is never delayed by queued
    # prefetch — in either mode
    for mode in ("slora", "caraserve"):
        assert res[(mode, "preempt")]["link"][
            "demand_delayed_by_prefetch"] == 0, res[(mode, "preempt")]["link"]
    # priority/preempt never lose to fifo on cold-start TTFT...
    for policy in ("priority", "preempt"):
        r = res[("slora", policy)]
        assert r["cold_ttft_mean"] <= fifo["cold_ttft_mean"] + 1e-9, \
            (policy, r["cold_ttft_mean"], fifo["cold_ttft_mean"])
    # ...and the better of the two strictly improves both metrics
    best = min(("priority", "preempt"),
               key=lambda p: res[("slora", p)]["cold_ttft_mean"])
    assert res[("slora", best)]["cold_ttft_mean"] < fifo["cold_ttft_mean"], \
        (best, res[("slora", best)]["cold_ttft_mean"],
         fifo["cold_ttft_mean"])
    best_slo = max(("priority", "preempt"),
                   key=lambda p: res[("slora", p)]["out"]["slo_attainment"])
    assert res[("slora", best_slo)]["out"]["slo_attainment"] > \
        fifo["out"]["slo_attainment"], \
        (best_slo, res[("slora", best_slo)]["out"]["slo_attainment"],
         fifo["out"]["slo_attainment"])
    write_bench_json("link", doc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for the CI cluster-smoke job")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
