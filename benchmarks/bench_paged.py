"""Paged vs dense KV memory at equal HBM budget (ROADMAP item "unified
paged device memory").

Two properties of the block-table memory plane, measured on the same
decode-heavy trace (cached adapters, short prompts):

* **capacity** — the dense slab statically reserves ``cache_slots`` tokens
  of KV per row, so an HBM budget of B rows admits at most B concurrent
  requests regardless of their actual lengths. The paged plane claims
  ``ceil((prompt + max_new) / page_size)`` pages per request from the same
  byte budget, so short requests pack: the peak concurrent batch is
  strictly larger for every page size that subdivides the ring
  (``page_size == cache_slots`` is the degenerate one-page-per-row point
  where paged collapses to dense capacity — reported, not asserted
  strict). Swept over page_size ∈ {16, 32, 64}.
* **parity + throughput** — at equal batch the paged path produces
  token-for-token the dense greedy stream (asserted, the CI smoke gate)
  and sustains comparable decode tokens/s (reported; the pure-jnp CPU
  gather makes paged decode pay a per-step gather the TPU kernel
  (kernels/paged.py) does via BlockSpec index maps instead).

Emits ``BENCH_paged.json`` (peaks, tokens/s, h2d counts per arm).

``--smoke`` runs one page size — the CI cluster-smoke job.
"""
import argparse
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.serving.request import Request

N_ADAPTERS = 4


def make_reqs(n, vocab, max_new, t0, rng, rid0=0, prompt_len=6):
    return [Request(rid=rid0 + i, adapter_uid=f"ad{i % N_ADAPTERS}",
                    prompt=rng.integers(0, vocab,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=max_new, arrival_ms=t0)
            for i in range(n)]


def make_server(cfg, memory, max_batch, cache_slots, page_size=32,
                total_pages=None):
    srv = InferenceServer(cfg, mode="cached", kernel="bgmv",
                          max_batch=max_batch, cache_slots=cache_slots,
                          numerics=True, seed=0, memory=memory,
                          page_size=page_size, total_pages=total_pages)
    for i in range(N_ADAPTERS):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
    return srv


def run_timed(srv, cfg, n_reqs, max_new):
    """Warmup run (pays jit) then a timed run; returns tokens/s + stats."""
    rng = np.random.default_rng(0)
    srv.run(make_reqs(n_reqs, cfg.vocab, max_new, 0.0, rng))
    n_warm = len(srv.states)
    pre = dict(srv.backend.transfer_stats)
    t0 = time.perf_counter()
    srv.run(make_reqs(n_reqs, cfg.vocab, max_new, srv.clock + 1.0, rng,
                      rid0=1000))
    wall_s = time.perf_counter() - t0
    states = srv.states[n_warm:]
    assert all(len(st.generated) == max_new for st in states)
    dec_tokens = sum(len(st.generated) - 1 for st in states)
    stats = {k: srv.backend.transfer_stats[k] - pre[k] for k in pre}
    return {"tps": dec_tokens / wall_s, "wall_s": wall_s,
            "toks": [st.generated for st in states],
            "peak_rows": srv.admission.peak_active_rows, **stats}


def run(smoke: bool = False):
    cfg = get_config("llama2-7b").smoke()
    cache_slots, dense_rows = 64, 4
    page_sizes = (32,) if smoke else (16, 32, 64)
    max_new, n_reqs = (10, 12) if smoke else (10, 16)
    results = {"config": {"cache_slots": cache_slots,
                          "dense_rows": dense_rows, "max_new": max_new,
                          "n_reqs": n_reqs, "smoke": smoke}, "capacity": {},
               "equal_batch": {}}

    # --- capacity at equal HBM budget -----------------------------------
    # the dense slab reserves dense_rows * cache_slots tokens of KV; the
    # paged pool gets exactly that byte budget in KV pages (adapters claim
    # from the same pool, so their pages are added on top for parity with
    # dense, whose adapter slots live outside the slab)
    dense = make_server(cfg, "dense", dense_rows, cache_slots)
    rng = np.random.default_rng(1)
    dense.run(make_reqs(n_reqs, cfg.vocab, max_new, 0.0, rng))
    dense_peak = dense.admission.peak_active_rows
    dense_toks = {st.req.rid: st.generated for st in dense.states}
    for ps in page_sizes:
        kv_pages = dense_rows * (cache_slots // ps)
        probe = make_server(cfg, "paged", 1, cache_slots, page_size=ps)
        ad_pages = N_ADAPTERS * probe.pool.pages_for(
            AdapterSpec("ad0", 8, cfg.name).nbytes(cfg))
        srv = make_server(cfg, "paged", n_reqs, cache_slots, page_size=ps,
                          total_pages=kv_pages + ad_pages)
        rng = np.random.default_rng(1)
        srv.run(make_reqs(n_reqs, cfg.vocab, max_new, 0.0, rng))
        peak = srv.admission.peak_active_rows
        toks = {st.req.rid: st.generated for st in srv.states}
        assert toks == dense_toks, f"token mismatch at page_size={ps}"
        emit(f"paged/capacity_ps{ps}", peak,
             f"paged_peak={peak};dense_peak={dense_peak};"
             f"kv_pages={kv_pages};ad_pages={ad_pages}")
        results["capacity"][f"ps{ps}"] = {
            "paged_peak_rows": peak, "dense_peak_rows": dense_peak,
            "kv_pages": kv_pages, "adapter_pages": ad_pages}
        if ps < cache_slots:
            assert peak > dense_peak, \
                (ps, peak, dense_peak,
                 "paged must admit a strictly larger concurrent batch "
                 "at equal HBM budget")
        else:
            assert peak >= dense_peak, (ps, peak, dense_peak)

    # --- equal batch: parity + tokens/s ---------------------------------
    arms = {}
    for memory in ("dense", "paged"):
        srv = make_server(cfg, memory, dense_rows, cache_slots)
        arms[memory] = run_timed(srv, cfg, dense_rows * 2, max_new)
        r = arms[memory]
        emit(f"paged/equal_batch_{memory}", r["tps"],
             f"tok_s={r['tps']:.1f};steps={r['decode_steps']};"
             f"h2d={r['h2d']};d2h={r['d2h']};peak={r['peak_rows']}")
        results["equal_batch"][memory] = {
            k: r[k] for k in ("tps", "wall_s", "decode_steps", "h2d",
                              "h2d_bytes", "d2h", "peak_rows")}
    # paged decode == dense decode token-for-token under greedy sampling
    assert arms["paged"]["toks"] == arms["dense"]["toks"], \
        "paged decode diverged from dense decode"
    # device-resident invariants hold on the paged path too
    assert arms["paged"]["h2d"] < 3 * arms["paged"]["decode_steps"], \
        "paged decode is paying per-step uploads"
    results["tokens_per_s"] = {m: arms[m]["tps"] for m in arms}
    results["paged_over_dense_tps"] = \
        arms["paged"]["tps"] / arms["dense"]["tps"]
    write_bench_json("paged", results)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one page size + parity gate for CI cluster-smoke")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
