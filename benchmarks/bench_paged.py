"""Paged vs dense KV memory at equal HBM budget (ROADMAP item "unified
paged device memory").

Two properties of the block-table memory plane, measured on the same
decode-heavy trace (cached adapters, short prompts):

* **capacity** — the dense slab statically reserves ``cache_slots`` tokens
  of KV per row, so an HBM budget of B rows admits at most B concurrent
  requests regardless of their actual lengths. The paged plane claims
  ``ceil((prompt + max_new) / page_size)`` pages per request from the same
  byte budget, so short requests pack: the peak concurrent batch is
  strictly larger for every page size that subdivides the ring
  (``page_size == cache_slots`` is the degenerate one-page-per-row point
  where paged collapses to dense capacity — reported, not asserted
  strict). Swept over page_size ∈ {16, 32, 64}.
* **parity + throughput** — at equal batch the paged path produces
  token-for-token the dense greedy stream (asserted, the CI smoke gate)
  and sustains comparable decode tokens/s (reported; the pure-jnp CPU
  gather makes paged decode pay a per-step gather the TPU kernel
  (kernels/paged.py) does via BlockSpec index maps instead).

* **sustained occupancy (KV over-subscription)** — on a MAF trace at equal
  HBM, prompt-only admission with lazy block-table growth keeps the batch
  full where the admit-full-footprint baseline defers arrivals until their
  whole lifetime footprint fits. Swept over nominal over-subscription
  factors (pool shrunk to ``nominal_kv_pages / factor``): sustained
  simulated tokens/s and SLO attainment for the ``full`` baseline vs
  ``swap`` vs ``recompute`` preemption arms, token-parity gated (every
  arm, preempted or not, must emit the reference token streams). At 1.25x
  the over-subscribed arms must beat the baseline's tokens/s — the paper's
  peak-batch-to-sustained-occupancy claim, and the CI acceptance gate.

Emits ``BENCH_paged.json`` (peaks, tokens/s, h2d counts, preemption
telemetry per arm).

``--smoke`` runs one page size + the 1.25x sustained factor — the CI
cluster-smoke job.
"""
import argparse
import time

import numpy as np

from benchmarks.common import (emit, itl_stats, oversub_stats,
                               write_bench_json)
from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.serving.request import Request
from repro.serving.request import summarize
from repro.traces.gen import maf_trace

N_ADAPTERS = 4


def make_reqs(n, vocab, max_new, t0, rng, rid0=0, prompt_len=6):
    return [Request(rid=rid0 + i, adapter_uid=f"ad{i % N_ADAPTERS}",
                    prompt=rng.integers(0, vocab,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=max_new, arrival_ms=t0)
            for i in range(n)]


def make_server(cfg, memory, max_batch, cache_slots, page_size=32,
                total_pages=None, **kw):
    srv = InferenceServer(cfg, mode="cached", kernel="bgmv",
                          max_batch=max_batch, cache_slots=cache_slots,
                          numerics=True, seed=0, memory=memory,
                          page_size=page_size, total_pages=total_pages,
                          **kw)
    for i in range(N_ADAPTERS):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
    return srv


def run_timed(srv, cfg, n_reqs, max_new):
    """Warmup run (pays jit) then a timed run; returns tokens/s + stats."""
    rng = np.random.default_rng(0)
    srv.run(make_reqs(n_reqs, cfg.vocab, max_new, 0.0, rng))
    n_warm = len(srv.states)
    pre = dict(srv.backend.transfer_stats)
    t0 = time.perf_counter()
    srv.run(make_reqs(n_reqs, cfg.vocab, max_new, srv.clock + 1.0, rng,
                      rid0=1000))
    wall_s = time.perf_counter() - t0
    states = srv.states[n_warm:]
    assert all(len(st.generated) == max_new for st in states)
    dec_tokens = sum(len(st.generated) - 1 for st in states)
    stats = {k: srv.backend.transfer_stats[k] - pre[k] for k in pre}
    return {"tps": dec_tokens / wall_s, "wall_s": wall_s,
            "toks": [st.generated for st in states],
            "peak_rows": srv.admission.peak_active_rows, **stats}


def run(smoke: bool = False):
    cfg = get_config("llama2-7b").smoke()
    cache_slots, dense_rows = 64, 4
    page_sizes = (32,) if smoke else (16, 32, 64)
    max_new, n_reqs = (10, 12) if smoke else (10, 16)
    results = {"config": {"cache_slots": cache_slots,
                          "dense_rows": dense_rows, "max_new": max_new,
                          "n_reqs": n_reqs, "smoke": smoke}, "capacity": {},
               "equal_batch": {}}

    # --- capacity at equal HBM budget -----------------------------------
    # the dense slab reserves dense_rows * cache_slots tokens of KV; the
    # paged pool gets exactly that byte budget in KV pages (adapters claim
    # from the same pool, so their pages are added on top for parity with
    # dense, whose adapter slots live outside the slab)
    dense = make_server(cfg, "dense", dense_rows, cache_slots)
    rng = np.random.default_rng(1)
    dense.run(make_reqs(n_reqs, cfg.vocab, max_new, 0.0, rng))
    dense_peak = dense.admission.peak_active_rows
    dense_toks = {st.req.rid: st.generated for st in dense.states}
    for ps in page_sizes:
        kv_pages = dense_rows * (cache_slots // ps)
        probe = make_server(cfg, "paged", 1, cache_slots, page_size=ps)
        ad_pages = N_ADAPTERS * probe.pool.pages_for(
            AdapterSpec("ad0", 8, cfg.name).nbytes(cfg))
        srv = make_server(cfg, "paged", n_reqs, cache_slots, page_size=ps,
                          total_pages=kv_pages + ad_pages)
        rng = np.random.default_rng(1)
        srv.run(make_reqs(n_reqs, cfg.vocab, max_new, 0.0, rng))
        peak = srv.admission.peak_active_rows
        toks = {st.req.rid: st.generated for st in srv.states}
        assert toks == dense_toks, f"token mismatch at page_size={ps}"
        emit(f"paged/capacity_ps{ps}", peak,
             f"paged_peak={peak};dense_peak={dense_peak};"
             f"kv_pages={kv_pages};ad_pages={ad_pages}")
        results["capacity"][f"ps{ps}"] = {
            "paged_peak_rows": peak, "dense_peak_rows": dense_peak,
            "kv_pages": kv_pages, "adapter_pages": ad_pages}
        if ps < cache_slots:
            assert peak > dense_peak, \
                (ps, peak, dense_peak,
                 "paged must admit a strictly larger concurrent batch "
                 "at equal HBM budget")
        else:
            assert peak >= dense_peak, (ps, peak, dense_peak)

    # --- equal batch: parity + tokens/s ---------------------------------
    arms = {}
    for memory in ("dense", "paged"):
        srv = make_server(cfg, memory, dense_rows, cache_slots)
        arms[memory] = run_timed(srv, cfg, dense_rows * 2, max_new)
        r = arms[memory]
        emit(f"paged/equal_batch_{memory}", r["tps"],
             f"tok_s={r['tps']:.1f};steps={r['decode_steps']};"
             f"h2d={r['h2d']};d2h={r['d2h']};peak={r['peak_rows']}")
        results["equal_batch"][memory] = {
            k: r[k] for k in ("tps", "wall_s", "decode_steps", "h2d",
                              "h2d_bytes", "d2h", "peak_rows")}
    # paged decode == dense decode token-for-token under greedy sampling
    assert arms["paged"]["toks"] == arms["dense"]["toks"], \
        "paged decode diverged from dense decode"
    # device-resident invariants hold on the paged path too
    assert arms["paged"]["h2d"] < 3 * arms["paged"]["decode_steps"], \
        "paged decode is paying per-step uploads"
    results["tokens_per_s"] = {m: arms[m]["tps"] for m in arms}
    results["paged_over_dense_tps"] = \
        arms["paged"]["tps"] / arms["dense"]["tps"]

    # --- sustained occupancy under KV over-subscription -----------------
    results["sustained"] = run_sustained(cfg, smoke)
    write_bench_json("paged", results)


def run_sustained(cfg, smoke: bool):
    """MAF trace at equal HBM, pool shrunk below the running batch's
    lifetime KV demand: prompt-only admission + preemptive swap/recompute
    vs the admit-full-footprint baseline. Throughput is *simulated*
    tokens/s (decode tokens over virtual-clock makespan) — deterministic,
    so CI can gate on it; SLO attainment comes from the same timeline."""
    cache_slots, ps, max_batch = 64, 32, 8
    # arrivals must bunch well inside a request's service time, or the
    # batch never fills and no pool size is ever actually over-subscribed
    rps, dur = (300.0, 0.06) if smoke else (300.0, 0.15)
    factors = (1.25,) if smoke else (1.0, 1.25, 1.5)
    specs = [AdapterSpec(f"ad{i}", 8, cfg.name) for i in range(N_ADAPTERS)]
    # nominal KV demand: every row at full ring depth (the dense slab's
    # reservation); factor f shrinks the pool's KV share to nominal / f
    nominal = max_batch * (cache_slots // ps)
    probe = make_server(cfg, "paged", 1, cache_slots, page_size=ps)
    ad_pages = N_ADAPTERS * probe.pool.pages_for(specs[0].nbytes(cfg))

    def trace():
        return maf_trace(specs, rps, dur, cfg.vocab, seed=3,
                         slo_tpt_ms=50.0, max_prompt=32, max_out=32)

    def run_arm(kv_pages, footprint, preempt):
        srv = make_server(cfg, "paged", max_batch, cache_slots,
                          page_size=ps, total_pages=kv_pages + ad_pages,
                          admit_footprint=footprint, preempt=preempt)
        reqs = trace()
        summ = srv.run(reqs)
        toks = {st.req.rid: list(st.generated) for st in srv.states}
        assert all(len(v) == r.max_new_tokens
                   for v, r in zip(toks.values(), reqs))
        dec = sum(len(v) - 1 for v in toks.values())
        return {"sim_tps": dec * 1e3 / srv.clock,
                "makespan_ms": srv.clock,
                "slo_attainment": summ["slo_attainment"],
                "peak_rows": srv.admission.peak_active_rows,
                "preempt": oversub_stats(srv),
                "itl": itl_stats(srv)}, toks

    out = {"config": {"rps": rps, "duration_s": dur, "max_batch": max_batch,
                      "nominal_kv_pages": nominal, "ad_pages": ad_pages}}
    ref_toks = None
    for f in factors:
        kv = max(2, round(nominal / f))
        fr = {"kv_pages": kv, "factor_actual": nominal / kv}
        for arm, (footprint, preempt) in {
                "full": ("full", "recompute"),
                "swap": ("prompt", "swap"),
                "recompute": ("prompt", "recompute")}.items():
            r, toks = run_arm(kv, footprint, preempt)
            if ref_toks is None:
                ref_toks = toks
            # the parity gate: over-subscription (deferral, preemption,
            # swap-in, re-prefill) never changes a single emitted token
            assert toks == ref_toks, \
                f"token stream diverged: factor={f} arm={arm}"
            fr[arm] = r
            emit(f"paged/sustained_f{f}_{arm}", r["sim_tps"],
                 f"tok_s={r['sim_tps']:.1f};slo={r['slo_attainment']:.3f};"
                 f"preempt={r['preempt']['preemptions']};"
                 f"grown={r['preempt']['grown_pages']};"
                 f"oversub={r['preempt']['peak_oversub']:.2f}")
        best = max(fr["swap"]["sim_tps"], fr["recompute"]["sim_tps"])
        fr["oversub_over_full_tps"] = best / fr["full"]["sim_tps"]
        if abs(f - 1.25) < 1e-9:
            # acceptance gate: converting peak batch to sustained
            # occupancy must raise throughput at equal HBM
            assert best > fr["full"]["sim_tps"], \
                (f, best, fr["full"]["sim_tps"],
                 "over-subscription lost to the admit-full baseline")
        out[f"f{f}"] = fr
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one page size + parity gate for CI cluster-smoke")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
