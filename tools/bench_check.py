"""Benchmark regression gate: compare freshly emitted BENCH_*.json headline
metrics against committed baselines with per-check tolerance bands.

Baselines live in benchmarks/baselines/*.json; each names the bench file it
gates and a list of checks::

    {
      "bench": "BENCH_chunked.json",
      "checks": [
        {"path": "arms.chunk128.itl.itl_p99_ms", "ref": 42.05,
         "tol_frac": 0.10, "higher_is_better": false,
         "note": "virtual clock: deterministic"}
      ]
    }

A check passes when the current value stays inside the tolerance band on
the *bad* side only — improvements never fail the gate::

    higher_is_better: value >= ref * (1 - tol_frac)
    lower_is_better:  value <= ref * (1 + tol_frac)

Virtual-clock metrics (simulated tokens/s, ITL percentiles — everything the
timing plane produces) are deterministic, so their bands can be tight.
Wall-clock metrics vary with the host; give them wide bands or gate on a
deterministic proxy instead.

Usage (CI cluster-smoke runs this after the --smoke benches)::

    python tools/bench_check.py                     # all committed baselines
    python tools/bench_check.py benchmarks/baselines/bench_chunked.smoke.json
    python tools/bench_check.py --update            # refresh refs in place

``--update`` rewrites each baseline's refs from the current bench output
(review the diff before committing — that *is* the regression sign-off).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")


def resolve(doc, path: str):
    """Walk a dotted path through nested dicts (list indices allowed)."""
    cur = doc
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        elif isinstance(cur, dict):
            if part not in cur:
                raise KeyError(path)
            cur = cur[part]
        else:
            raise KeyError(path)
    return cur


def check_one(baseline_path: str, bench_dir: str, update: bool):
    """Run every check in one baseline file. Returns (n_fail, lines)."""
    with open(baseline_path) as f:
        base = json.load(f)
    bench_path = os.path.join(bench_dir, base["bench"])
    if not os.path.exists(bench_path):
        return 1, [f"MISSING  {base['bench']} (run the bench first) "
                   f"[{os.path.basename(baseline_path)}]"]
    with open(bench_path) as f:
        bench = json.load(f)

    fails, lines = 0, []
    for chk in base["checks"]:
        path, ref = chk["path"], float(chk["ref"])
        tol, hib = float(chk["tol_frac"]), bool(chk["higher_is_better"])
        try:
            val = float(resolve(bench, path))
        except (KeyError, IndexError, TypeError, ValueError):
            fails += 1
            lines.append(f"FAIL     {path}: not found in {base['bench']}")
            continue
        if update:
            chk["ref"] = val
        bound = ref * (1.0 - tol) if hib else ref * (1.0 + tol)
        ok = val >= bound if hib else val <= bound
        arrow = ">=" if hib else "<="
        status = "ok" if ok else "FAIL"
        if not ok and not update:
            fails += 1
        lines.append(f"{status:8s} {path}: {val:.4g} {arrow} {bound:.4g}"
                     f" (ref {ref:.4g}, tol {tol:.0%})")
    if update:
        with open(baseline_path, "w") as f:
            json.dump(base, f, indent=2, sort_keys=True)
            f.write("\n")
        lines.append(f"updated  {baseline_path}")
        fails = 0
    return fails, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baselines", nargs="*",
                    help="baseline json files (default: "
                         "benchmarks/baselines/*.json)")
    ap.add_argument("--bench-dir", default=".",
                    help="directory holding the BENCH_*.json outputs")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline refs from current bench output")
    args = ap.parse_args(argv)

    paths = args.baselines or sorted(glob.glob(
        os.path.join(BASELINE_DIR, "*.json")))
    if not paths:
        print("no baselines found", file=sys.stderr)
        return 2
    total = 0
    for p in paths:
        n, lines = check_one(p, args.bench_dir, args.update)
        total += n
        print(f"== {os.path.basename(p)}")
        for ln in lines:
            print(f"   {ln}")
    if total:
        print(f"bench_check: {total} regression(s)", file=sys.stderr)
        return 1
    print("bench_check: all gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
