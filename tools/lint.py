#!/usr/bin/env python
"""CLI for the JAX-aware lint (`repro.analysis.lint`).

Usage:
    python tools/lint.py [--json [FILE]] [--strict-waivers] [PATH ...]

Analyzes the whole `src/repro` package (reachability is cross-module) and
reports findings for files under the given paths (default: `src/`).
Exits 1 if any un-waived finding remains. Waive a finding with
``# lint: allow-<rule>  # reason`` on the finding line or the line above.

``--json``           emit the full report (findings, waived, unused
                     waivers) as JSON to stdout, or to FILE when given —
                     the structured artifact CI uploads.
``--strict-waivers`` additionally fail (exit 1) on waiver comments that
                     matched no finding in this run.
"""
from __future__ import annotations

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.analysis.lint import run_lint_report  # noqa: E402


def main(argv):
    args = list(argv)
    json_out = None
    emit_json = False
    strict_waivers = False
    if "--strict-waivers" in args:
        strict_waivers = True
        args.remove("--strict-waivers")
    if "--json" in args:
        emit_json = True
        i = args.index("--json")
        args.pop(i)
        if i < len(args) and not args[i].startswith("-") \
                and not os.path.exists(args[i]):
            json_out = args.pop(i)
    targets = [os.path.abspath(p) for p in args] or [SRC]
    report = run_lint_report(SRC, targets)
    findings, waived, unused = (report.findings, report.waived,
                                report.unused_waivers)

    if emit_json:
        payload = report.to_dict()
        payload["exit"] = 1 if (findings or
                                (strict_waivers and unused)) else 0
        text = json.dumps(payload, indent=2, sort_keys=True)
        if json_out:
            with open(json_out, "w") as f:
                f.write(text + "\n")
            print(f"wrote {json_out}", file=sys.stderr)
        else:
            print(text)
    else:
        for f in findings:
            print(f.render())
        if strict_waivers:
            for f in unused:
                print(f.render())

    fail = bool(findings)
    n_rules = {}
    for f in findings:
        n_rules[f.rule] = n_rules.get(f.rule, 0) + 1
    if findings:
        per = ", ".join(f"{r}={n}" for r, n in sorted(n_rules.items()))
        print(f"\n{len(findings)} finding(s) ({per}), "
              f"{len(waived)} waived", file=sys.stderr)
    else:
        print(f"lint clean ({len(waived)} waived finding(s))",
              file=sys.stderr)
    if strict_waivers and unused:
        print(f"{len(unused)} unused waiver(s)", file=sys.stderr)
        fail = True
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
