#!/usr/bin/env python
"""CLI for the JAX-aware lint (`repro.analysis.lint`).

Usage:
    python tools/lint.py [PATH ...]

Analyzes the whole `src/repro` package (reachability is cross-module) and
reports findings for files under the given paths (default: `src/`).
Exits 1 if any un-waived finding remains. Waive a finding with
``# lint: allow-<rule>  # reason`` on the finding line or the line above.
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.analysis.lint import run_lint  # noqa: E402


def main(argv):
    targets = [os.path.abspath(p) for p in argv] or [SRC]
    findings, waived = run_lint(SRC, targets)
    for f in findings:
        print(f.render())
    n_rules = {}
    for f in findings:
        n_rules[f.rule] = n_rules.get(f.rule, 0) + 1
    if findings:
        per = ", ".join(f"{r}={n}" for r, n in sorted(n_rules.items()))
        print(f"\n{len(findings)} finding(s) ({per}), "
              f"{len(waived)} waived", file=sys.stderr)
        return 1
    print(f"lint clean ({len(waived)} waived finding(s))", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
