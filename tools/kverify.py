#!/usr/bin/env python
"""Standalone Pallas kernel verifier CLI (`repro.analysis.kernel_verify`).

Usage:
    python tools/kverify.py [--json FILE] [--budget BYTES] [ARCH ...]

Extracts the symbolic model of every Pallas kernel at each config's
shapes (default: every arch in `repro.configs`), runs the five static
checks (race, bounds, scratch, dtype, vmem), and prints the per-kernel
VMEM footprint table — per-grid-step bytes under double buffering
(2 x (in + out) blocks + scratch) against the per-core budget.

Exit 1 if any check fails or any footprint exceeds the budget.
``--json FILE`` writes the machine-readable report (the footprint table
plus findings) for CI artifacts.
"""
from __future__ import annotations

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.analysis import kernel_model, kernel_verify  # noqa: E402
from repro.configs.base import all_arch_ids, get_config  # noqa: E402


def _fmt_bytes(n: int) -> str:
    if n >= 1 << 20:
        return f"{n / (1 << 20):.2f} MiB"
    return f"{n / (1 << 10):.1f} KiB"


def main(argv):
    args = list(argv)
    json_out = None
    budget = kernel_verify.VMEM_BUDGET_BYTES
    if "--json" in args:
        i = args.index("--json")
        args.pop(i)
        json_out = args.pop(i)
    if "--budget" in args:
        i = args.index("--budget")
        args.pop(i)
        budget = int(args.pop(i))
    archs = args or list(all_arch_ids())

    rows = []
    findings = []
    for arch in archs:
        case = kernel_model.case_from_config(get_config(arch))
        models = kernel_model.build_models(case)
        for m in models:
            fp = m.vmem_footprint()
            over = fp["total_bytes"] > budget
            rows.append({"arch": arch, "kernel": m.name,
                         "grid": list(m.grid), **fp, "over_budget": over})
        for f in kernel_verify.verify_models(models, budget):
            findings.append({"arch": arch, "rule": f.rule, "path": f.path,
                             "line": f.line, "kernel": f.kernel,
                             "message": f.message})

    w = max(len(r["arch"]) for r in rows) + 2
    print(f"{'arch':<{w}}{'kernel':<18}{'in':>12}{'out':>12}"
          f"{'scratch':>12}{'total':>12}  budget({_fmt_bytes(budget)})")
    for r in rows:
        flag = "OVER" if r["over_budget"] else "ok"
        print(f"{r['arch']:<{w}}{r['kernel']:<18}"
              f"{_fmt_bytes(r['in_bytes']):>12}"
              f"{_fmt_bytes(r['out_bytes']):>12}"
              f"{_fmt_bytes(r['scratch_bytes']):>12}"
              f"{_fmt_bytes(r['total_bytes']):>12}  {flag}")

    for f in findings:
        print(f"{f['path']}:{f['line']}: [{f['rule']}] ({f['arch']}) "
              f"{f['kernel']}: {f['message']}")

    n_over = sum(r["over_budget"] for r in rows)
    fail = bool(findings) or n_over > 0
    print(f"\n{len(rows)} kernel/config case(s), {len(findings)} "
          f"finding(s), {n_over} over budget", file=sys.stderr)

    if json_out:
        with open(json_out, "w") as fh:
            json.dump({"budget_bytes": budget, "vmem": rows,
                       "findings": findings, "exit": 1 if fail else 0},
                      fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {json_out}", file=sys.stderr)
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
