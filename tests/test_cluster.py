"""Cluster simulation + trace generators + end-to-end scheduler behaviour."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.traces import gen

CFG = get_config("llama2-7b")


def build(policy, adapters, perf, slo, n_servers=4, mode="caraserve"):
    servers = []
    for _ in range(n_servers):
        s = InferenceServer(CFG, mode=mode, kernel="bgmv", max_batch=8,
                            numerics=False)
        for ad in adapters:
            s.register_adapter(ad)
        servers.append(s)
    sched = make_scheduler(policy, perf, slo_ms=slo) \
        if policy == "rank_aware" else make_scheduler(policy)
    return Cluster(servers, sched)


def test_all_requests_complete_exactly_once():
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(16, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    reqs = gen.maf_trace(adapters, rps=30, duration_s=5, vocab=100, seed=1)
    cl = build("rank_aware", adapters, perf, slo=None)
    out, states = cl.run(reqs)
    assert out["n"] == len(reqs)
    assert sorted(s.req.rid for s in states) == sorted(r.rid for r in reqs)
    for s in states:
        assert len(s.generated) == s.req.max_new_tokens
        assert s.finish_ms >= s.req.arrival_ms


def test_rank_aware_beats_naive_under_contention():
    """Heterogeneous ranks + contention: Algo 1 must beat FIRSTFIT on SLO
    attainment (paper Fig 19/20)."""
    rng = np.random.default_rng(2)
    adapters = gen.make_adapters(32, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    slo = 1.5 * perf.dec_perf([64] * 8)
    # ~80% of aggregate decode capacity: contended but not overloaded,
    # which is where scheduling decisions matter (paper sec 7.5)
    reqs = gen.maf_trace(adapters, rps=25, duration_s=10, vocab=100, seed=3,
                         slo_tpt_ms=slo)
    res = {}
    for policy in ("rank_aware", "first_fit", "random"):
        out, _ = build(policy, adapters, perf, slo).run(reqs)
        res[policy] = out
    assert res["rank_aware"]["slo_attainment"] >= \
        res["first_fit"]["slo_attainment"]
    assert res["rank_aware"]["slo_attainment"] >= \
        res["random"]["slo_attainment"] - 0.02


def test_trace_generators():
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(10, CFG.name, rng, uniform_rank=64)
    assert all(a.rank == 64 for a in adapters)
    reqs = gen.synthetic_trace(adapters, rps=50, duration_s=4, vocab=32000,
                               seed=0)
    assert len(reqs) > 100
    ts = [r.arrival_ms for r in reqs]
    assert ts == sorted(ts) and ts[-1] <= 4000
    # distinct cycling: consecutive requests hit different adapters
    assert all(reqs[i].adapter_uid != reqs[i + 1].adapter_uid
               for i in range(9))
    # maf trace is popularity-skewed
    m = gen.maf_trace(adapters, rps=100, duration_s=10, vocab=100, seed=1)
    counts = {}
    for r in m:
        counts[r.adapter_uid] = counts.get(r.adapter_uid, 0) + 1
    top = max(counts.values()) / len(m)
    assert top > 2.0 / len(adapters)       # far above uniform share


def test_zipf_popularity_shape():
    p = gen.zipf_popularity(100)
    assert p[0] > p[10] > p[50]
    assert abs(p.sum() - 1.0) < 1e-9
