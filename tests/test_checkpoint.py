"""Checkpoint roundtrip, retention, corruption detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = tree()
    p = str(tmp_path / "ck" / "ckpt_1.npz")
    checkpoint.save(p, t, step=1, extra={"note": "x"})
    loaded, man = checkpoint.load(p, t)
    assert man["step"] == 1 and man["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_shape_mismatch_rejected(tmp_path):
    p = str(tmp_path / "ckpt_1.npz")
    checkpoint.save(p, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        checkpoint.load(p, {"a": jnp.ones((3,))})


def test_retention_and_latest(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30, 40):
        checkpoint.save(checkpoint.step_path(d, s), {"a": jnp.ones(1)},
                        step=s)
    assert checkpoint.latest_step(d) == 40
    checkpoint.retain(d, keep=2)
    left = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert left == ["ckpt_30.npz", "ckpt_40.npz"]
    assert checkpoint.latest_step(str(tmp_path / "nope")) is None
