"""Rank-aware scheduling (Algo 1) + performance models (paper sec 5)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.perf_model import (LinearPerfModel, ServerPerfModel,
                                   batch_feature, profile_and_fit)
from repro.core.scheduler import (FirstFitScheduler, MostIdleScheduler,
                                  RandomScheduler, RankAwareScheduler,
                                  ServerStats, calc_cost)

CFG = get_config("llama2-7b")


def test_perf_model_fit_r2():
    """Linear fits reach the paper's R^2 ~= 0.96 (Fig 9)."""
    for kernel in ("bgmv", "mbgmv"):
        m, _ = profile_and_fit(CFG, kernel, noise=0.02, seed=0)
        assert m.r2 > 0.9, (kernel, m.r2)
        assert m.alpha > 0


def test_kernel_laws_differ():
    """BGMV: max-rank law; MBGMV: sum-rank law (paper Fig 4)."""
    bg, _ = profile_and_fit(CFG, "bgmv", noise=0.0)
    mb, _ = profile_and_fit(CFG, "mbgmv", noise=0.0)
    hetero = [8] * 15 + [64]       # one high-rank straggler
    homo = [64] * 16
    # padding penalizes the heterogeneous batch under BGMV only; compare the
    # kernel term (alpha*feature), the intercept is the base-model decode
    assert bg.predict(hetero) == pytest.approx(bg.predict(homo), rel=0.02)
    kern = lambda m, s: m.predict(s) - m.beta
    assert kern(mb, hetero) < 0.5 * kern(mb, homo)
    assert kern(bg, hetero) == pytest.approx(kern(bg, homo), rel=0.02)


def test_batch_feature():
    assert batch_feature([8, 64], "bgmv") == 2 * 64
    assert batch_feature([8, 64], "mbgmv") == 72
    assert batch_feature([], "bgmv") == 0.0


def stats(running, queued=(), hosts=True, free=4):
    return ServerStats(list(running), list(queued), hosts, free,
                       len(running) + len(queued))


@pytest.fixture(scope="module")
def perf():
    return ServerPerfModel(CFG, kernel="bgmv")


def test_algo1_prefers_idle(perf):
    s = RankAwareScheduler(perf, slo_ms=None)
    assert s.route(64, [stats([64] * 8), stats([])]) == 1


def test_algo1_slo_penalty_steers_away(perf):
    """Paper Fig 5: with BGMV, a rank-64 request must go to the instance
    already running high ranks, not the low-rank one it would poison."""
    slo = perf.dec_perf([32] * 25) * 1.02   # tight: adding r64 to inst-1 breaks
    s = RankAwareScheduler(perf, slo_ms=slo)
    inst1 = stats([32] * 24)               # 24 x rank-32
    inst2 = stats([64] * 16)               # 16 x rank-64
    choice = s.route(64, [inst1, inst2])
    assert choice == 1
    # sanity: without the SLO, the idler instance (fewer reqs) would win
    s2 = RankAwareScheduler(perf, slo_ms=None)
    assert s2.route(64, [inst1, inst2]) == 1  # still fewer requests on 2


def test_route_requires_hosting(perf):
    s = RankAwareScheduler(perf)
    with pytest.raises(LookupError):
        s.route(8, [stats([], hosts=False)])


@settings(max_examples=30, deadline=None)
@given(ranks=st.lists(st.sampled_from([8, 16, 32, 64]), min_size=1,
                      max_size=12),
       req=st.sampled_from([8, 16, 32, 64]))
def test_property_cost_nonnegative_and_rank_affinity(perf, ranks, req):
    c = calc_cost(req, stats(ranks), perf, None, 64.0)
    assert c >= -1e-6
    # the paper's Fig 5 insight, as a property: under the BGMV max-rank law,
    # a request lands strictly cheaper on a same-size batch that already
    # contains its rank (padding paid) than on a lower-rank batch it would
    # poison (every member newly pays the padding to `req`)
    high = [req] + [min(r, req) for r in ranks[1:]]   # max == req
    low = [min(r, max(req // 2, 1)) for r in ranks]   # max < req
    c_high = calc_cost(req, stats(high), perf, None, 64.0)
    c_low = calc_cost(req, stats(low), perf, None, 64.0)
    assert c_high <= c_low + 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_baselines_route_to_hosting(seed):
    rng = np.random.default_rng(seed)
    ss = [stats([8] * int(rng.integers(0, 5)),
                hosts=bool(rng.integers(0, 2))) for _ in range(6)]
    if not any(s.hosts_adapter for s in ss):
        ss[0] = stats([], hosts=True)
    for sched in (MostIdleScheduler(), FirstFitScheduler(),
                  RandomScheduler(seed)):
        i = sched.route(16, ss)
        assert ss[i].hosts_adapter
