"""Failure plane (core/faults.py): scripted fault schedules, upload retry
with backoff, brownout link slowdown, crash drain + failover re-admission,
warm restart, SLO shedding, the CPU-assist decode fault shield — and the
determinism gate: two same-seed chaos runs must agree on every event,
every token, and every summary number."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.cold_start import LoadTracker
from repro.core.engine import InferenceServer
from repro.core.faults import (FaultEvent, FaultPlane, chaos_schedule)
from repro.core.lora import AdapterSpec
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.core.timing import TimingModel
from repro.serving.request import Request
from repro.traces import gen

CFG = get_config("llama2-7b")


def mk_req(rid, uid, t, tokens=32, out=4, slo=None):
    return Request(rid=rid, adapter_uid=uid,
                   prompt=np.zeros(tokens, np.int32), max_new_tokens=out,
                   arrival_ms=t, slo_tpt_ms=slo)


def mk_server(mode="caraserve", max_batch=4, n_adapters=4, rank=16, **kw):
    srv = InferenceServer(CFG, mode=mode, max_batch=max_batch,
                          numerics=False, **kw)
    for i in range(n_adapters):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank, CFG.name))
    return srv


# ------------------------------------------------------- fault schedule ----

def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0.0, "meteor", 0)
    with pytest.raises(ValueError, match="window must end"):
        FaultEvent(10.0, "brownout", 0, until_ms=5.0, slowdown=2.0)
    with pytest.raises(ValueError, match="fail_prob"):
        FaultEvent(0.0, "upload_flaky", 0, until_ms=1.0, fail_prob=1.5)
    with pytest.raises(ValueError, match="slowdown"):
        FaultEvent(0.0, "brownout", 0, until_ms=1.0, slowdown=0.5)


def test_chaos_schedule_deterministic_and_spares_server_zero():
    a = chaos_schedule(4, 10_000.0, seed=3, n_crashes=2)
    b = chaos_schedule(4, 10_000.0, seed=3, n_crashes=2)
    assert a == b
    for seed in range(8):
        evs = chaos_schedule(4, 10_000.0, seed=seed)
        crashes = [e for e in evs if e.kind == "crash"]
        assert crashes and all(e.server != 0 for e in crashes)
        assert any(e.kind == "brownout" for e in evs)
        assert sum(e.kind == "upload_flaky" for e in evs) == 4


# ------------------------------------------------------- upload retries ----

def test_backoff_deterministic_and_exponential():
    tr = LoadTracker(TimingModel(CFG), policy="fifo")
    tr.retry_seed = 42

    class E:
        uid = "u"
        attempt = 0
    backs = []
    for a in range(4):
        E.attempt = a
        backs.append(tr._backoff_ms(E))
    E.attempt = 0
    assert tr._backoff_ms(E) == backs[0]
    # jitter is bounded by retry_jitter, so doubling always dominates it
    for i in range(3):
        assert backs[i + 1] > backs[i]
    assert backs[3] >= tr.retry_base_ms * 8


def test_retry_budget_makes_final_attempt_infallible():
    """Even a 100%-failing link cannot lose a demand upload: the hook is
    only consulted while retry budget remains, so the run terminates with
    the adapter delivered after exactly `retry_budget` failures."""
    tr = LoadTracker(TimingModel(CFG), policy="fifo")
    tr.begin("u", 0, 1 << 20, 0.0, demand=True)
    tr.fail_hook = lambda e: True
    done = tr.complete_until(1e9)
    assert [e.uid for e in done] == ["u"]
    assert done[0].attempt == tr.retry_budget
    assert tr.stats["upload_failures"] == tr.retry_budget
    assert tr.stats["retries"] == tr.retry_budget


def test_failed_prefetch_drops_and_releases_slot():
    """Speculative uploads get no retry budget: a failed prefetch is
    dropped outright and the manager releases its reserved pool slot."""
    srv = mk_server()
    srv.cold.tracker.fail_hook = lambda e: True
    assert srv.cold.load_async("ad0", 0.0, demand=False) is not None
    assert srv.pool.lookup("ad0") is not None     # slot reserved
    srv.cold.poll(1e9)
    assert srv.cold.tracker.stats["prefetch_dropped"] == 1
    assert srv.cold.tracker.stats["retries"] == 0
    assert srv.pool.lookup("ad0") is None         # slot given back


# ------------------------------------------------------------- brownout ----

def test_brownout_scales_transfers_starting_inside_window():
    tm = TimingModel(CFG)
    tr = LoadTracker(tm, policy="fifo")
    tr.brownouts = [(100.0, 200.0, 3.0)]
    nbytes = 1 << 22
    base = tm.load_ms(nbytes)
    assert tr._xfer_ms(nbytes, 50.0) == pytest.approx(base)
    assert tr._xfer_ms(nbytes, 100.0) == pytest.approx(3.0 * base)
    assert tr._xfer_ms(nbytes, 199.9) == pytest.approx(3.0 * base)
    assert tr._xfer_ms(nbytes, 200.0) == pytest.approx(base)  # half-open
    assert tr.slowdown_at(150.0) == 3.0
    assert tr.slowdown_at(999.0) == 1.0


def test_cancel_all_empties_the_link():
    tr = LoadTracker(TimingModel(CFG), policy="fifo")
    tr.begin("a", 0, 1 << 20, 0.0, demand=True)
    tr.begin("b", 1, 1 << 20, 0.0, demand=True)   # queues behind a
    out = tr.cancel_all()
    assert len(out) == 2 and all(e.canceled for e in out)
    assert tr.stats["crash_canceled"] == 2
    assert tr.next_finish_ms() is None
    assert tr.complete_until(1e9) == []


# --------------------------------------------------------- CPU timing ----

def test_cpu_lora_decode_ms_max_rank_law():
    tm = TimingModel(CFG)
    assert tm.cpu_lora_decode_ms([]) == 0.0
    a = tm.cpu_lora_decode_ms([8])
    b = tm.cpu_lora_decode_ms([64])
    assert b > a > 0.0
    # rows run on distinct cores in parallel: max-rank, not sum-rank
    assert tm.cpu_lora_decode_ms([64, 8, 8]) == pytest.approx(b)


# ------------------------------------------------------- assist shield ----

def test_assist_shield_decodes_through_upload_retry():
    """A demand upload whose first attempt fails leaves its rows waiting
    on the retry; in caraserve mode they keep decoding on the CPU-assist
    path instead (fault shield) and flip to device when the retry
    lands."""
    srv = mk_server(mode="caraserve", max_batch=2, rank=64)
    srv.cold.tracker.fail_hook = lambda e: e.attempt == 0
    # a long backoff keeps the retry pending across many decode steps —
    # exactly the window the shield exists for
    srv.cold.tracker.retry_base_ms = 60.0
    out = srv.run([mk_req(0, "ad0", 0.0, out=12)])
    assert out["n"] == 1
    assert srv.cold.tracker.stats["retries"] == 1
    assert srv.fault_stats["assist_shield_rows"] == 1
    assert srv.fault_stats["assist_shield_tokens"] > 0
    (st,) = srv.states
    assert len(st.generated) == 12
    assert not st.assist_decode           # cleared once the retry landed
    assert st.flip_ms is not None


# --------------------------------------------------------- engine crash ----

def test_engine_crash_drains_everything_and_clears_device():
    srv = mk_server(mode="cached", max_batch=2)
    reqs = [mk_req(i, f"ad{i}", 0.0, out=64) for i in range(4)]
    for r in reqs:
        srv.submit(r)
    for _ in range(12):                  # get rows decoding mid-stream
        srv.step()
    assert any(st is not None for st in srv.rows)
    drained = srv.crash(srv.clock)
    assert len(drained) == 4
    assert not srv.busy() and srv.states == []
    assert srv.cold.tracker.next_finish_ms() is None
    for s in range(srv.pool.n_slots):
        assert srv.pool.slot_uid[s] is None
    for st in drained:
        assert st.phase == "queued" and st.row == -1
        if st.issued > 0:                # mid-decode: replay plan attached
            assert st.preempted and st.resume_kind == "recompute"
            assert st.resume_pos > 0
    assert srv.fault_stats["crashes"] == 1
    assert srv.fault_stats["drained_requests"] == 4


# ------------------------------------------------------ cluster health ----

def _mk_cluster(n=2, faults=None, shed="none", **kw):
    servers = []
    for _ in range(n):
        servers.append(mk_server(mode="caraserve", max_batch=4))
    return Cluster(servers, make_scheduler("most_idle"),
                   faults=faults, shed_policy=shed, **kw)


def test_set_down_busy_server_raises_without_drain_time():
    cl = _mk_cluster()
    cl.servers[0].submit(mk_req(0, "ad0", 0.0))
    with pytest.raises(RuntimeError, match="strand"):
        cl.set_down(0)
    assert 0 not in cl.down               # refused, not half-applied


def test_set_down_with_time_drains_and_fails_over():
    cl = _mk_cluster()
    cl.servers[0].submit(mk_req(0, "ad0", 0.0, out=6))
    cl.set_down(0, now_ms=5.0)
    assert 0 in cl.down
    assert cl.fault_stats["failovers"] == 1
    assert cl.servers[0].states == []
    s1 = cl.servers[1]
    assert len(s1.states) == 1
    while s1.busy():
        s1.step()
    (st,) = s1.states
    assert len(st.generated) == 6 and st.recovered == 1


def test_idle_set_down_still_plain():
    cl = _mk_cluster()
    cl.set_down(1)
    assert 1 in cl.down
    cl.set_up(1)
    assert 1 not in cl.down


def test_lockstep_engine_rejects_faults():
    faults = FaultPlane(chaos_schedule(2, 1000.0))
    with pytest.raises(ValueError, match="lockstep"):
        _mk_cluster(faults=faults, engine="lockstep")
    with pytest.raises(ValueError, match="shed_policy"):
        _mk_cluster(shed="chaotic-good")


# ------------------------------------------------------------- shedding ----

def test_admission_sheds_provably_late_requests():
    srv = mk_server(mode="cached", shed_late_slo=1.0)
    srv.submit(mk_req(0, "ad0", 0.0, out=4, slo=1.0))   # budget: 4 ms
    srv.clock = 500.0                     # arrives hopelessly late
    srv.step()
    (st,) = srv.states
    assert st.shed and st.phase == "shed"
    assert srv.admission.shed_count == 1
    assert not srv.busy()


def test_admission_never_sheds_recovered_or_preempted():
    srv = mk_server(mode="cached", shed_late_slo=1.0)
    srv.submit(mk_req(0, "ad0", 0.0, out=4, slo=1.0))
    (st,) = srv.states
    st.recovered = 1                      # crash failover must always land
    srv.clock = 500.0
    srv.step()
    assert not st.shed and srv.admission.shed_count == 0


def test_cluster_sheds_when_every_server_is_saturated():
    """shed_policy="slo": a burst beyond aggregate decode-SLO capacity is
    partially shed at the router — and n + shed still covers every
    submission (zero lost)."""
    ads = [AdapterSpec(f"ad{i}", 64, CFG.name) for i in range(2)]
    perf = ServerPerfModel(CFG, kernel="bgmv")
    slo = perf.dec_perf([64] * 2)         # breaks at ~2 concurrent rows
    servers = []
    for _ in range(2):
        s = InferenceServer(CFG, mode="caraserve", max_batch=8,
                            numerics=False)
        for ad in ads:
            s.register_adapter(ad)
        servers.append(s)
    cl = Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=slo),
                 shed_policy="slo")
    reqs = [mk_req(i, ads[i % 2].uid, 0.0, out=32, slo=slo)
            for i in range(12)]
    out, states = cl.run(reqs)
    assert out["shed"] > 0
    assert out["n"] + out["shed"] == len(reqs)
    assert sorted(s.req.rid for s in states) == list(range(12))
    assert cl.fault_stats["shed"] == out["shed"]


# -------------------------------------------------- chaos determinism ----

def _chaos_run(seed):
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(8, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    slo = 1.5 * perf.dec_perf([64] * 8)
    reqs = gen.maf_trace(adapters, rps=30, duration_s=3, vocab=100,
                         seed=2, slo_tpt_ms=slo)
    faults = FaultPlane(chaos_schedule(3, reqs[-1].arrival_ms, seed=seed),
                        seed=seed)
    servers = []
    for _ in range(3):
        s = InferenceServer(CFG, mode="caraserve", kernel="bgmv",
                            max_batch=8, numerics=False,
                            link_policy="priority")
        for ad in adapters:
            s.register_adapter(ad)
        servers.append(s)
    cl = Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=slo),
                 faults=faults, shed_policy="slo")
    out, states = cl.run(reqs)
    tokens = {s.req.rid: tuple(s.generated) for s in states}
    return faults.log, out, tokens, cl.fault_stats, len(reqs)


def test_chaos_runs_are_deterministic_and_lose_nothing():
    log1, out1, tok1, fs1, n = _chaos_run(11)
    log2, out2, tok2, fs2, _ = _chaos_run(11)
    assert log1 and log1 == log2          # identical fault timelines
    assert out1 == out2                   # identical summary numbers
    assert tok1 == tok2                   # identical per-request tokens
    assert fs1 == fs2
    assert fs1["crashes"] == 1 and fs1["restarts"] == 1
    assert out1["n"] + out1["shed"] == n  # zero lost under chaos


# ---------------------------------------------- crash recovery parity ----

def test_crash_recovery_token_parity_numerics():
    """Numerics gate: requests drained off a crashed server and re-admitted
    on the survivor finish with exactly the tokens of the unfailed run
    (recompute failover replays prompt + generated-so-far, then greedy
    decode continues identically on the identically-seeded peer)."""
    cfg = get_config("llama2-7b").smoke()
    rng = np.random.default_rng(5)
    adapters = gen.make_adapters(3, cfg.name, rng, uniform_rank=8)

    def build(faults=None):
        servers = []
        for _ in range(2):
            s = InferenceServer(cfg, mode="cached", max_batch=4,
                                numerics=True, seed=0, pipeline="fused")
            for ad in adapters:
                s.register_adapter(ad)
            servers.append(s)
        return Cluster(servers, make_scheduler("most_idle"),
                       faults=faults)

    reqs = []
    for i in range(4):
        prompt = rng.integers(0, cfg.vocab, 10 + 3 * i).astype(np.int32)
        reqs.append(Request(rid=i, adapter_uid=adapters[i % 3].uid,
                            prompt=prompt, max_new_tokens=10,
                            arrival_ms=4.0 * i))
    _, free_states = build().run(reqs)
    want = {s.req.rid: list(s.generated) for s in free_states}

    faults = FaultPlane([FaultEvent(15.0, "crash", 1),
                         FaultEvent(40.0, "restart", 1)], seed=1)
    cl = build(faults)
    out, states = cl.run(reqs)
    assert out["n"] == len(reqs)
    assert out["recovered"] > 0, "the crash drained no live requests"
    assert {s.req.rid: list(s.generated) for s in states} == want
