"""Paged memory plane: PageAllocator correctness (fragmentation,
exhaustion, KV/adapter aliasing), paged cache primitives against their
dense counterparts, and the unified-pool interplay between KV admission
and resident adapters."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec, DevicePool
from repro.kernels import ref
from repro.serving import cache as cache_lib
from repro.serving.cache import PageAllocator
from repro.serving.request import Request
from repro.models import model
from repro.models.layers import (cache_init, cache_write_token,
                                 cache_write_token_paged, paged_kv_for_attn)


# ----------------------------------------------------------- allocator ----

def test_allocator_claim_free_fragmentation():
    """Interleaved claim/free keeps ids unique, counts consistent, and
    reuses freed pages regardless of fragmentation order."""
    al = PageAllocator(10)
    a = al.claim(4, "kv:0")
    b = al.claim(3, "adapter:x")
    assert al.free_pages == 3 and al.used_pages == 7
    assert len(set(a) | set(b)) == 7          # no id handed out twice
    al.free(a[1:3])                           # punch a hole
    assert al.free_pages == 5
    c = al.claim(5, "kv:1")                   # spans the hole + the tail
    assert c is not None and al.free_pages == 0
    assert set(c).isdisjoint(set(b)) and set(c).isdisjoint({a[0], a[3]})
    assert al.claim(1, "kv:2") is None        # exhausted: no-op, no change
    assert al.free_pages == 0
    al.free(b)
    al.free([a[0], a[3]] + c)
    assert al.free_pages == 10 and al.used_pages == 0
    with pytest.raises(ValueError):
        al.free([c[0]])                       # double free is an error


def test_allocator_owner_tags():
    al = PageAllocator(6)
    kv = al.claim(2, "kv:7")
    ad = al.claim(2, "adapter:u")
    assert all(al.owner_of(p) == "kv:7" for p in kv)
    assert sorted(al.owned_by("adapter:")) == sorted(ad)
    al.free(kv)
    assert al.owner_of(kv[0]) is None


# --------------------------------------------- paged cache primitives ----

def _mk_row_cache(rng, L, B, KV, S, hd):
    k = jnp.asarray(rng.normal(size=(L, B, KV, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(L, B, KV, S, hd)), jnp.float32)
    pos = jnp.asarray(
        np.broadcast_to(np.arange(S, dtype=np.int32), (L, B, S)))
    return {"k": k, "v": v, "pos": pos}


def test_scatter_gather_pages_roundtrip():
    """scatter_pages then gather_pages reconstructs each row's dense cache
    exactly; pages of other rows are untouched."""
    rng = np.random.default_rng(0)
    L, B, KV, S, hd, ps, P = 2, 3, 2, 16, 4, 8, 9
    rows = _mk_row_cache(rng, L, B, KV, S, hd)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((L, 1) + x.shape[2:], x.dtype), rows)
    pool = cache_lib.zeros_paged(abstract, P, ps)
    npr = S // ps
    al = PageAllocator(P)
    ids = np.stack([al.claim(npr, f"kv:{b}") for b in range(B)])
    pool = cache_lib.scatter_pages(pool, rows, jnp.asarray(ids, jnp.int32))
    for b in range(B):
        got = cache_lib.gather_pages(pool, ids[b])
        for leaf in ("k", "v", "pos"):
            want = np.asarray(rows[leaf][:, b:b + 1])
            assert np.array_equal(np.asarray(got[leaf]), want), (b, leaf)
    # the unclaimed page was never written
    spare = al.claim(P - B * npr, "kv:spare")
    for pg in spare:
        assert np.all(np.asarray(pool["pos"])[:, pg] == -1)
    # a partially-valid block table gathers -1 pos beyond the claim
    short = np.array([ids[0][0], -1], np.int32)
    got = cache_lib.gather_pages(pool, short)
    assert np.all(np.asarray(got["pos"])[:, 0, ps:] == -1)


def test_paged_token_write_and_attn_match_dense():
    """A paged decode step (write + gathered attention view) is bitwise
    identical to the dense per-row cache on every written slot, with
    frozen rows (write_mask) dropping their page write."""
    rng = np.random.default_rng(1)
    B, KV, S, hd, ps = 3, 2, 16, 4, 8
    W = S // ps
    dense = cache_init(B, KV, S, hd, jnp.float32)
    al = PageAllocator(B * W + 2)
    bt = np.stack([al.claim(W, f"kv:{b}") for b in range(B)])
    bt = jnp.asarray(bt, jnp.int32)
    paged = {
        "k": jnp.zeros((al.n_pages, KV, ps, hd), jnp.float32),
        "v": jnp.zeros((al.n_pages, KV, ps, hd), jnp.float32),
        "pos": jnp.full((al.n_pages, ps), -1, jnp.int32),
    }
    mask = jnp.asarray([True, True, False])
    pos = jnp.asarray([0, ps + 3, 5], jnp.int32)   # crosses a page boundary
    for step in range(4):
        k_t = jnp.asarray(rng.normal(size=(B, 1, KV, hd)), jnp.float32)
        v_t = jnp.asarray(rng.normal(size=(B, 1, KV, hd)), jnp.float32)
        dense = cache_write_token(dense, k_t, v_t, pos, write_mask=mask)
        paged = cache_write_token_paged(paged, k_t, v_t, pos, bt,
                                        write_mask=mask)
        pos = jnp.where(mask, pos + 1, pos)
    pk, pv, ppos = paged_kv_for_attn(paged, bt)
    # row 2 frozen: its gathered view stays empty
    assert np.all(np.asarray(ppos)[2] == -1)
    dpos = np.asarray(dense["pos"])
    gpos = np.asarray(ppos)
    written = dpos >= 0
    assert np.array_equal(gpos[written], dpos[written])
    for dn, pg in ((dense["k"], pk), (dense["v"], pv)):
        dn = np.asarray(dn).transpose(0, 2, 1, 3)   # (B, S, KV, hd)
        pg = np.asarray(pg).transpose(0, 2, 1, 3)
        assert np.array_equal(dn[written], pg[written])


def test_paged_attention_ref_matches_dense_attn_decode():
    """The paged oracle on a scattered cache == dense attn_decode on the
    equivalent row cache, bitwise (the acceptance property behind paged
    decode's token-for-token parity)."""
    from repro.models.layers import attn_decode
    rng = np.random.default_rng(2)
    B, H, KV, S, hd, ps = 2, 4, 2, 16, 8, 8
    W = S // ps
    lens = [5, 11]
    dense = cache_init(B, KV, S, hd, jnp.float32)
    al = PageAllocator(B * W)
    bt = jnp.asarray(np.stack([al.claim(W, f"kv:{b}") for b in range(B)]),
                     jnp.int32)
    paged = {
        "k": jnp.asarray(rng.normal(size=(al.n_pages, KV, ps, hd)),
                         jnp.float32) * 0,
        "v": jnp.zeros((al.n_pages, KV, ps, hd), jnp.float32),
        "pos": jnp.full((al.n_pages, ps), -1, jnp.int32),
    }
    pos = jnp.asarray([0, 0], jnp.int32)
    for t in range(max(lens)):
        k_t = jnp.asarray(rng.normal(size=(B, 1, KV, hd)), jnp.float32)
        v_t = jnp.asarray(rng.normal(size=(B, 1, KV, hd)), jnp.float32)
        mask = jnp.asarray([t < n for n in lens])
        dense = cache_write_token(dense, k_t, v_t, pos, write_mask=mask)
        paged = cache_write_token_paged(paged, k_t, v_t, pos, bt,
                                        write_mask=mask)
        pos = jnp.where(mask, pos + 1, pos)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    cur = jnp.asarray([n - 1 for n in lens], jnp.int32)
    want = attn_decode(q, dense["k"], dense["v"], dense["pos"], cur)
    got = ref.paged_attention_ref(q[:, 0], paged["k"], paged["v"],
                                  paged["pos"], bt, cur)
    assert np.array_equal(np.asarray(want[:, 0]), np.asarray(got))


# ------------------------------------------------------- unified pool ----

def _small_server(total_pages, n_adapters=3, prefetch=False, **kw):
    cfg = get_config("llama2-7b").smoke()
    srv = InferenceServer(cfg, mode="caraserve", max_batch=4,
                          cache_slots=64, numerics=True, seed=0,
                          memory="paged", page_size=32,
                          total_pages=total_pages, prefetch=prefetch, **kw)
    for i in range(n_adapters):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
    return srv, cfg


def test_kv_and_adapter_pages_never_alias():
    """Every page is owned by exactly one tenant: block-table pages and
    adapter pages are disjoint at all times during a mixed run."""
    srv, cfg = _small_server(total_pages=12)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, adapter_uid=f"ad{i % 3}",
                    prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                    max_new_tokens=6, arrival_ms=float(i))
            for i in range(6)]
    seen_checks = 0
    pending = sorted(reqs, key=lambda r: r.arrival_ms)
    i = 0
    while i < len(pending) or srv.busy():
        while i < len(pending) and pending[i].arrival_ms <= srv.clock:
            srv.submit(pending[i])
            i += 1
        if not srv.busy() and i < len(pending):
            srv.clock = pending[i].arrival_ms
            continue
        srv.step(horizon_ms=pending[i].arrival_ms if i < len(pending)
                 else None)
        al = srv.allocator
        kv = set(al.owned_by("kv:"))
        ad = set(al.owned_by("adapter:"))
        assert kv.isdisjoint(ad)
        assert len(kv) + len(ad) == al.used_pages
        # the pool's own bookkeeping agrees with the allocator's
        pool_pages = [p for pages in srv.pool.slot_pages for p in pages]
        assert sorted(pool_pages) == sorted(ad)
        row_pages = [p for pages in srv.admission.row_pages for p in pages]
        assert sorted(row_pages) == sorted(kv)
        seen_checks += 1
    assert seen_checks > 5
    srv.backend.flush_readback()
    assert all(len(s.generated) == s.req.max_new_tokens for s in srv.states)
    assert srv.allocator.owned_by("kv:") == []   # all KV pages returned


def test_kv_burst_evicts_cold_adapter_pages():
    """Unified pool: when a KV-hungry admission finds the allocator short,
    it reclaims a cold resident adapter's pages instead of deferring."""
    srv, cfg = _small_server(total_pages=6)
    # park two cold adapters on device: 1 page each (smoke adapters are
    # tiny), leaving 4 pages — two 2-page requests then need a reclaim
    for uid in ("ad1", "ad2"):
        spec = srv.store.specs[uid]
        slot = srv.pool.insert(uid, srv.store.weights(uid), spec.rank,
                               nbytes=spec.nbytes(cfg))
        assert slot is not None
    assert srv.allocator.free_pages == 4
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i, adapter_uid="ad0",
                    prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                    max_new_tokens=16, arrival_ms=0.0)
            for i in range(2)]            # 2 pages KV each + ad0's page
    srv.run(reqs)
    assert all(len(s.generated) == 16 for s in srv.states)
    # the burst had to shed at least one cold resident
    assert srv.pool.lookup("ad1") is None or srv.pool.lookup("ad2") is None


def test_admission_defers_until_pages_free():
    """Temporary exhaustion defers admission (requests still complete,
    serially); the pool never over-commits."""
    srv, cfg = _small_server(total_pages=3)   # one 2-page request + adapter
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, adapter_uid="ad0",
                    prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                    max_new_tokens=8, arrival_ms=0.0)
            for i in range(3)]
    srv.run(reqs)
    assert all(len(s.generated) == 8 for s in srv.states)
    assert srv.admission.peak_active_rows == 1   # pages forced serial


def test_submit_errors_page_flavored():
    """Impossible demands fail loudly at submit time: a prompt overflowing
    the per-row block table, or a request larger than the whole pool."""
    srv, cfg = _small_server(total_pages=3)
    long_prompt = np.zeros(100, np.int32)       # > cache_slots=64
    with pytest.raises(ValueError, match="block table"):
        srv.submit(Request(rid=0, adapter_uid="ad0", prompt=long_prompt,
                           max_new_tokens=4))
    # a request's demand is capped by the ring depth (2 pages here), so
    # only a pool smaller than one row's block table can never satisfy it
    tiny, _ = _small_server(total_pages=1)
    big = Request(rid=1, adapter_uid="ad0",
                  prompt=np.zeros(33, np.int32), max_new_tokens=64)
    with pytest.raises(ValueError, match="page pool"):
        tiny.submit(big)                         # needs 2 > 1 total pages


def test_paged_prefill_clears_reclaimed_pages():
    """Pages reclaimed from a retired request carry stale positions; a new
    tenant's prefill must invalidate every claimed page before decode
    attends. Back-to-back waves reusing the same pool would diverge from
    the dense oracle otherwise (covered by equality with a fresh server)."""
    srv, cfg = _small_server(total_pages=8)
    rng = np.random.default_rng(6)

    def wave(srv, t0, rid0):
        return [Request(rid=rid0 + i, adapter_uid=f"ad{i % 3}",
                        prompt=rng.integers(0, cfg.vocab,
                                            10 + i).astype(np.int32),
                        max_new_tokens=30, arrival_ms=t0)
                for i in range(2)]
    w1, w2 = wave(srv, 0.0, 0), wave(srv, 1e6, 10)
    srv.run(w1)
    srv.run(w2)                      # reuses the retired wave's pages
    fresh, _ = _small_server(total_pages=8)
    fresh.run([Request(r.rid, r.adapter_uid, r.prompt, r.max_new_tokens,
                       arrival_ms=0.0) for r in w2])
    got = {s.req.rid: s.generated for s in srv.states}
    want = {s.req.rid: s.generated for s in fresh.states}
    for rid in want:
        assert got[rid] == want[rid], rid


def test_device_pool_page_accounting():
    """reserve/evict/release move adapter pages through the allocator;
    a failed reservation leaves the chosen victim resident."""
    cfg = get_config("llama2-7b").smoke()
    al = PageAllocator(2)
    pool = DevicePool(cfg, n_slots=2, materialize=False, allocator=al,
                      page_bytes=10**9)          # 1 page per adapter
    s0 = pool.reserve("a", None, 8, nbytes=1)
    pool.commit(s0)
    s1 = pool.reserve("b", None, 8, nbytes=1)
    assert al.free_pages == 0
    pool.release(s1)                             # canceled mid-upload
    assert al.free_pages == 1 and pool.lookup("b") is None
    # 3rd adapter overwrites the LRU resident in place, budget conserved
    s2 = pool.reserve("c", None, 8, nbytes=1)
    assert s2 is not None and al.free_pages == 0
    pool.commit(s2)
    # pinned everywhere + empty budget -> reservation fails, nothing lost
    al2 = PageAllocator(1)
    pool2 = DevicePool(cfg, n_slots=1, materialize=False, allocator=al2,
                       page_bytes=1)
    hog = al2.claim(1, "kv:hog")
    assert pool2.reserve("x", None, 8, nbytes=1, pinned=(0,)) is None
    assert al2.free_pages == 0 and al2.owner_of(hog[0]) == "kv:hog"


def test_supports_paged_matrix():
    assert model.supports_paged(get_config("llama2-7b").smoke())
    assert model.supports_paged(get_config("dbrx-132b").smoke())
    assert not model.supports_paged(get_config("mamba2-130m").smoke())
    assert not model.supports_paged(get_config("recurrentgemma-2b").smoke())
    assert not model.supports_paged(get_config("whisper-tiny").smoke())


def test_calc_cost_page_gate():
    """Routing treats a page-blocked server like an SLO break: demand
    above free_pages adds the penalty; dense servers (free_pages None)
    and satisfiable demands are unaffected."""
    from repro.core.perf_model import ServerPerfModel
    from repro.core.scheduler import PENALTY, ServerStats, calc_cost
    cfg = get_config("llama2-7b")
    perf = ServerPerfModel(cfg, kernel="bgmv")

    def stats(**kw):
        return ServerStats(running_ranks=[8], queued_ranks=[],
                           hosts_adapter=True, free_rows=4, n_requests=1,
                           **kw)
    base = calc_cost(8, stats(), perf, None, 64.0)
    fits = calc_cost(8, stats(free_pages=10, req_pages=3), perf, None, 64.0)
    blocked = calc_cost(8, stats(free_pages=2, req_pages=3), perf, None,
                        64.0)
    assert fits == base                    # satisfiable demand: no change
    assert blocked >= base + PENALTY       # page-blocked: penalized


def test_cluster_stats_carry_page_demand():
    """Numerics cluster servers report free_pages and per-request page
    demand (KV + non-resident adapter pages) to the scheduler."""
    from repro.core.cluster import Cluster
    from repro.core.scheduler import make_scheduler
    cfg = get_config("llama2-7b").smoke()
    servers = [InferenceServer(cfg, mode="cached", max_batch=2,
                               cache_slots=64, numerics=True,
                               memory="paged", page_size=32)
               for _ in range(2)]
    specs = [AdapterSpec("ad0", rank=8, base_model=cfg.name)]
    cl = Cluster(servers, make_scheduler("most_idle"), specs=specs)
    for s in servers:
        s.register_adapter(specs[0])
    req = Request(rid=0, adapter_uid="ad0",
                  prompt=np.zeros(40, np.int32), max_new_tokens=16)
    st = cl._stats("ad0", 0.0, req=req)
    for row in st:
        assert row.free_pages == servers[0].allocator.n_pages
        # 2 KV pages (56 tokens / 32) + 1 page for the cold adapter
        assert row.req_pages == 2 + servers[0].pool.pages_for(
            specs[0].nbytes(cfg))


def test_submit_rejects_kv_plus_adapter_overcommit():
    """A request whose KV demand alone fits the pool but whose KV +
    adapter pages cannot ever be resident together is rejected at submit
    (it would otherwise requeue forever without producing a token)."""
    cfg = get_config("llama2-7b").smoke()
    srv = InferenceServer(cfg, mode="caraserve", max_batch=4,
                          cache_slots=64, numerics=True, memory="paged",
                          page_size=32, total_pages=2)
    srv.register_adapter(AdapterSpec("ad0", rank=8, base_model=cfg.name))
    req = Request(rid=0, adapter_uid="ad0",
                  prompt=np.zeros(40, np.int32), max_new_tokens=24)
    with pytest.raises(ValueError, match="adapter pages"):
        srv.submit(req)          # 2 KV pages + 1 adapter page > 2 total


def test_doomed_reclaim_evicts_nothing():
    """A claim that cannot succeed even by shedding every cold resident
    must not evict any of them (reserve and KV admission alike)."""
    cfg = get_config("llama2-7b").smoke()
    al = PageAllocator(4)
    pool = DevicePool(cfg, n_slots=3, materialize=False, allocator=al,
                      page_bytes=10**9)            # 1 page per adapter
    for uid in ("a", "b"):
        pool.commit(pool.reserve(uid, None, 8, nbytes=1))
    hog = al.claim(2, "kv:hog")                    # pool now full
    # needs 4 pages; free 0 + own 0 + sheddable 2 < 4 -> refuse, no evict
    assert pool.reserve("c", None, 8, nbytes=4 * 10**9) is None
    assert pool.lookup("a") is not None and pool.lookup("b") is not None
    al.free(hog)
    # admission side: demand above free + sheddable defers, no eviction
    from repro.core.admission import AdmissionPlane
    from repro.core.cold_start import ColdStartManager
    from repro.core.lora import HostLoRAStore
    from repro.core.timing import TimingModel, V5E
    store = HostLoRAStore(cfg)
    cold = ColdStartManager(TimingModel(cfg, V5E), store, pool,
                            "caraserve")
    adm = AdmissionPlane(cold, store, pool, max_batch=2, allocator=al,
                         page_size=32, cache_slots=256)
    st = type("S", (), {})()
    st.preempted = False
    st.req = Request(rid=9, adapter_uid="a",
                     prompt=np.zeros(160, np.int32), max_new_tokens=200)
    assert adm.kv_pages_needed(st.req) == 8
    # admission claims prompt pages only (lazy growth) — but even the
    # 5-page prompt claim exceeds 2 free + 2 sheddable, so it defers
    assert adm.kv_pages_admit(st.req) == 5
    assert adm._claim_kv(st) is None
    assert pool.lookup("a") is not None and pool.lookup("b") is not None


# --------------------------------------- over-subscription / preemption ----

def _drive(srv, reqs, stop=None, max_iters=2000):
    """`run()` with an optional stop predicate, so a test can halt mid-run
    and inspect device state before retirement frees the pages."""
    pending = sorted(reqs, key=lambda r: r.arrival_ms)
    i = 0
    for _ in range(max_iters):
        if i >= len(pending) and not srv.busy():
            break
        while i < len(pending) and pending[i].arrival_ms <= srv.clock:
            srv.submit(pending[i])
            i += 1
        if not srv.busy() and i < len(pending):
            srv.clock = pending[i].arrival_ms
            continue
        srv.step(horizon_ms=pending[i].arrival_ms if i < len(pending)
                 else None)
        if stop is not None and stop():
            return
    srv.backend.flush_readback()


def _oversub_reqs(cfg, n=2, prompt_len=10, max_new=40, seed=7, slo=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, adapter_uid="ad0",
                    prompt=rng.integers(0, cfg.vocab,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=max_new, arrival_ms=0.0,
                    slo_tpt_ms=slo[i] if slo else None)
            for i in range(n)]


def test_select_victim_policy():
    """LRU by last token time, SLO-aware tiebreak (no-SLO first, then the
    loosest SLO), rid for determinism; exclusions honored."""
    from repro.core.scheduler import select_victim
    from repro.serving.request import RequestState

    def st(rid, last, slo=None):
        s = RequestState(Request(rid=rid, adapter_uid="a",
                                 prompt=np.zeros(4, np.int32),
                                 max_new_tokens=4, slo_tpt_ms=slo))
        s.token_times_ms = [last]
        return s

    a, b, c = st(0, 10.0), st(1, 5.0), st(2, 5.0, slo=20.0)
    assert select_victim([a, b, c]) is b      # LRU, then no-SLO preferred
    assert select_victim([a, b, c], exclude=(b,)) is c
    assert select_victim([a], exclude=(a,)) is None
    assert select_victim([]) is None
    loose, tight = st(4, 5.0, slo=100.0), st(5, 5.0, slo=10.0)
    assert select_victim([loose, tight]) is loose   # most slack evicted
    d = st(3, 5.0, slo=20.0)
    assert select_victim([c, d]) is c               # full tie: lowest rid


def test_lazy_growth_claims_on_boundary():
    """Admission claims prompt pages only; block tables grow exactly at
    page-boundary crossings, and the grown run is token-for-token equal
    to a pool that never ran short."""
    roomy, cfg = _small_server(total_pages=12, n_adapters=1)
    reqs = _oversub_reqs(cfg, n=3)
    roomy.run(reqs)
    srv, _ = _small_server(total_pages=8, n_adapters=1)
    srv.run([Request(r.rid, r.adapter_uid, r.prompt, r.max_new_tokens,
                     arrival_ms=0.0) for r in reqs])
    # 3 prompt pages at admission, one boundary claim each at pos 32,
    # plus ad0's page: 7 of 8 — growth never exhausts, nobody preempted
    assert srv.preempt_stats["grown_pages"] == 3
    assert srv.preempt_stats["preemptions"] == 0
    assert srv.admission.peak_active_rows == 3
    got = {s.req.rid: s.generated for s in srv.states}
    want = {s.req.rid: s.generated for s in roomy.states}
    assert got == want


@pytest.mark.parametrize("policy", ["recompute", "swap"])
def test_preemption_token_parity(policy):
    """Over-subscribed pool: mid-decode exhaustion preempts rows (swap or
    drop-and-recompute), and every resumed request still emits exactly the
    token stream of an uninterrupted run — including through megasteps."""
    roomy, cfg = _small_server(total_pages=12, n_adapters=1)
    reqs = _oversub_reqs(cfg)
    roomy.run(reqs)
    assert roomy.preempt_stats["preemptions"] == 0
    tight, _ = _small_server(total_pages=4, n_adapters=1, preempt=policy)
    tight.run([Request(r.rid, r.adapter_uid, r.prompt, r.max_new_tokens,
                       arrival_ms=0.0) for r in reqs])
    assert tight.preempt_stats["preemptions"] > 0
    if policy == "swap":
        assert tight.preempt_stats["swapped_pages"] > 0
    else:
        assert tight.preempt_stats["recompute_tokens"] > 0
    assert tight.peak_oversub > 1.0
    got = {s.req.rid: s.generated for s in tight.states}
    want = {s.req.rid: s.generated for s in roomy.states}
    for rid in want:
        assert got[rid] == want[rid], rid
    # preempted requests were billed a resume, never a second first token
    for s in tight.states:
        assert len(s.token_times_ms) == s.req.max_new_tokens
    assert tight.allocator.owned_by("kv:") == []


def test_swap_resume_restores_kv_pages_bitwise():
    """A swap-preempted row's restored KV pages — and the tokens written
    after resume — are bitwise-identical to an uninterrupted run gathered
    at the same decode position."""
    tight, cfg = _small_server(total_pages=4, n_adapters=1, preempt="swap",
                               megastep=0)
    reqs = _oversub_reqs(cfg)

    def resumed():
        return next((s for s in tight.states
                     if s.preemptions > 0 and s.row >= 0 and not s.done
                     and not s.preempted and s.phase == "decode"
                     and s.issued > s.resume_pos - s.req.prompt_len + 2),
                    None)

    _drive(tight, reqs, stop=lambda: resumed() is not None)
    st = resumed()
    assert st is not None, "scenario produced no resumed row mid-decode"
    tight.backend.flush_readback()
    pos_t = int(tight.admission.row_pos[st.row])
    width = tight.cache_slots // tight.page_size
    bt = np.full((width,), -1, np.int32)
    pages = tight.admission.row_pages[st.row]
    bt[:len(pages)] = pages
    got = cache_lib.gather_pages(tight.backend.cache, bt)

    base, _ = _small_server(total_pages=12, n_adapters=1, megastep=0)
    base.submit(Request(st.req.rid, st.req.adapter_uid, st.req.prompt,
                        st.req.max_new_tokens, arrival_ms=0.0))
    bs = base.states[0]
    while int(base.admission.row_pos[bs.row if bs.row >= 0 else 0]) < pos_t:
        base.step()
    base.backend.flush_readback()
    assert base.preempt_stats["preemptions"] == 0
    bbt = np.full((width,), -1, np.int32)
    bpages = base.admission.row_pages[bs.row]
    bbt[:len(bpages)] = bpages
    want = cache_lib.gather_pages(base.backend.cache, bbt)
    assert st.generated == bs.generated[:len(st.generated)]
    wpos = np.asarray(want["pos"])
    gpos = np.asarray(got["pos"])
    written = wpos >= 0
    assert written.any()
    assert np.array_equal(gpos, wpos)
    for leaf in ("k", "v"):
        g, w = np.asarray(got[leaf]), np.asarray(want[leaf])
        # (L, 1, KV, S, hd): compare every slot a position is written for
        m = np.broadcast_to(written[:, :, None, :, None], g.shape)
        assert np.array_equal(g[m], w[m]), leaf


def test_exhaustion_prefers_no_slo_victim():
    """First victim under exhaustion: with equal progress, the request
    without a decode SLO is preempted before the SLO-bound ones. Three
    rows cross their page boundary together with one free page: row 0
    grabs it, row 1's claim runs dry and hunts a victim among rows 0
    (SLO 5 ms) and 2 (no SLO) — equal last-token times, so the SLO
    tiebreak must pick row 2 even though row 0 has the lower rid."""
    tight, cfg = _small_server(total_pages=5, n_adapters=1,
                               preempt="recompute")
    reqs = _oversub_reqs(cfg, n=3, slo=[5.0, None, None])
    _drive(tight, reqs,
           stop=lambda: tight.preempt_stats["preemptions"] == 1)
    assert tight.preempt_stats["preemptions"] >= 1
    first = [s for s in tight.states if s.preemptions > 0]
    assert first and first[0].req.rid == 2


def test_freed_pages_readmit_same_step():
    """Deferral re-check (allocator on_free hook): a retirement that frees
    pages re-runs admission in the same engine step — the deferred request
    does not wait out an extra iteration."""
    srv, cfg = _small_server(total_pages=3, n_adapters=1)
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, adapter_uid="ad0",
                    prompt=rng.integers(0, cfg.vocab, 40).astype(np.int32),
                    max_new_tokens=8, arrival_ms=0.0)
            for i in range(2)]
    for r in reqs:
        srv.submit(r)
    st0, st1 = srv.states
    for _ in range(500):
        srv.step()
        if st0.done:
            break
    assert st0.done
    # rid 1 was deferred (2 prompt pages, 0 free) the whole time rid 0
    # ran; the step that retired rid 0 must also have admitted it
    assert st1.row >= 0 and st1.first_token_ms is not None


def test_calc_cost_preempt_pressure():
    """Routing charges the windowed preemption rate as per-token cost and
    steers toward the calm server."""
    from repro.core.perf_model import ServerPerfModel
    from repro.core.scheduler import (PREEMPT_PRESSURE_MS, ServerStats,
                                      calc_cost, make_scheduler)
    cfg = get_config("llama2-7b")
    perf = ServerPerfModel(cfg, kernel="bgmv")

    def stats(**kw):
        return ServerStats(running_ranks=[8], queued_ranks=[],
                           hosts_adapter=True, free_rows=4, n_requests=1,
                           **kw)

    calm = calc_cost(8, stats(), perf, None, 64.0)
    thrash = calc_cost(8, stats(preempt_pressure=2.0), perf, None, 64.0)
    assert thrash == calm + 2.0 * PREEMPT_PRESSURE_MS
    sched = make_scheduler("rank_aware", perf)
    assert sched.route(8, [stats(preempt_pressure=2.0), stats()]) == 1


def test_paged_attn_impl_routing_and_parity():
    """models/layers routes paged decode through the Pallas kernel when
    selected (interpret mode off-TPU) and it matches the gather path;
    auto mode picks the kernel exactly on TPU backends, and windowed
    attention always takes the gather path."""
    from repro.models import layers
    rng = np.random.default_rng(8)
    B, H, KV, hd, ps, W, P = 2, 4, 2, 8, 8, 2, 5
    cache = {
        "k": jnp.asarray(rng.normal(size=(P, KV, ps, hd)), jnp.float32),
        "v": jnp.asarray(rng.normal(size=(P, KV, ps, hd)), jnp.float32),
        "pos": jnp.full((P, ps), -1, jnp.int32),
    }
    bt = jnp.asarray([[0, 1], [2, -1]], jnp.int32)
    pos_pages = np.full((P, ps), -1, np.int32)
    for row, pages in enumerate([[0, 1], [2]]):
        for j, pg in enumerate(pages):
            pos_pages[pg] = np.arange(j * ps, (j + 1) * ps)
    cache["pos"] = jnp.asarray(pos_pages)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    pos = jnp.asarray([12, 5], jnp.int32)
    expect = "pallas" if jax.default_backend() == "tpu" else "gather"
    assert layers.paged_attn_impl() == expect
    old = layers.PAGED_ATTN_IMPL
    try:
        layers.PAGED_ATTN_IMPL = "gather"
        want = layers.paged_attn_decode(q, cache, bt, pos)
        layers.PAGED_ATTN_IMPL = "pallas"
        got = layers.paged_attn_decode(q, cache, bt, pos)
        # windowed attention: falls back to gather on either impl
        win = layers.paged_attn_decode(q, cache, bt, pos, window=4)
        layers.PAGED_ATTN_IMPL = "gather"
        assert np.array_equal(
            np.asarray(win),
            np.asarray(layers.paged_attn_decode(q, cache, bt, pos,
                                                window=4)))
    finally:
        layers.PAGED_ATTN_IMPL = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
