"""Runtime sanitizers (REPRO_SANITIZE=1): PageSan shadow ownership over the
unified page pool, LinkSan happens-before checks on the upload link, and the
RetraceSan steady-state retrace detector — each must catch an injected
violation and stay silent on the legitimate paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitizers
from repro.analysis.retrace import RetraceError, RetraceSan
from repro.analysis.sanitizers import LinkSanError, PageSanError
from repro.configs.base import get_config
from repro.core.cold_start import ColdStartManager, LoadTracker
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import TimingModel
from repro.serving.cache import PageAllocator
from repro.serving.request import Request


# ------------------------------------------------------------- PageSan ----

def test_pagesan_off_by_default():
    with sanitizers.force(False):          # even under REPRO_SANITIZE=1 CI
        al = PageAllocator(4)
    assert al.san is None                  # no shadow state, no overhead


def test_pagesan_double_free_detected():
    """A double-free is caught even when the allocator's own book-keeping
    was corrupted back to 'owned' — the shadow map is the authority."""
    with sanitizers.force(True):
        al = PageAllocator(8)
        ids = al.claim(2, "kv:1")
        al.free(ids)
        al._owner.update({i: "kv:1" for i in ids})   # inject corruption
        with pytest.raises(PageSanError, match="double-free"):
            al.free(ids)


def test_pagesan_double_claim_detected():
    with sanitizers.force(True):
        al = PageAllocator(4)
        a = al.claim(2, "kv:1")
        al._free.append(a[0])              # inject: live page re-listed free
        with pytest.raises(PageSanError, match="double-claim"):
            al.claim(3, "kv:2")


def test_pagesan_use_after_free():
    """Freed pages are quarantined, so a stale block-table entry touches a
    dead page and is reported — with the previous owner named."""
    with sanitizers.force(True):
        al = PageAllocator(8)
        ids = al.claim(2, "kv:1")
        al.san.check_access(ids, "kv:", "decode block table")   # live: fine
        al.free(ids)
        with pytest.raises(PageSanError, match="use-after-free.*kv:1"):
            al.san.check_access(ids, "kv:", "decode block table")


def test_pagesan_kv_adapter_aliasing():
    with sanitizers.force(True):
        al = PageAllocator(8)
        kv = al.claim(2, "kv:1")
        ad = al.claim(2, "adapter:u")
        al.san.check_access(kv, "kv:", "decode block table")
        al.san.check_access(ad, "adapter:", "lora slot")
        with pytest.raises(PageSanError, match="aliasing"):
            al.san.check_access(kv + ad, "kv:", "decode block table")


def test_pagesan_quarantine_is_capacity_neutral():
    """free_pages counts quarantined pages and claim recycles them under
    pressure: accounting is identical with and without the sanitizer."""
    with sanitizers.force(True):
        al = PageAllocator(4)
        a = al.claim(3, "kv:1")
        al.free(a)
        assert al.free_pages == 4 and al.used_pages == 0
        b = al.claim(4, "kv:2")            # needs the quarantined pages
        assert b is not None and al.free_pages == 0
        al.san.check_access(b, "kv:", "decode")    # recycled = live again
        assert al.claim(1, "kv:3") is None         # genuinely exhausted


def test_pagesan_negative_ids_skipped():
    """-1 block-table entries (unclaimed logical pages) are not accesses."""
    with sanitizers.force(True):
        al = PageAllocator(4)
        ids = al.claim(2, "kv:1")
        al.san.check_access(list(ids) + [-1, -1], "kv:", "decode")


# ------------------------------------------------------------- LinkSan ----

def _mk_manager(policy, uids=("u0", "u1", "u2", "u3"), n_slots=8):
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    store = HostLoRAStore(cfg)
    for u in uids:
        store.register(AdapterSpec(u, rank=64, base_model=cfg.name),
                       materialize=False)
    pool = DevicePool(cfg, n_slots=n_slots, materialize=False)
    return ColdStartManager(tm, store, pool, "caraserve",
                            link_policy=policy), tm


def test_linksan_clean_on_legitimate_preempt_flow():
    with sanitizers.force(True):
        mgr, _ = _mk_manager("preempt")
        mgr.load_async("u0", 0.0, demand=False)
        mgr.load_async("u1", 0.0, demand=False)   # queues behind u0
        ev = mgr.load_async("u2", 1.0, demand=True)
        assert ev is not None
        mgr.poll(10_000.0)
        assert mgr.tracker.stats["demand_delayed_by_prefetch"] == 0


def test_linksan_detects_demand_behind_prefetch():
    """Break the manager's preempt step: queued speculative uploads survive
    a demand begin, so the demand start is delayed behind prefetch — the
    exact hazard the preempt policy exists to rule out."""
    with sanitizers.force(True):
        mgr, _ = _mk_manager("preempt")
        mgr._cancel_queued_prefetch = lambda: None    # inject the bug
        mgr.load_async("u0", 0.0, demand=False)       # takes the lane
        mgr.load_async("u1", 0.0, demand=False)       # queued prefetch
        with pytest.raises(LinkSanError,
                           match="prefetch|delayed"):
            mgr.load_async("u2", 1.0, demand=True)


def test_linksan_detects_rescheduled_started_upload():
    """A started upload's (start, finish) is frozen; moving it afterwards
    (lane reassignment bug) is flagged at retirement."""
    with sanitizers.force(True):
        cfg = get_config("llama2-7b")
        tracker = LoadTracker(TimingModel(cfg), policy="fifo")
        ev = tracker.begin("u", 0, 1 << 20, 0.0, demand=True)
        assert ev.started
        ev.finish_ms += 7.0                           # inject the bug
        with pytest.raises(LinkSanError, match="frozen"):
            tracker.complete_until(1e9)


def test_linksan_kv_swap_rides_demand_class():
    with sanitizers.force(True):
        mgr, _ = _mk_manager("preempt")
        mgr.load_async("u0", 0.0, demand=False)
        mgr.load_async("u1", 0.0, demand=False)
        ev = mgr.upload_kv(7, 1 << 22, 1.0)           # preempts the queue
        assert ev.demand and ev.uid == "kvswap:7"
        mgr.poll(10_000.0)


def test_linksan_killed_upload_must_never_retire():
    """Inject the failure plane's nightmare: a crash-canceled upload put
    back on the running list by a buggy recovery path."""
    with sanitizers.force(True):
        cfg = get_config("llama2-7b")
        tracker = LoadTracker(TimingModel(cfg), policy="fifo")
        ev = tracker.begin("u", 0, 1 << 20, 0.0, demand=True)
        tracker.cancel_all()
        tracker._running.append(ev)                   # inject the bug
        with pytest.raises(LinkSanError, match="must never retire"):
            tracker.complete_until(1e9)


def test_linksan_failed_attempt_must_never_retire():
    """A failed attempt's seq joins the never-retire set; a tracker bug
    that retires the stale event object anyway is flagged."""
    with sanitizers.force(True):
        cfg = get_config("llama2-7b")
        tracker = LoadTracker(TimingModel(cfg), policy="fifo")
        ev = tracker.begin("u", 0, 1 << 20, 0.0, demand=True)
        tracker.fail_hook = lambda e: True            # every retirement fails
        tracker.complete_until(ev.finish_ms + 0.001)  # fails -> retry queued
        assert tracker.stats["upload_failures"] == 1
        tracker.fail_hook = None
        tracker._running.append(ev)                   # inject: zombie retire
        with pytest.raises(LinkSanError, match="must never retire"):
            tracker.complete_until(1e9)


def test_linksan_retry_must_follow_failed_attempt():
    """on_retry's happens-before: a retry requested at (or before) the
    failed attempt's finish means the backoff vanished."""
    with sanitizers.force(True):
        cfg = get_config("llama2-7b")
        tracker = LoadTracker(TimingModel(cfg), policy="fifo")
        ev = tracker.begin("u", 0, 1 << 20, 0.0, demand=True)
        tracker.fail_hook = lambda e: True
        tracker._backoff_ms = lambda e: 0.0           # inject: no backoff
        with pytest.raises(LinkSanError, match="not after the failed"):
            tracker.complete_until(1e9)


def test_linksan_retry_attempt_numbering():
    with sanitizers.force(True):
        cfg = get_config("llama2-7b")
        tracker = LoadTracker(TimingModel(cfg), policy="fifo")
        failed = tracker.begin("u", 0, 1 << 20, 0.0, demand=True)
        retry = tracker.begin("u", 0, 1 << 20, failed.finish_ms + 5.0,
                              demand=True)
        retry.attempt = 3                             # inject: skipped a step
        with pytest.raises(LinkSanError, match="carries attempt"):
            tracker.san.on_retry(failed, retry)


def test_linksan_clean_retry_flow():
    """The legitimate fail -> backoff -> retry -> retire path stays
    silent, and the retry retires strictly after the failed attempt."""
    with sanitizers.force(True):
        cfg = get_config("llama2-7b")
        tracker = LoadTracker(TimingModel(cfg), policy="fifo")
        ev = tracker.begin("u", 0, 1 << 20, 0.0, demand=True)
        first_finish = ev.finish_ms
        fails = {0}
        tracker.fail_hook = lambda e: e.attempt in fails
        done = tracker.complete_until(1e9)
        assert [e.uid for e in done] == ["u"]
        assert done[0].attempt == 1
        assert done[0].finish_ms > first_finish
        assert tracker.stats["retries"] == 1


# ----------------------------------------------------------- RetraceSan ----

def test_retrace_detects_shape_unstable_step():
    san = RetraceSan()
    fn = jax.jit(lambda x: x * 2)
    fn(jnp.ones((4,)))
    san.observe("step", fn)
    san.mark_steady()
    fn(jnp.ones((4,)))
    san.observe("step", fn)
    san.assert_clean()                     # trace-stable: no violation
    fn(jnp.ones((5,)))                     # shape change -> retrace
    san.observe("step", fn)
    with pytest.raises(RetraceError, match="step"):
        san.assert_clean()


def test_retrace_warmup_is_tolerated():
    san = RetraceSan()
    fn = jax.jit(lambda x: x + 1)
    for n in (2, 3, 4):                    # warmup traces before steady
        fn(jnp.ones((n,)))
        san.observe("warm", fn)
    san.mark_steady()
    fn(jnp.ones((4,)))
    san.observe("warm", fn)
    san.assert_clean()


def _run_server(reqs, srv):
    srv.run(reqs)
    return srv


def test_retrace_steady_megastep_clean():
    """The megastep decode pipeline must be trace-stable: after a full
    warmup run, replaying an identical workload compiles nothing new."""
    with sanitizers.force(True):
        cfg = get_config("llama2-7b").smoke()
        srv = InferenceServer(cfg, mode="cached", max_batch=4,
                              cache_slots=64, numerics=True, seed=0,
                              pipeline="fused", megastep=8)
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, cfg.vocab, 5 + i).astype(np.int32)
                   for i in range(3)]
        for i in range(3):
            srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                             base_model=cfg.name))
        reqs = [Request(rid=i, adapter_uid=f"ad{i}", prompt=prompts[i],
                        max_new_tokens=n, arrival_ms=0.0)
                for i, n in enumerate((9, 5, 7))]
        srv.run(reqs)
        san = srv.backend.retrace_san
        assert san is not None and srv.backend.transfer_stats["megasteps"]
        san.mark_steady()
        replay = [Request(rid=10 + i, adapter_uid=f"ad{i}",
                          prompt=prompts[i], max_new_tokens=n,
                          arrival_ms=0.0)
                  for i, n in enumerate((9, 5, 7))]
        srv.run(replay)
        san.assert_clean()
