"""Continuous-batching engine: numerics correctness (batched serving must
reproduce offline generation), mode orderings, cold-start accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec, pool_init, pool_insert
from repro.models import model
from repro.models.param import split
from repro.serving.request import Request
from repro.serving.sampling import sample


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama2-7b").smoke()


def offline_generate(cfg, params, store, uid, prompt, n_new):
    """Reference: single-request greedy generation with bucket-padded prefill
    (mirrors the engine's padding so logits match exactly)."""
    from repro.core.engine import _bucket
    L = len(prompt)
    Lp = _bucket(L)
    toks = np.zeros((1, Lp), np.int32)
    toks[0, :L] = prompt
    w = store[uid]
    pool = {t: {"a": jnp.asarray(w[t]["a"])[:, None],
                "b": jnp.asarray(w[t]["b"])[:, None]} for t in w}
    pool["ranks"] = jnp.full((1,), 8, jnp.int32)
    lora = {"pool": pool, "idx": jnp.zeros((1,), jnp.int32), "mode": "bgmv"}
    logits, cache = model.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                                  lora=lora, cache_slots=64)
    out = [int(sample(logits[:, L - 1])[0])]
    # mask padded cache slots like the engine does
    def fix(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name == "pos":
            return jnp.where(jnp.arange(x.shape[-1])[None] < L, x, -1)
        return x
    cache = jax.tree_util.tree_map_with_path(fix, cache)
    pos = L
    while len(out) < n_new:
        lg, cache = model.decode(cfg, params, cache,
                                 jnp.array([[out[-1]]], jnp.int32),
                                 jnp.array([pos], jnp.int32), lora=lora)
        out.append(int(sample(lg[:, -1])[0]))
        pos += 1
    return out


def test_engine_matches_offline_generation(cfg):
    """3 overlapping requests with different adapters, continuous batching:
    every request's tokens == its isolated offline generation."""
    srv = InferenceServer(cfg, mode="caraserve", max_batch=4, cache_slots=64,
                          numerics=True, seed=0)
    rng = np.random.default_rng(0)
    for i in range(3):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
    reqs = []
    for i in range(3):
        prompt = rng.integers(0, cfg.vocab, 6 + i).astype(np.int32)
        reqs.append(Request(rid=i, adapter_uid=f"ad{i}", prompt=prompt,
                            max_new_tokens=5, arrival_ms=float(i)))
    srv.run(reqs)
    for st in srv.states:
        want = offline_generate(cfg, srv.params,
                                {u: srv.store.weights(u)
                                 for u in srv.store.specs},
                                st.req.adapter_uid, st.req.prompt, 5)
        assert st.generated == want, st.req.rid


def test_batched_prefill_matches_offline(cfg):
    """All requests arrive at once, so the backend packs them into ONE
    padded prefill call — which must still reproduce each isolated offline
    generation exactly (causal masking makes packing logit-identical)."""
    srv = InferenceServer(cfg, mode="cached", max_batch=4, cache_slots=64,
                          numerics=True, seed=0)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(3):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
        prompt = rng.integers(0, cfg.vocab, 6 + i).astype(np.int32)
        reqs.append(Request(rid=i, adapter_uid=f"ad{i}", prompt=prompt,
                            max_new_tokens=5, arrival_ms=0.0))
    srv.run(reqs)
    # one packed call: batch bucketed to 4, length bucketed to 8 (paged
    # keys carry the bucketed clear-list length as a third component)
    assert [k[:2] for k in srv.backend._prefill_jit] == [(4, 8)]
    for st in srv.states:
        want = offline_generate(cfg, srv.params,
                                {u: srv.store.weights(u)
                                 for u in srv.store.specs},
                                st.req.adapter_uid, st.req.prompt, 5)
        assert st.generated == want, st.req.rid


def test_mode_ttft_ordering(cfg):
    """TTFT: cached <= caraserve < ondemand on a cold-start-heavy trace."""
    rng = np.random.default_rng(1)
    results = {}
    for mode in ("cached", "caraserve", "ondemand"):
        srv = InferenceServer(cfg, mode=mode, max_batch=4, numerics=False)
        for i in range(8):
            srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                             base_model=cfg.name))
        reqs = [Request(rid=i, adapter_uid=f"ad{i}",
                        prompt=np.zeros(16, np.int32), max_new_tokens=4,
                        arrival_ms=i * 200.0) for i in range(8)]
        results[mode] = srv.run(reqs)
    # caraserve rivals the CACHED oracle (host GEMMs genuinely parallel to the
    # device prefill, so it may even edge it out slightly) and strictly beats
    # blocking on-demand loading
    assert results["caraserve"]["ttft_mean"] <= \
        1.25 * results["cached"]["ttft_mean"]
    assert results["caraserve"]["ttft_mean"] < \
        results["ondemand"]["ttft_mean"]
    assert results["caraserve"]["assisted"] == 8
    assert results["ondemand"]["cold_starts"] == 8


def test_ondemand_blocks_inflight_decode(cfg):
    """Paper Fig 2: a cold start under ONDMD delays the in-flight request's
    tokens; CARASERVE does not."""
    tpt = {}
    for mode in ("caraserve", "ondemand"):
        srv = InferenceServer(cfg, mode=mode, max_batch=4, numerics=False)
        srv.register_adapter(AdapterSpec("hot", rank=8, base_model=cfg.name))
        srv.register_adapter(AdapterSpec("cold", rank=64,
                                         base_model=cfg.name))
        reqs = [
            Request(rid=0, adapter_uid="hot", prompt=np.zeros(8, np.int32),
                    max_new_tokens=30, arrival_ms=0.0),
            Request(rid=1, adapter_uid="cold", prompt=np.zeros(8, np.int32),
                    max_new_tokens=5, arrival_ms=10.0),
        ]
        srv.run(reqs)
        tpt[mode] = srv.states[0].tpt_ms()
    assert tpt["caraserve"] < tpt["ondemand"]


def test_prompt_longer_than_cache_slots_rejected(cfg):
    """A prompt that cannot fit a KV-cache row must be rejected with a
    clear error at submit time — previously it surfaced as an opaque numpy
    broadcast error mid-iteration inside the packed prefill."""
    srv = InferenceServer(cfg, mode="cached", max_batch=2, cache_slots=8,
                          numerics=True, seed=0)
    srv.register_adapter(AdapterSpec("a", rank=8, base_model=cfg.name))
    long_req = Request(rid=0, adapter_uid="a",
                       prompt=np.zeros(9, np.int32), max_new_tokens=2,
                       arrival_ms=0.0)
    with pytest.raises(ValueError, match="KV-cache"):
        srv.submit(long_req)
    assert not srv.states and not srv.queue      # nothing half-enqueued
    # boundary: a prompt of exactly cache_slots tokens is fine
    ok = Request(rid=1, adapter_uid="a", prompt=np.zeros(8, np.int32),
                 max_new_tokens=2, arrival_ms=0.0)
    out = srv.run([ok])
    assert out["n"] == 1
    # timing-only servers have no KV pool: long prompts stay legal there
    srv2 = InferenceServer(cfg, mode="cached", max_batch=2, cache_slots=8,
                           numerics=False)
    srv2.register_adapter(AdapterSpec("a", rank=8, base_model=cfg.name))
    srv2.submit(long_req)


def test_rows_freed_and_reused(cfg):
    srv = InferenceServer(cfg, mode="cached", max_batch=2, numerics=False)
    srv.register_adapter(AdapterSpec("a", rank=8, base_model=cfg.name))
    reqs = [Request(rid=i, adapter_uid="a", prompt=np.zeros(4, np.int32),
                    max_new_tokens=3, arrival_ms=0.0) for i in range(6)]
    out = srv.run(reqs)
    assert out["n"] == 6
    assert all(r is None for r in srv.rows)


def test_prefetch_reduces_cold_starts(cfg):
    """Beyond-paper: popularity-EWMA prefetching (the mechanism S-LoRA
    leaves unspecified, paper sec 2.3) cuts cold starts on skewed traces."""
    import numpy as np
    from repro.traces import gen
    full = __import__("repro.configs.base", fromlist=["get_config"]
                      ).get_config("llama2-7b")
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(64, full.name, rng, uniform_rank=64)
    reqs = gen.maf_trace(adapters, rps=8, duration_s=30, vocab=100, seed=1)
    colds = {}
    for pf in (False, True):
        srv = InferenceServer(full, mode="caraserve", max_batch=16,
                              numerics=False, prefetch=pf, pool_slots=24)
        for ad in adapters:
            srv.register_adapter(ad)
        out = srv.run(reqs)
        colds[pf] = out["cold_starts"]
    assert colds[True] < colds[False]


def test_async_readback_ordering_under_flip(cfg):
    """Mid-flight CPU-assist->device flips (and retirements) land between
    decode dispatches: the async readback queue binds each token block to
    the states it was dispatched for, so tokens drained after a flip — or
    after the row was already released — still reproduce each request's
    isolated offline generation exactly."""
    srv = InferenceServer(cfg, mode="caraserve", max_batch=4, cache_slots=64,
                          numerics=True, seed=0)
    srv.register_adapter(AdapterSpec("warm", rank=8, base_model=cfg.name))
    srv.register_adapter(AdapterSpec("cold", rank=64, base_model=cfg.name))
    rng = np.random.default_rng(3)
    reqs = [
        Request(rid=0, adapter_uid="warm",
                prompt=rng.integers(0, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=12, arrival_ms=0.0),
        # arrives while rid=0 decodes: prefill + upload + flip mid-stream
        Request(rid=1, adapter_uid="cold",
                prompt=rng.integers(0, cfg.vocab, 7).astype(np.int32),
                max_new_tokens=6, arrival_ms=5.0),
    ]
    srv.run(reqs)
    assert all(st.assist_used for st in srv.states)
    assert any(st.flip_ms is not None for st in srv.states)
    assert all(st.pending_tokens == 0 for st in srv.states)  # all drained
    for st in srv.states:
        want = offline_generate(cfg, srv.params,
                                {u: srv.store.weights(u)
                                 for u in srv.store.specs},
                                st.req.adapter_uid, st.req.prompt,
                                st.req.max_new_tokens)
        assert st.generated == want, st.req.rid


def test_staging_cache_hits_and_eviction(cfg):
    """The CPU-assist prefill staging cache: a repeated prefill of the
    same adapter reuses the device copy (no host-link crossing); the LRU
    bound evicts the coldest entry; a re-registered adapter misses."""
    srv = InferenceServer(cfg, mode="cached", max_batch=2, cache_slots=64,
                          numerics=True, seed=0, staging_slots=2)
    for i in range(3):
        srv.register_adapter(AdapterSpec(f"s{i}", rank=8,
                                         base_model=cfg.name))

    def one(rid, uid):
        srv.run([Request(rid=rid, adapter_uid=uid,
                         prompt=np.zeros(4, np.int32), max_new_tokens=2,
                         arrival_ms=srv.clock + 1.0)])

    st = srv.backend.staging
    one(0, "s0")
    assert (st.hits, st.misses, st.evictions) == (0, 1, 0)
    one(1, "s0")                      # hot adapter: device copy reused
    assert (st.hits, st.misses, st.evictions) == (1, 1, 0)
    one(2, "s1")
    one(3, "s2")                      # bound is 2: s0 (LRU) evicted
    assert (st.hits, st.misses, st.evictions) == (1, 3, 1)
    one(4, "s0")                      # evicted: pays the upload again
    assert (st.hits, st.misses, st.evictions) == (1, 4, 2)
    # a re-registered adapter (new registered_ms) must not hit stale state
    from repro.core.lora import AdapterSpec as AS
    srv.store.register(AS("s0", rank=8, base_model=cfg.name, seed=1),
                       materialize=True, now_ms=srv.clock + 0.5)
    one(5, "s0")
    assert st.misses == 5
