"""Roofline utilities: HLO collective parsing and term computation."""
import pytest

from repro import roofline
from repro.configs.base import INPUT_SHAPES, get_config

HLO = """
ENTRY %main {
  %ag = bf16[8,512,1024]{2,1,0} all-gather(%p0), replica_groups=...
  %ar.1 = f32[256,128]{1,0} all-reduce(%x), to_apply=%add
  %rs = bf16[4,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (bf16[2,8]{1,0}, bf16[2,8]{1,0}) all-to-all(%a, %b)
  %cp = u8[1024]{0} collective-permute(%z), source_target_pairs=...
  %dot = f32[8,8]{1,0} dot(%q, %k)
}
"""


def test_collective_bytes_parsing():
    got = roofline.collective_bytes(HLO)
    assert got["all-gather"] == 8 * 512 * 1024 * 2
    assert got["all-reduce"] == 256 * 128 * 4 * 2          # 2x factor
    assert got["reduce-scatter"] == 4 * 64 * 2
    assert got["all-to-all"] == 2 * (2 * 8 * 2)            # tuple: both elems
    assert got["collective-permute"] == 1024


def test_roofline_terms_dominance():
    t = roofline.roofline_terms(197e12, 0.0, 0.0, 256)     # 1 s of compute
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    t = roofline.roofline_terms(0.0, 819e9, 1e9, 1)
    assert t["dominant"] == "memory" and t["memory_s"] == pytest.approx(1.0)
    t = roofline.roofline_terms(0.0, 0.0, 50e9, 1)
    assert t["dominant"] == "collective"
    assert t["collective_s"] == pytest.approx(1.0)


def test_model_flops_conventions():
    cfg = get_config("llama2-7b")
    tr = roofline.model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = roofline.model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = roofline.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096)
    assert pf == pytest.approx(2 * n * 32 * 32768)
    assert dc == pytest.approx(2 * n * 128)
    # MoE uses active params
    g = get_config("grok-1-314b")
    assert roofline.model_flops(g, INPUT_SHAPES["decode_32k"]) < \
        2 * g.param_count() * 128
