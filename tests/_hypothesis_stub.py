"""Minimal deterministic stand-in for `hypothesis`, used only when the real
package is absent (the pinned CI/container image does not ship it).

Implements just the surface this test-suite uses — ``given``, ``settings``,
and the ``integers`` / ``sampled_from`` / ``lists`` strategies — by drawing
``max_examples`` pseudo-random examples from a seed derived from the test
name, so runs are reproducible. Property shrinking, example databases, and
the rest of hypothesis are intentionally out of scope: install the real
dependency (``pip install -e .[test]``) for full property testing.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[int(rng.integers(len(options)))])


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


def floats(min_value=0.0, max_value=1.0):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


class settings:
    """Decorator recording run options; only max_examples is honoured."""

    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(**strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        passthrough = [p for name, p in sig.parameters.items()
                       if name not in strategies]

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue

        # pytest must see only the non-strategy params (fixtures); hide the
        # wrapped signature functools.wraps exposes via __wrapped__
        del runner.__wrapped__
        runner.__signature__ = sig.replace(parameters=passthrough)
        return runner
    return deco


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass
