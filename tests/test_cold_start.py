"""Cold-start manager: overlap timeline invariants (paper sec 4) +
hypothesis properties over ranks/prompt lengths."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.cold_start import ColdStartManager
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import TimingModel


def mk(mode, rank=64, n_slots=4):
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    store = HostLoRAStore(cfg)
    store.register(AdapterSpec("u", rank=rank, base_model=cfg.name),
                   materialize=False)
    pool = DevicePool(cfg, n_slots=n_slots, materialize=False)
    return ColdStartManager(tm, store, pool, mode), tm


@settings(max_examples=25, deadline=None)
@given(rank=st.sampled_from([8, 16, 32, 64]),
       tokens=st.integers(4, 2048))
def test_caraserve_never_slower_than_ondemand(rank, tokens):
    cara, _ = mk("caraserve", rank)
    ond, _ = mk("ondemand", rank)
    p_c = cara.admit("u", 0.0, tokens)
    p_o = ond.admit("u", 0.0, tokens)
    assert p_c.prefill_ms <= p_o.prefill_ms + 1e-9
    assert p_c.blocking_ms == 0.0          # decode of others not stalled
    assert p_o.blocking_ms > 0.0
    assert p_c.assist and p_c.cold


@settings(max_examples=25, deadline=None)
@given(rank=st.sampled_from([8, 16, 32, 64]), tokens=st.integers(4, 2048))
def test_overlap_bounds(rank, tokens):
    """Hybrid prefill is bounded below by the base prefill and the decode
    switch cannot happen before the upload completes."""
    cara, tm = mk("caraserve", rank)
    spec = AdapterSpec("u", rank=rank, base_model=tm.cfg.name)
    plan = cara.admit("u", 0.0, tokens)
    t_load = tm.load_ms(spec.nbytes(tm.cfg))
    assert plan.prefill_ms >= tm.base_prefill_ms(tokens) - 1e-9
    assert plan.ready_decode_ms >= t_load - 1e-9


def test_cached_has_no_load():
    c, tm = mk("cached")
    plan = c.admit("u", 0.0, 128)
    assert plan.blocking_ms == 0.0 and not plan.assist


def test_warm_adapter_no_cold_start():
    c, _ = mk("caraserve")
    p1 = c.admit("u", 0.0, 128)
    p2 = c.admit("u", 100.0, 128)
    assert p1.cold and not p2.cold
    # warm runs base+LoRA serially on-device; cold CPU-assist overlaps the
    # host GEMMs with the base prefill, so the two are within a few percent
    assert p2.prefill_ms <= 1.1 * p1.prefill_ms
    # but only the cold path waits on the upload before decoding
    assert p2.ready_decode_ms == 100.0 + p2.prefill_ms
    assert p1.ready_decode_ms > p1.prefill_ms


def test_load_time_scales_with_rank():
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    loads = [tm.load_ms(AdapterSpec("x", r, cfg.name).nbytes(cfg))
             for r in (8, 16, 32, 64)]
    assert all(a < b for a, b in zip(loads, loads[1:]))
    # paper Fig 3-right: tens of ms for rank 64 on a 7B model
    assert 10.0 < loads[-1] < 100.0


def test_profiling_guided_parallelization():
    cfg = get_config("llama2-7b")
    tm = TimingModel(cfg)
    assert tm.cpu_cores_for(8) == 1
    assert tm.cpu_cores_for(128) == 8      # 16 tokens per core
    assert tm.cpu_cores_for(10 ** 6) == tm.hw.cpu_cores  # capped
    # more cores -> faster host prefill (Fig 18-right)
    assert tm.cpu_lora_prefill_ms(128, 64) < 8 * tm.cpu_lora_prefill_ms(16, 64)
