"""Chunked-prefill control plane: timing-model pins (the quadratic
attention term must not move short-prompt costs), chunk-by-chunk page
claims, megastep boundary semantics with an in-flight chunk, preemption
of half-prefilled rows, the decode-commitment routing term, and the ITL
metric helpers. All engine tests here run the timing-only plane (no
numerics backend) — bitwise chunk/monolithic parity lives in
test_decode_consistency.py."""
import math
import types

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import ServerStats, calc_cost
from repro.core.timing import TimingModel
from repro.serving.cache import boundary_steps, pages_for_tokens
from repro.serving.request import Request, RequestState, itl_percentiles

CFG = get_config("llama2-7b")


# ------------------------------------------------------- timing model ----

def _linear_prefill_ms(tm, tokens):
    """The pre-attention-term prefill law: linear GEMM flops vs HBM."""
    t_c = 2 * tm._active_params * tokens / (tm.hw.peak_flops * tm.hw.chips)
    t_m = tm._active_bytes / (tm.hw.hbm_bw * tm.hw.chips)
    return max(t_c, t_m) * 1e3 + tm.hw.step_overhead_ms


@pytest.mark.parametrize("tokens", [16, 64, 128])
def test_base_prefill_short_prompts_unchanged(tokens):
    """Short prompts are HBM-bound: adding the quadratic causal-attention
    flops term leaves their cost bitwise identical to the old linear law
    (the compute term stays under the memory term)."""
    tm = TimingModel(CFG)
    assert tm.base_prefill_ms(tokens) == _linear_prefill_ms(tm, tokens)


def test_base_prefill_quadratic_marginal_grows():
    """Long prompts are compute-bound and the attention term is quadratic,
    so the marginal cost of extra tokens grows with depth — the linear law
    would price both 1k-token extensions identically."""
    tm = TimingModel(CFG)
    lo = tm.base_prefill_ms(2048) - tm.base_prefill_ms(1024)
    hi = tm.base_prefill_ms(4096) - tm.base_prefill_ms(3072)
    assert hi > lo
    assert tm.base_prefill_ms(4096) > _linear_prefill_ms(tm, 4096)


@pytest.mark.parametrize("total,chunk", [(512, 64), (61, 16), (40, 16)])
def test_attn_flops_chunk_conservation(total, chunk):
    """Splitting a prefill into chunks conserves attention flops exactly:
    sum over chunks of attn(C_i, ctx_i) == attn(total, 0). This is the
    algebra behind chunked billing never drifting from monolithic."""
    tm = TimingModel(CFG)
    acc, pos = 0.0, 0
    while pos < total:
        n = min(chunk, total - pos)
        acc += tm._attn_flops(n, pos)
        pos += n
    assert math.isclose(acc, tm._attn_flops(total), rel_tol=1e-12)
    assert tm._attn_flops(0) == 0.0


def test_mixed_step_reduces_to_pure_forms():
    """mixed_step_ms degenerates to the pure decode iteration at
    chunk_tokens=0 and to the standalone chunk iteration at batch=0."""
    tm = TimingModel(CFG)
    assert tm.mixed_step_ms(8, 512, 0) == tm.base_decode_ms(8, 512)
    assert tm.chunk_prefill_ms(64, 512) == tm.mixed_step_ms(0, 0, 64, 512)


def test_piggyback_shares_weight_pass_and_overhead():
    """The piggyback win: one mixed iteration is strictly cheaper than a
    decode iteration plus a standalone chunk iteration (the chunk rides
    the batch's weight pass and fixed step overhead)."""
    tm = TimingModel(CFG)
    mixed = tm.mixed_step_ms(8, 512, 64, 512)
    split = tm.base_decode_ms(8, 512) + tm.chunk_prefill_ms(64, 512)
    assert mixed < split


def test_prefill_spike_ms_regimes():
    """Routing's interference spike: whole prompt on a monolithic server,
    one (deepest-context) chunk on a chunking server, zero for nothing."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    tm = perf._tm
    assert perf.prefill_spike_ms(0) == 0.0
    assert perf.prefill_spike_ms(1024) == tm.base_prefill_ms(1024)
    assert perf.prefill_spike_ms(1024, 128) == tm.chunk_prefill_ms(128, 896)
    # budget >= prompt means the prompt goes up in one piece anyway
    assert perf.prefill_spike_ms(96, 128) == tm.base_prefill_ms(96)
    assert perf.prefill_spike_ms(1024, 128) < perf.prefill_spike_ms(1024)


# ------------------------------------------------ page-boundary algebra ----

def test_boundary_steps_chunk_boundary_equals_page_boundary():
    """A write position sitting exactly on its claimed prefix's edge has
    zero steps of headroom (the current write needs a claim first); one
    slot earlier has exactly one."""
    assert boundary_steps(16, 1, 16, 4) == 0
    assert boundary_steps(15, 1, 16, 4) == 1
    assert boundary_steps(0, 0, 16, 4) == 0
    assert boundary_steps(17, 2, 16, 4) == 15


def test_boundary_steps_width_one_window():
    """A one-page block table is fully grown after its first claim: the
    ring wraps onto the same page forever, no boundary event exists."""
    assert boundary_steps(0, 1, 16, 1) is None
    assert boundary_steps(19, 1, 16, 1) is None
    assert boundary_steps(5, 0, 16, 1) <= 0     # unclaimed: claim now


def test_pages_for_tokens():
    assert pages_for_tokens(0, 16) == 0
    assert pages_for_tokens(1, 16) == 1
    assert pages_for_tokens(16, 16) == 1
    assert pages_for_tokens(17, 16) == 2
    assert pages_for_tokens(-3, 16) == 0


# ------------------------------------------------- engine (timing plane) ----

def _mk_server(chunk_budget, preempt="recompute", total_pages=None,
               max_batch=4):
    srv = InferenceServer(CFG, mode="cached", numerics=False,
                          max_batch=max_batch, cache_slots=64,
                          memory="paged", page_size=16, preempt=preempt,
                          total_pages=total_pages,
                          chunk_budget=chunk_budget)
    srv.register_adapter(AdapterSpec("ad0", rank=16, base_model=CFG.name))
    return srv


def _req(rid, prompt_len, max_new, arrival=0.0):
    prompt = np.arange(prompt_len, dtype=np.int32) % 100
    return Request(rid=rid, adapter_uid="ad0", prompt=prompt,
                   max_new_tokens=max_new, arrival_ms=arrival)


def _drain(srv, max_iters=400):
    it = 0
    while (srv.busy() or srv.queue) and it < max_iters:
        srv.step()
        it += 1
    assert it < max_iters, "server failed to drain"


def test_chunk_claims_pages_chunk_by_chunk():
    """Admission claims only the first chunk's page; each later chunk
    claims its own page just before its KV lands (chunk boundary ==
    page boundary here: chunk_budget == page_size == 16)."""
    srv = _mk_server(chunk_budget=16)
    st = srv.submit(_req(0, 48, 2))
    want = [(16, 1), (32, 2), (48, 3)]
    for pos, n_pages in want:
        srv.step()
        assert st.prefill_pos == pos
        assert len(srv.admission.row_pages[st.row]) == n_pages
        assert st.phase == ("prefill" if pos < 48 else "decode")
    assert st.first_token_ms is not None
    assert len(st.token_times_ms) == 1          # final chunk sampled token 1
    _drain(srv)
    assert len(st.generated) == 2
    assert st.prefill_pos == 48


def test_prompt_shorter_than_chunk_budget_goes_monolithic():
    """chunk_budget longer than the prompt: the request takes the plain
    monolithic admission path (prefill_pos jumps to prompt_len in one
    shot, no prefill phase is ever visible)."""
    srv = _mk_server(chunk_budget=64)
    st = srv.submit(_req(0, 24, 3))
    srv.step()
    assert st.prefill_pos == 24
    assert st.phase != "prefill"
    assert st.first_token_ms is not None
    _drain(srv)
    assert len(st.generated) == 3


def test_monolithic_admission_prefill_pos_invariant():
    """chunk_budget=0 (and any chunked run, once drained): every admitted
    request ends with prefill_pos == prompt_len."""
    for cb in (0, 16):
        srv = _mk_server(chunk_budget=cb)
        for i, pl in enumerate((24, 40, 61)):
            srv.submit(_req(i, pl, 2))
        _drain(srv)
        for st in srv.states:
            assert st.prefill_pos == st.req.prompt_len, (cb, st.req.rid)
            assert len(st.generated) == 2


def test_megastep_treats_inflight_chunk_as_boundary():
    """_plan_megastep must refuse to fuse decode iterations while any live
    row is mid-chunked-prefill (each iteration may carry a chunk), and
    fuse again once the prefill completes."""
    srv = _mk_server(chunk_budget=16)
    # a timing-only server has no backend; megastep *planning* only reads
    # pipeline/megastep_max from it, so a stub is attached just for the
    # direct _plan_megastep calls (steps run backend-less as usual)
    stub = types.SimpleNamespace(pipeline="fused", megastep_max=8)
    decoding = srv.submit(_req(0, 8, 8))
    srv.step()                                   # rid 0 admitted, decoding
    assert decoding.phase == "decode"
    # prompt 40 (not page-aligned): after prefill the row has decode
    # headroom inside its claimed pages, so only the in-flight chunk —
    # not a boundary claim — can block fusion below
    chunking = srv.submit(_req(1, 40, 4, arrival=srv.clock))
    srv.step()                                   # rid 1 admitted + chunk 1
    assert chunking.phase == "prefill"
    assert 0 < chunking.prefill_pos < 40
    srv.backend = stub
    assert srv._plan_megastep([decoding], None) is None
    # finish the prefill (timing plane): the boundary condition lifts
    srv.backend = None
    while chunking.phase == "prefill":
        srv.step()
    srv.backend = stub
    live = [r for r in srv.admission.rows if r is not None and not r.done]
    plan = srv._plan_megastep(live, None)
    assert plan is not None
    K, nsteps, per_iter = plan
    assert K >= 2 and len(per_iter) == K


def test_preempt_half_prefilled_swap_preserves_chunk_progress():
    """Swap-preempting a row mid-chunked-prefill keeps prefill_pos: the
    resume restores the written chunk pages and chunking continues where
    it left off instead of replaying the prompt."""
    srv = _mk_server(chunk_budget=16, preempt="swap")
    st = srv.submit(_req(0, 48, 3))
    srv.step()
    srv.step()
    assert st.phase == "prefill" and st.prefill_pos == 32
    srv._preempt(st)
    assert st.resume_kind == "swap"
    assert st.preempted
    assert st.prefill_pos == 32                  # chunk progress survives
    assert st.row == -1 and st.phase == "queued"
    assert srv.queue[0] is st
    _drain(srv)
    assert st.preemptions == 1
    assert srv.preempt_stats["swap_preemptions"] == 1
    assert st.prefill_pos == 48
    assert len(st.generated) == 3


def test_preempt_half_prefilled_recompute_restarts_chunking():
    """Recompute-preempting a half-prefilled row drops its chunk prefix:
    it re-enters as a *fresh* chunked admission (no resume state) and
    still completes."""
    srv = _mk_server(chunk_budget=16, preempt="recompute")
    st = srv.submit(_req(0, 48, 3))
    srv.step()
    assert st.phase == "prefill" and st.prefill_pos == 16
    srv._preempt(st)
    assert st.prefill_pos == 0                   # nothing survives
    assert not st.preempted and st.resume_kind == ""
    assert st.preemptions == 1
    _drain(srv)
    assert st.prefill_pos == 48
    assert len(st.generated) == 3


# ------------------------------------------------------ routing term ----

def _stats(**kw):
    base = dict(running_ranks=[16, 16, 16, 16], queued_ranks=[],
                hosts_adapter=True, free_rows=4, n_requests=4)
    base.update(kw)
    return ServerStats(**base)


def test_calc_cost_decode_commitment_term():
    """Deeper decode commitment -> higher routing cost for a long prompt
    (its prefill spikes stall more outstanding tokens); prefill_tokens=0
    and an idle server are exempt."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    args = (16, perf, None, 64.0)

    def cost(prefill_tokens, **kw):
        return calc_cost(args[0], _stats(**kw), perf, args[2], args[3],
                         prefill_tokens=prefill_tokens)

    c0 = cost(1024, decode_commit_tokens=0)
    c_mid = cost(1024, decode_commit_tokens=2)
    c_deep = cost(1024, decode_commit_tokens=1024)
    assert c0 < c_mid <= c_deep
    # no prefill tokens (or no resident batch): the term contributes zero
    assert cost(0, decode_commit_tokens=1024) == cost(0,
                                                      decode_commit_tokens=0)
    idle = calc_cost(16, _stats(running_ranks=[],
                                decode_commit_tokens=1024),
                     perf, None, 64.0, prefill_tokens=1024)
    idle0 = calc_cost(16, _stats(running_ranks=[], decode_commit_tokens=0),
                      perf, None, 64.0, prefill_tokens=1024)
    assert idle == idle0


def test_calc_cost_chunked_server_has_smaller_spike():
    """A chunking server's interference spike per iteration is one chunk,
    not the whole prompt — with equal shallow commitment it routes
    cheaper than the monolithic server for a long prompt."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    mono = calc_cost(16, _stats(decode_commit_tokens=2, chunk_budget=0),
                     perf, None, 64.0, prefill_tokens=2048)
    chunked = calc_cost(16, _stats(decode_commit_tokens=2,
                                   chunk_budget=128),
                        perf, None, 64.0, prefill_tokens=2048)
    assert chunked < mono


# ------------------------------------------------------------- metrics ----

def test_itl_samples_and_percentiles():
    st = RequestState(req=_req(0, 4, 3))
    st.token_times_ms = [10.0, 12.0, 16.0]
    assert st.itl_ms() == [2.0, 4.0]
    p = itl_percentiles([2.0, 4.0])
    assert p["n_gaps"] == 2 and p["itl_mean_ms"] == 3.0
    assert p["itl_p50_ms"] == 3.0
    empty = itl_percentiles([])
    assert empty == {"n_gaps": 0, "itl_mean_ms": 0.0,
                     "itl_p50_ms": 0.0, "itl_p99_ms": 0.0}


def test_server_itl_stats_pool_gaps():
    srv = _mk_server(chunk_budget=16)
    for i, pl in enumerate((48, 24)):
        srv.submit(_req(i, pl, 4))
    _drain(srv)
    samples = srv.itl_samples()
    assert len(samples) == sum(len(s.token_times_ms) - 1
                               for s in srv.states)
    stats = srv.itl_stats()
    assert stats["n_gaps"] == len(samples)
    assert stats["itl_p99_ms"] >= stats["itl_p50_ms"] > 0.0
