"""Async cold-start plane: LoadTracker link contention, deterministic
completion ordering, in-flight slot reservation, mid-flight CPU-assist ->
device flips, and event-driven vs lockstep cluster parity."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.cold_start import ColdStartManager, LoadTracker
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.core.timing import TimingModel
from repro.serving.request import Request
from repro.traces import gen

CFG = get_config("llama2-7b")


def mk_tracker(concurrency=None):
    return LoadTracker(TimingModel(CFG), concurrency=concurrency)


def adapter_bytes(rank=64):
    return AdapterSpec("x", rank, CFG.name).nbytes(CFG)


# ------------------------------------------------------------ tracker ----

def test_concurrent_loads_share_link():
    """K simultaneous uploads serialize on load_bw: the last finish time
    grows linearly with K and each upload keeps its solo duration."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    last = []
    for k in (1, 2, 4, 8):
        tr = mk_tracker()
        evs = [tr.begin(f"u{i}", i, nb, 0.0) for i in range(k)]
        assert all(e.finish_ms - e.start_ms == pytest.approx(solo)
                   for e in evs)
        last.append(max(e.finish_ms for e in evs))
    assert last == sorted(last)
    assert last[-1] == pytest.approx(8 * solo)
    assert last[0] == pytest.approx(solo)


def test_load_concurrency_lanes():
    """Two link lanes halve the makespan of an even upload batch."""
    nb = adapter_bytes()
    tr1, tr2 = mk_tracker(1), mk_tracker(2)
    f1 = max(tr1.begin(f"u{i}", i, nb, 0.0).finish_ms for i in range(4))
    f2 = max(tr2.begin(f"u{i}", i, nb, 0.0).finish_ms for i in range(4))
    assert f2 == pytest.approx(f1 / 2)


def test_completion_order_deterministic():
    """Ties on finish time retire in begin order (seq), repeatably."""
    nb = adapter_bytes()
    orders = []
    for _ in range(3):
        tr = mk_tracker(concurrency=4)       # 4 lanes -> 4 equal finishes
        for i in range(4):
            tr.begin(f"u{i}", i, nb, 0.0)
        done = tr.complete_until(1e9)
        orders.append([e.uid for e in done])
        assert not tr.inflight
    assert orders[0] == [f"u{i}" for i in range(4)]
    assert orders.count(orders[0]) == 3


def test_partial_completion_and_link_busy():
    nb = adapter_bytes()
    tr = mk_tracker()
    e0 = tr.begin("a", 0, nb, 0.0)
    e1 = tr.begin("b", 1, nb, 0.0)
    assert tr.link_busy_until_ms() == pytest.approx(e1.finish_ms)
    done = tr.complete_until(e0.finish_ms)
    assert [e.uid for e in done] == ["a"]
    assert tr.pending_for("b") is e1
    assert tr.next_finish_ms() == pytest.approx(e1.finish_ms)


# ------------------------------------------------- slot reservation ----

def test_inflight_slot_not_evictable():
    store = HostLoRAStore(CFG)
    pool = DevicePool(CFG, n_slots=2, materialize=False)
    for u in ("a", "b", "c"):
        store.register(AdapterSpec(u, 64, CFG.name), materialize=False)
    mgr = ColdStartManager(TimingModel(CFG), store, pool, "caraserve")
    mgr.admit("a", 0.0, 128)
    mgr.admit("b", 0.0, 128)
    assert sorted(pool.inflight_slots()) == [0, 1]
    # both slots mid-upload: a third cold start must be deferred, not evict
    assert mgr.admit("c", 0.0, 128) is None
    # after the uploads land the pool becomes evictable again
    mgr.poll(1e9)
    assert pool.inflight_slots() == []
    assert mgr.admit("c", 1e9, 128) is not None


def test_same_adapter_concurrent_requests_share_upload():
    """Second request for a cold adapter rides the first one's upload: no
    second transfer, decode gated on the shared finish time."""
    store = HostLoRAStore(CFG)
    pool = DevicePool(CFG, n_slots=4, materialize=False)
    store.register(AdapterSpec("u", 64, CFG.name), materialize=False)
    mgr = ColdStartManager(TimingModel(CFG), store, pool, "caraserve")
    p1 = mgr.admit("u", 0.0, 128)
    p2 = mgr.admit("u", 1.0, 128)
    assert p1.cold and not p2.cold
    assert len(mgr.tracker.inflight) == 1
    assert p2.load_finish_ms == pytest.approx(p1.load_finish_ms)
    assert p2.ready_decode_ms >= p1.load_finish_ms - 1e-9


# ------------------------------------------------------- engine-level ----

def _cold_burst(mode, k, rank=64):
    srv = InferenceServer(CFG, mode=mode, max_batch=16, numerics=False)
    for i in range(k):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank, CFG.name))
    reqs = [Request(rid=i, adapter_uid=f"ad{i}",
                    prompt=np.zeros(128, np.int32), max_new_tokens=4,
                    arrival_ms=0.0) for i in range(k)]
    return srv, srv.run(reqs)


def test_ttft_monotone_in_simultaneous_cold_starts():
    """Link contention is modeled: mean TTFT of K simultaneous cold starts
    is monotonically non-decreasing in K under caraserve."""
    means = [_cold_burst("caraserve", k)[1]["ttft_mean"]
             for k in (1, 2, 4, 8)]
    assert all(a <= b + 1e-9 for a, b in zip(means, means[1:]))


def test_cached_ttft_matches_analytic_oracle():
    """CACHED never touches the link: TTFT of the i-th of K simultaneous
    requests is exactly the i serial prefills (seed-identical timeline)."""
    tm = TimingModel(CFG)
    pre = tm.base_prefill_ms(128) + tm.lora_prefill_gpu_ms(128, 64)
    for k in (1, 4):
        srv, out = _cold_burst("cached", k)
        want = pre * (np.arange(k) + 1)
        got = sorted(s.ttft_ms() for s in srv.states)
        np.testing.assert_allclose(got, want, rtol=1e-9)


def test_decode_waits_for_upload_and_flips():
    """caraserve: the first decode token cannot precede the upload finish,
    and the load-complete event flips the request to the device pool."""
    srv, out = _cold_burst("caraserve", 2)
    assert out["flipped"] == 2
    for st in srv.states:
        assert st.load_finish_ms is not None
        assert st.flip_ms == pytest.approx(st.load_finish_ms)
        # token 0 is the prefill's; decode tokens follow the upload
        assert st.token_times_ms[1] >= st.load_finish_ms - 1e-9


def test_ondemand_ttft_counts_load_once():
    """TTFT of a lone ONDMD cold start is exactly load + base prefill +
    device LoRA prefill — the blocking load is not double-counted into the
    iteration on top of the plan's first-token latency."""
    tm = TimingModel(CFG)
    want = tm.load_ms(adapter_bytes()) + tm.base_prefill_ms(128) \
        + tm.lora_prefill_gpu_ms(128, 64)
    srv, out = _cold_burst("ondemand", 1)
    assert out["ttft_mean"] == pytest.approx(want)


def test_prefetch_uploads_not_reported_as_cold_starts():
    """Speculative prefetch occupies the link but has no request attached:
    it must not appear in loading_ranks (scheduler's decode-batch view)."""
    srv = InferenceServer(CFG, mode="caraserve", max_batch=4, numerics=False,
                          prefetch=True, pool_slots=4)
    srv.register_adapter(AdapterSpec("hot", 64, CFG.name))
    ev = srv.cold.load_async("hot", 0.0, demand=False)
    assert ev is not None and not ev.demand
    assert srv.loading_ranks() == []
    assert srv.link_busy_ms() > 0.0


def test_ondemand_blocking_includes_link_queueing():
    """Under ONDMD the K-th cold start waits out K-1 uploads before its own
    blocking load (paper Fig 2 made contention-aware)."""
    tm = TimingModel(CFG)
    load = tm.load_ms(adapter_bytes())
    srv, _ = _cold_burst("ondemand", 4)
    last = max(s.ttft_ms() for s in srv.states)
    assert last >= 4 * load - 1e-6


def test_router_prefers_server_already_uploading_adapter():
    """A request whose adapter is mid-upload on server A rides that upload
    for free; calc_cost must not charge A a second transfer, so the
    rank-aware router picks A over an equally-loaded fresh server."""
    from repro.core.scheduler import RankAwareScheduler, ServerStats
    perf = ServerPerfModel(CFG, kernel="bgmv")
    load = perf.load_perf(64)
    uploading = ServerStats([64], [], True, 7, 1, loading_ranks=[64],
                            link_busy_ms=load / 2, adapter_ready=False,
                            adapter_loading=True)
    fresh = ServerStats([64], [], True, 7, 1, adapter_ready=False)
    s = RankAwareScheduler(perf, slo_ms=None)
    assert s.route(64, [fresh, uploading]) == 1


# ------------------------------------------------------ cluster parity ----

def _cluster(engine, adapters, perf, mode="caraserve"):
    servers = []
    for _ in range(4):
        s = InferenceServer(CFG, mode=mode, kernel="bgmv", max_batch=8,
                            numerics=False)
        for ad in adapters:
            s.register_adapter(ad)
        servers.append(s)
    return Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=None),
                   engine=engine)


def test_event_cluster_matches_lockstep_metrics():
    """The event-driven simulator reproduces the lockstep oracle's summary
    metrics on a fixed trace (within 1%; typically exact)."""
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(16, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    reqs = gen.maf_trace(adapters, rps=30, duration_s=5, vocab=100, seed=1)
    out_e, states_e = _cluster("events", adapters, perf).run(reqs)
    out_l, states_l = _cluster("lockstep", adapters, perf).run(reqs)
    assert out_e["n"] == out_l["n"] == len(reqs)
    assert out_e["cold_starts"] == out_l["cold_starts"]
    for k in ("ttft_mean", "tpt_mean", "latency_mean", "ttft_p99"):
        assert out_e[k] == pytest.approx(out_l[k], rel=0.01), k


def test_event_cluster_deterministic_and_counts_event_kinds():
    rng = np.random.default_rng(3)
    adapters = gen.make_adapters(8, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    reqs = gen.maf_trace(adapters, rps=20, duration_s=3, vocab=100, seed=2)
    cl1 = _cluster("events", adapters, perf)
    cl2 = _cluster("events", adapters, perf)
    out1, _ = cl1.run(reqs)
    out2, _ = cl2.run(reqs)
    assert out1 == out2
    assert cl1.event_counts == cl2.event_counts
    assert cl1.event_counts["arrival"] == len(reqs)
    assert cl1.event_counts["iter"] > 0
