"""Async cold-start plane: LoadTracker link contention, deterministic
completion ordering, in-flight slot reservation, mid-flight CPU-assist ->
device flips, event-driven vs lockstep cluster parity, and the priority-
aware link scheduler (fifo/priority/preempt policies, demand promotion,
prefetch preemption, per-class telemetry)."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.cold_start import (CLS_DEMAND, CLS_PREFETCH, CLS_PROMOTED,
                                   ColdStartManager, LoadTracker)
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.core.timing import TimingModel
from repro.serving.request import Request
from repro.traces import gen

CFG = get_config("llama2-7b")


def mk_tracker(concurrency=None, policy="fifo"):
    return LoadTracker(TimingModel(CFG), concurrency=concurrency,
                       policy=policy)


def adapter_bytes(rank=64):
    return AdapterSpec("x", rank, CFG.name).nbytes(CFG)


# ------------------------------------------------------------ tracker ----

def test_concurrent_loads_share_link():
    """K simultaneous uploads serialize on load_bw: the last finish time
    grows linearly with K and each upload keeps its solo duration."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    last = []
    for k in (1, 2, 4, 8):
        tr = mk_tracker()
        evs = [tr.begin(f"u{i}", i, nb, 0.0) for i in range(k)]
        assert all(e.finish_ms - e.start_ms == pytest.approx(solo)
                   for e in evs)
        last.append(max(e.finish_ms for e in evs))
    assert last == sorted(last)
    assert last[-1] == pytest.approx(8 * solo)
    assert last[0] == pytest.approx(solo)


def test_load_concurrency_lanes():
    """Two link lanes halve the makespan of an even upload batch."""
    nb = adapter_bytes()
    tr1, tr2 = mk_tracker(1), mk_tracker(2)
    f1 = max(tr1.begin(f"u{i}", i, nb, 0.0).finish_ms for i in range(4))
    f2 = max(tr2.begin(f"u{i}", i, nb, 0.0).finish_ms for i in range(4))
    assert f2 == pytest.approx(f1 / 2)


def test_completion_order_deterministic():
    """Ties on finish time retire in begin order (seq), repeatably."""
    nb = adapter_bytes()
    orders = []
    for _ in range(3):
        tr = mk_tracker(concurrency=4)       # 4 lanes -> 4 equal finishes
        for i in range(4):
            tr.begin(f"u{i}", i, nb, 0.0)
        done = tr.complete_until(1e9)
        orders.append([e.uid for e in done])
        assert not tr.inflight
    assert orders[0] == [f"u{i}" for i in range(4)]
    assert orders.count(orders[0]) == 3


def test_partial_completion_and_link_busy():
    nb = adapter_bytes()
    tr = mk_tracker()
    e0 = tr.begin("a", 0, nb, 0.0)
    e1 = tr.begin("b", 1, nb, 0.0)
    assert tr.link_busy_until_ms() == pytest.approx(e1.finish_ms)
    done = tr.complete_until(e0.finish_ms)
    assert [e.uid for e in done] == ["a"]
    assert tr.pending_for("b") is e1
    assert tr.next_finish_ms() == pytest.approx(e1.finish_ms)


# ---------------------------------------------------- link scheduler ----

def test_link_busy_earliest_free_lane_multilane():
    """link_busy_until_ms is the earliest-free-lane delay: with a second
    idle lane a single running upload imposes no queueing at all, and a
    third upload queues only until the *first* lane drains (the old
    max-over-lanes answer said 2x)."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    tr = mk_tracker(concurrency=2)
    tr.begin("a", 0, nb, 0.0)
    assert tr.link_busy_until_ms() == pytest.approx(0.0)  # lane 1 idle
    tr.begin("b", 1, nb, 0.0)
    assert tr.link_busy_until_ms() == pytest.approx(solo)
    tr.begin("c", 2, nb, 0.0)                             # queued
    assert tr.link_busy_until_ms() == pytest.approx(solo)  # other lane
    tr.begin("d", 3, nb, 0.0)
    assert tr.link_busy_until_ms() == pytest.approx(2 * solo)


def test_multilane_assignment_and_completion_order():
    """Queued uploads take the earliest-freeing lane; retirement stays in
    deterministic (finish, begin-seq) order."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    tr = mk_tracker(concurrency=2)
    evs = [tr.begin(f"u{i}", i, nb, 0.0) for i in range(4)]
    done = tr.complete_until(1e9)
    assert [e.uid for e in done] == ["u0", "u1", "u2", "u3"]
    assert [e.finish_ms for e in evs] == pytest.approx(
        [solo, solo, 2 * solo, 2 * solo])


def test_priority_demand_jumps_queued_prefetch():
    """Queued (not-yet-started) prefetch uploads never delay a demand
    upload under `priority`; under `fifo` the demand waits out the whole
    speculative queue (and the delayed-by-prefetch counter records it)."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    res = {}
    for policy in ("fifo", "priority"):
        tr = mk_tracker(policy=policy)
        for i in range(3):                      # 1 running + 2 queued
            tr.begin(f"p{i}", i, nb, 0.0, demand=False)
        d = tr.begin("d", 3, nb, 1.0, demand=True)
        res[policy] = (d.finish_ms, tr.stats["demand_delayed_by_prefetch"])
    assert res["fifo"][0] == pytest.approx(4 * solo)
    assert res["priority"][0] == pytest.approx(2 * solo)  # behind p0 only
    assert res["fifo"][1] == 1 and res["priority"][1] == 0


def test_priority_pushes_queued_prefetch_back():
    """The jumped prefetches' provisional finish times are recomputed on
    the demand insertion (stale begin()-time stamps would be wrong)."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    tr = mk_tracker(policy="priority")
    ps = [tr.begin(f"p{i}", i, nb, 0.0, demand=False) for i in range(2)]
    before = ps[1].finish_ms
    tr.begin("d", 2, nb, 1.0, demand=True)
    assert ps[1].finish_ms == pytest.approx(before + solo)
    assert tr.next_finish_ms() == pytest.approx(ps[0].finish_ms)


def test_started_prefetch_never_aborted():
    """Preemption only touches queued uploads: a started prefetch runs to
    completion even under `preempt`."""
    nb = adapter_bytes()
    tr = mk_tracker(policy="preempt")
    p0 = tr.begin("p0", 0, nb, 0.0, demand=False)      # started
    p1 = tr.begin("p1", 1, nb, 0.0, demand=False)      # queued
    assert p0.started and not p1.started
    canceled = tr.cancel_queued_prefetch()
    assert [e.uid for e in canceled] == ["p1"] and p1.canceled
    assert [e.uid for e in tr.inflight] == ["p0"]
    assert tr.stats["preempted"] == 1


def test_preempt_demand_reclaims_queued_prefetch_slot():
    """A demand cold start blocked only by *queued* speculative
    reservations reclaims device slots: `priority` cancels one prefetch at
    a time (last-scheduled first — earlier speculative work survives),
    `preempt` cancels the whole speculative queue, `fifo` defers the
    admission. Started uploads are never touched."""
    def mk_mgr(policy):
        store = HostLoRAStore(CFG)
        pool = DevicePool(CFG, n_slots=3, materialize=False)
        for u in ("a", "b", "c", "d"):
            store.register(AdapterSpec(u, 64, CFG.name), materialize=False)
        mgr = ColdStartManager(TimingModel(CFG), store, pool, "caraserve",
                               link_policy=policy)
        mgr.load_async("a", 0.0, demand=False)     # started, slot 0
        mgr.load_async("b", 0.0, demand=False)     # queued, slot 1
        mgr.load_async("c", 0.0, demand=False)     # queued, slot 2
        return mgr, pool

    mgr, pool = mk_mgr("priority")                 # minimal reclaim
    plan = mgr.admit("d", 1.0, 128)
    assert plan is not None and plan.cold
    assert "c" not in pool.slot_uid                # last-scheduled canceled
    assert "b" in pool.slot_uid                    # earlier prefetch kept
    assert "a" in pool.slot_uid                    # started upload survives
    assert mgr.tracker.pending_for("b") is not None
    assert mgr.tracker.stats["preempted"] == 1

    mgr, pool = mk_mgr("preempt")                  # whole queue canceled
    plan = mgr.admit("d", 1.0, 128)
    assert plan is not None and plan.cold
    assert "b" not in pool.slot_uid and "c" not in pool.slot_uid
    assert "a" in pool.slot_uid
    assert mgr.tracker.stats["preempted"] == 2

    mgr, pool = mk_mgr("fifo")
    assert mgr.admit("d", 1.0, 128) is None        # defer: all slots held
    assert sorted(pool.slot_uid) == ["a", "b", "c"]


def test_demand_admit_promotes_inflight_prefetch():
    """A demand admission that finds its adapter mid-prefetch promotes the
    upload to demand class (CLS_PROMOTED) — link policies and telemetry see
    a demand upload, and the plan gates on the promoted finish time."""
    store = HostLoRAStore(CFG)
    pool = DevicePool(CFG, n_slots=4, materialize=False)
    store.register(AdapterSpec("u", 64, CFG.name), materialize=False)
    mgr = ColdStartManager(TimingModel(CFG), store, pool, "caraserve",
                           link_policy="priority")
    ev = mgr.load_async("u", 0.0, demand=False)
    assert not ev.demand and ev.cls == CLS_PREFETCH
    plan = mgr.admit("u", 1.0, 128)
    assert ev.demand and ev.cls == CLS_PROMOTED
    assert mgr.tracker.stats["promoted"] == 1
    assert not plan.cold and plan.assist
    assert plan.load_finish_ms == pytest.approx(ev.finish_ms)


def test_promotion_jumps_queue_under_priority():
    """A queued promoted upload overtakes the remaining speculative queue
    (demand > promoted > prefetch), and both finishes are recomputed."""
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    tr = mk_tracker(policy="priority")
    tr.begin("d0", 0, nb, 0.0, demand=True)            # running
    pa = tr.begin("pa", 1, nb, 0.0, demand=False)      # queued
    pb = tr.begin("pb", 2, nb, 0.0, demand=False)      # queued behind pa
    assert pb.finish_ms == pytest.approx(3 * solo)
    tr.promote("pb", 1.0)
    assert pb.cls == CLS_PROMOTED and pb.demand
    assert pb.finish_ms == pytest.approx(2 * solo)
    assert pa.finish_ms == pytest.approx(3 * solo)
    # a later plain demand still jumps the promoted upload
    d1 = tr.begin("d1", 3, nb, 2.0, demand=True)
    assert d1.finish_ms == pytest.approx(2 * solo)
    assert pb.finish_ms == pytest.approx(3 * solo)


def test_fifo_ignores_classes():
    """The legacy policy: begin order rules regardless of class (parity
    oracle for the pre-scheduler lane model)."""
    nb = adapter_bytes()
    tr = mk_tracker(policy="fifo")
    evs = [tr.begin(f"u{i}", i, nb, 0.0, demand=(i % 2 == 0))
           for i in range(4)]
    tr.promote("u1", 0.5)                 # class changes, order does not
    fins = [e.finish_ms for e in evs]
    assert fins == sorted(fins)
    done = tr.complete_until(1e9)
    assert [e.uid for e in done] == [f"u{i}" for i in range(4)]


def test_per_class_busy_and_queue_delay_telemetry():
    nb = adapter_bytes()
    solo = TimingModel(CFG).load_ms(nb)
    tr = mk_tracker(policy="priority")
    tr.begin("p0", 0, nb, 0.0, demand=False)           # running
    tr.begin("p1", 1, nb, 0.0, demand=False)           # queued
    cb = tr.class_busy_ms(0.0)
    assert cb[CLS_PREFETCH] == pytest.approx(2 * solo)
    assert cb[CLS_DEMAND] == 0.0 and cb[CLS_PROMOTED] == 0.0
    assert tr.demand_busy_ms(0.0) == 0.0
    assert tr.prefetch_busy_ms(0.0) == pytest.approx(2 * solo)
    # a new demand upload jumps the queued prefetch; a new prefetch queues
    # behind everything
    assert tr.link_busy_until_ms(CLS_DEMAND) == pytest.approx(solo)
    assert tr.link_busy_until_ms(CLS_PREFETCH) == pytest.approx(2 * solo)
    tr.begin("d", 2, nb, 0.0, demand=True)
    assert tr.demand_busy_ms(0.0) == pytest.approx(solo)
    # mid-transfer: the running upload's remaining occupancy shrinks
    assert tr.class_busy_ms(solo / 2)[CLS_PREFETCH] == \
        pytest.approx(1.5 * solo)


def test_prefetch_backs_off_while_demand_on_link():
    """The prefetcher never starts speculative uploads while demand-class
    traffic owns the link (it would queue ahead of the next cold start
    under fifo); it resumes once the demand upload lands."""
    srv = InferenceServer(CFG, mode="caraserve", max_batch=4, numerics=False,
                          prefetch=True, pool_slots=4)
    for u in ("cold", "hot"):
        srv.register_adapter(AdapterSpec(u, 64, CFG.name))
    ev = srv.cold.load_async("cold", 0.0, demand=True)
    srv.admission._popularity = {"hot": 5.0}
    srv.admission.prefetch_tick(0.0)
    assert srv.cold.tracker.pending_for("hot") is None   # backed off
    srv.cold.poll(ev.finish_ms)                          # demand lands
    srv.admission.prefetch_tick(ev.finish_ms)
    assert srv.cold.tracker.pending_for("hot") is not None


def test_ready_gate_tracks_rescheduled_upload():
    """Priority policy end-to-end: a request riding a *promoted* prefetch
    is later jumped by a fresh demand upload — the engine must re-derive
    its decode gate from the recomputed finish time (a stale admit()-time
    stamp would let it decode before its adapter landed)."""
    srv = InferenceServer(CFG, mode="caraserve", max_batch=8, numerics=False,
                          pool_slots=8, link_policy="priority")
    for u in ("d0", "a", "d1"):
        srv.register_adapter(AdapterSpec(u, 64, CFG.name))
    srv.cold.load_async("d0", 0.0, demand=True)    # occupies the link
    srv.cold.load_async("a", 0.0, demand=False)    # queued prefetch
    reqs = [Request(rid=0, adapter_uid="a", prompt=np.zeros(64, np.int32),
                    max_new_tokens=4, arrival_ms=1.0),
            Request(rid=1, adapter_uid="d1", prompt=np.zeros(64, np.int32),
                    max_new_tokens=4, arrival_ms=2.0)]
    srv.run(reqs)
    assert srv.cold.tracker.stats["promoted"] == 1
    rider = next(s for s in srv.states if s.req.rid == 0)
    assert rider.flip_ms is not None
    assert rider.load_finish_ms == pytest.approx(rider.flip_ms)
    # no decode token before the (delayed) upload actually landed
    assert rider.token_times_ms[1] >= rider.flip_ms - 1e-9


def test_slora_cold_ttft_policy_ordering():
    """Deterministic end-to-end: a cold start arriving behind a burst of
    speculative uploads pays the full queue under fifo, one upload under
    priority/preempt (S-LoRA loading: the upload is on the TTFT path)."""
    ttft = {}
    for policy in ("fifo", "priority", "preempt"):
        srv = InferenceServer(CFG, mode="slora", max_batch=4, numerics=False,
                              pool_slots=8, link_policy=policy)
        for i in range(4):
            srv.register_adapter(AdapterSpec(f"p{i}", 64, CFG.name))
        srv.register_adapter(AdapterSpec("cold", 64, CFG.name))
        for i in range(4):
            srv.cold.load_async(f"p{i}", 0.0, demand=False)
        out = srv.run([Request(rid=0, adapter_uid="cold",
                               prompt=np.zeros(64, np.int32),
                               max_new_tokens=2, arrival_ms=1.0)])
        ttft[policy] = out["ttft_mean"]
    assert ttft["priority"] < ttft["fifo"]
    assert ttft["preempt"] < ttft["fifo"]
    assert ttft["preempt"] <= ttft["priority"] + 1e-9


# ------------------------------------------------- slot reservation ----

def test_inflight_slot_not_evictable():
    store = HostLoRAStore(CFG)
    pool = DevicePool(CFG, n_slots=2, materialize=False)
    for u in ("a", "b", "c"):
        store.register(AdapterSpec(u, 64, CFG.name), materialize=False)
    mgr = ColdStartManager(TimingModel(CFG), store, pool, "caraserve")
    mgr.admit("a", 0.0, 128)
    mgr.admit("b", 0.0, 128)
    assert sorted(pool.inflight_slots()) == [0, 1]
    # both slots mid-upload: a third cold start must be deferred, not evict
    assert mgr.admit("c", 0.0, 128) is None
    # after the uploads land the pool becomes evictable again
    mgr.poll(1e9)
    assert pool.inflight_slots() == []
    assert mgr.admit("c", 1e9, 128) is not None


def test_same_adapter_concurrent_requests_share_upload():
    """Second request for a cold adapter rides the first one's upload: no
    second transfer, decode gated on the shared finish time."""
    store = HostLoRAStore(CFG)
    pool = DevicePool(CFG, n_slots=4, materialize=False)
    store.register(AdapterSpec("u", 64, CFG.name), materialize=False)
    mgr = ColdStartManager(TimingModel(CFG), store, pool, "caraserve")
    p1 = mgr.admit("u", 0.0, 128)
    p2 = mgr.admit("u", 1.0, 128)
    assert p1.cold and not p2.cold
    assert len(mgr.tracker.inflight) == 1
    assert p2.load_finish_ms == pytest.approx(p1.load_finish_ms)
    assert p2.ready_decode_ms >= p1.load_finish_ms - 1e-9


# ------------------------------------------------------- engine-level ----

def _cold_burst(mode, k, rank=64):
    srv = InferenceServer(CFG, mode=mode, max_batch=16, numerics=False)
    for i in range(k):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank, CFG.name))
    reqs = [Request(rid=i, adapter_uid=f"ad{i}",
                    prompt=np.zeros(128, np.int32), max_new_tokens=4,
                    arrival_ms=0.0) for i in range(k)]
    return srv, srv.run(reqs)


def test_ttft_monotone_in_simultaneous_cold_starts():
    """Link contention is modeled: mean TTFT of K simultaneous cold starts
    is monotonically non-decreasing in K under caraserve."""
    means = [_cold_burst("caraserve", k)[1]["ttft_mean"]
             for k in (1, 2, 4, 8)]
    assert all(a <= b + 1e-9 for a, b in zip(means, means[1:]))


def test_cached_ttft_matches_analytic_oracle():
    """CACHED never touches the link: TTFT of the i-th of K simultaneous
    requests is exactly the i serial prefills (seed-identical timeline)."""
    tm = TimingModel(CFG)
    pre = tm.base_prefill_ms(128) + tm.lora_prefill_gpu_ms(128, 64)
    for k in (1, 4):
        srv, out = _cold_burst("cached", k)
        want = pre * (np.arange(k) + 1)
        got = sorted(s.ttft_ms() for s in srv.states)
        np.testing.assert_allclose(got, want, rtol=1e-9)


def test_decode_waits_for_upload_and_flips():
    """caraserve: the first decode token cannot precede the upload finish,
    and the load-complete event flips the request to the device pool."""
    srv, out = _cold_burst("caraserve", 2)
    assert out["flipped"] == 2
    for st in srv.states:
        assert st.load_finish_ms is not None
        assert st.flip_ms == pytest.approx(st.load_finish_ms)
        # token 0 is the prefill's; decode tokens follow the upload
        assert st.token_times_ms[1] >= st.load_finish_ms - 1e-9


def test_ondemand_ttft_counts_load_once():
    """TTFT of a lone ONDMD cold start is exactly load + base prefill +
    device LoRA prefill — the blocking load is not double-counted into the
    iteration on top of the plan's first-token latency."""
    tm = TimingModel(CFG)
    want = tm.load_ms(adapter_bytes()) + tm.base_prefill_ms(128) \
        + tm.lora_prefill_gpu_ms(128, 64)
    srv, out = _cold_burst("ondemand", 1)
    assert out["ttft_mean"] == pytest.approx(want)


def test_prefetch_uploads_not_reported_as_cold_starts():
    """Speculative prefetch occupies the link but has no request attached:
    it must not appear in loading_ranks (scheduler's decode-batch view)."""
    srv = InferenceServer(CFG, mode="caraserve", max_batch=4, numerics=False,
                          prefetch=True, pool_slots=4)
    srv.register_adapter(AdapterSpec("hot", 64, CFG.name))
    ev = srv.cold.load_async("hot", 0.0, demand=False)
    assert ev is not None and not ev.demand
    assert srv.loading_ranks() == []
    assert srv.link_busy_ms() > 0.0


def test_ondemand_blocking_includes_link_queueing():
    """Under ONDMD the K-th cold start waits out K-1 uploads before its own
    blocking load (paper Fig 2 made contention-aware)."""
    tm = TimingModel(CFG)
    load = tm.load_ms(adapter_bytes())
    srv, _ = _cold_burst("ondemand", 4)
    last = max(s.ttft_ms() for s in srv.states)
    assert last >= 4 * load - 1e-6


def test_router_prefers_server_already_uploading_adapter():
    """A request whose adapter is mid-upload on server A rides that upload
    for free; calc_cost must not charge A a second transfer, so the
    rank-aware router picks A over an equally-loaded fresh server."""
    from repro.core.scheduler import RankAwareScheduler, ServerStats
    perf = ServerPerfModel(CFG, kernel="bgmv")
    load = perf.load_perf(64)
    uploading = ServerStats([64], [], True, 7, 1, loading_ranks=[64],
                            link_busy_ms=load / 2, adapter_ready=False,
                            adapter_loading=True)
    fresh = ServerStats([64], [], True, 7, 1, adapter_ready=False)
    s = RankAwareScheduler(perf, slo_ms=None)
    assert s.route(64, [fresh, uploading]) == 1


# ------------------------------------------------------ cluster parity ----

def _cluster(engine, adapters, perf, mode="caraserve"):
    servers = []
    for _ in range(4):
        s = InferenceServer(CFG, mode=mode, kernel="bgmv", max_batch=8,
                            numerics=False)
        for ad in adapters:
            s.register_adapter(ad)
        servers.append(s)
    return Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=None),
                   engine=engine)


def test_event_cluster_matches_lockstep_metrics():
    """The event-driven simulator reproduces the lockstep oracle's summary
    metrics on a fixed trace (within 1%; typically exact)."""
    rng = np.random.default_rng(0)
    adapters = gen.make_adapters(16, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    reqs = gen.maf_trace(adapters, rps=30, duration_s=5, vocab=100, seed=1)
    out_e, states_e = _cluster("events", adapters, perf).run(reqs)
    out_l, states_l = _cluster("lockstep", adapters, perf).run(reqs)
    assert out_e["n"] == out_l["n"] == len(reqs)
    assert out_e["cold_starts"] == out_l["cold_starts"]
    for k in ("ttft_mean", "tpt_mean", "latency_mean", "ttft_p99"):
        assert out_e[k] == pytest.approx(out_l[k], rel=0.01), k


def test_event_cluster_deterministic_and_counts_event_kinds():
    rng = np.random.default_rng(3)
    adapters = gen.make_adapters(8, CFG.name, rng)
    perf = ServerPerfModel(CFG, kernel="bgmv")
    reqs = gen.maf_trace(adapters, rps=20, duration_s=3, vocab=100, seed=2)
    cl1 = _cluster("events", adapters, perf)
    cl2 = _cluster("events", adapters, perf)
    out1, _ = cl1.run(reqs)
    out2, _ = cl2.run(reqs)
    assert out1 == out2
    assert cl1.event_counts == cl2.event_counts
    assert cl1.event_counts["arrival"] == len(reqs)
    assert cl1.event_counts["iter"] > 0
