"""Training substrate: AdamW vs numpy reference, schedules, loss decrease,
grad-accum equivalence, LoRA training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, packed_batches
from repro.models import model
from repro.models.param import split
from repro.training import optim, train


def test_adamw_matches_numpy_reference():
    cfg = optim.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, clip_norm=None,
                            warmup_steps=0, total_steps=10 ** 9,
                            min_lr_ratio=1.0)
    p = {"w": jnp.array([[1.0, -2.0]])}
    g = {"w": jnp.array([[0.5, 0.3]])}
    state = optim.init(p)
    p1, state, _ = optim.apply(cfg, p, g, state)
    # numpy reference, step 1
    mu = 0.1 * np.array([[0.5, 0.3]])
    nu = 0.01 * np.array([[0.25, 0.09]])
    mhat = mu / (1 - 0.9)
    vhat = nu / (1 - 0.99)
    want = np.array([[1.0, -2.0]]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, atol=1e-6)


def test_clip_and_schedule():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            clip_norm=1.0)
    assert float(optim.schedule(cfg, jnp.array(0))) == 0.0
    assert float(optim.schedule(cfg, jnp.array(10))) == pytest.approx(1.0)
    assert float(optim.schedule(cfg, jnp.array(100))) == pytest.approx(
        cfg.min_lr_ratio)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, stats = optim.apply(cfg, p, g, optim.init(p))
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_grad_accum_equivalence():
    """accum=2 on batch 4 == accum=1 (same total gradient)."""
    cfg = get_config("llama2-7b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    ocfg = optim.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                             clip_norm=None, weight_decay=0.0)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab),
             "loss_mask": jnp.ones((4, 16), jnp.int32)}
    outs = []
    for accum in (1, 2):
        step = jax.jit(train.make_train_step(cfg, ocfg, accum=accum))
        p2, _, m = step(params, optim.init(params), batch)
        outs.append((p2, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-5)
    # Adam normalizes by sqrt(v): tiny fp reassociation diffs in the summed
    # grads get amplified for near-zero entries -> tolerance reflects that
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_loss_decreases_dense():
    cfg = get_config("llama2-7b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    ocfg = optim.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=500,
                             weight_decay=0.0)
    state = optim.init(params)
    step = jax.jit(train.make_train_step(cfg, ocfg, accum=1))
    it = packed_batches(DataConfig(vocab=cfg.vocab, seq_len=64, batch=8,
                                   seed=0))
    losses = []
    for _ in range(40):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1


def test_lora_training_moves_only_adapter():
    cfg = get_config("llama2-7b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    adapter = train.init_lora_adapter(cfg, rank=4,
                                      rng=jax.random.PRNGKey(1))
    ocfg = optim.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                             weight_decay=0.0)
    state = optim.init(adapter)
    step = jax.jit(train.make_lora_train_step(cfg, ocfg, rank=4))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                          cfg.vocab)}
    a1, state, m1 = step(adapter, state, params, batch)
    a2, state, m2 = step(a1, state, params, batch)
    assert float(m2["loss"]) < float(m1["loss"])   # fits a fixed batch
    # B starts at zero (pure base model) and becomes nonzero
    assert float(jnp.abs(adapter["q"]["b"]).max()) == 0.0
    assert float(jnp.abs(a2["q"]["b"]).max()) > 0.0


def test_data_pipeline_deterministic_and_masked():
    dcfg = DataConfig(vocab=97, seq_len=32, batch=4, seed=5)
    a = next(packed_batches(dcfg))
    b = next(packed_batches(dcfg))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 32)
    assert a["tokens"].max() < 97
    assert a["loss_mask"].shape == (4, 32)
    # different hosts see different data
    c = next(packed_batches(dcfg, host=1))
    assert not np.array_equal(a["tokens"], c["tokens"])
