import os
import sys

# tests see ONE device (the dry-run sets its own XLA flags in a subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests prefer the real hypothesis (declared in the `test` extra of
# pyproject.toml); fall back to the deterministic stub when it is absent so
# the suite still collects on the hermetic container image.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub as _stub

    _mod = types.ModuleType("hypothesis")
    _mod.given = _stub.given
    _mod.settings = _stub.settings
    _mod.assume = _stub.assume
    _mod.HealthCheck = _stub.HealthCheck
    _strat = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "sampled_from", "booleans", "floats", "lists"):
        setattr(_strat, _name, getattr(_stub, _name))
    _mod.strategies = _strat
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _strat
