import os
import sys

# tests see ONE device (the dry-run sets its own XLA flags in a subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
