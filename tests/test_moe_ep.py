"""Expert-parallel MoE (shard_map all-to-all): numerics vs the einsum path,
on multi-device debug meshes, in a subprocess (device-count isolation)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, MoEConfig
from repro.models import moe as moe_mod
from repro.models.moe_ep import moe_apply_ep, ep_factors, shard_expert_weights
from repro.models.param import split

assert ep_factors(8, 16) == (2, 1)      # grok on the production mesh
assert ep_factors(16, 16) == (1, 1)     # dbrx
assert ep_factors(4, 2) == (1, 2)       # smoke

worst = 0.0
for (dshape, E, topk) in (((4, 2), 4, 2), ((2, 2), 4, 2), ((4, 2), 2, 1),
                          ((8, 1), 4, 2)):
    devs = np.asarray(jax.devices()[: dshape[0] * dshape[1]]).reshape(dshape)
    names = ("data", "model") if dshape[1] > 1 or True else ("data",)
    mesh = jax.sharding.Mesh(devs, ("data", "model"))
    cfg = get_config("dbrx-132b").smoke()
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(E, topk, capacity_factor=float(E) * 2))
    p, _ = split(moe_mod.moe_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    want, _ = moe_mod.moe_apply(cfg, p, x, group_by_sequence=False)
    with mesh:
        got, _ = jax.jit(lambda x_, p_: moe_apply_ep(cfg, p_, x_, mesh))(x, p)
    err = float(jnp.abs(got - want).max() / (jnp.abs(want).max() + 1e-9))
    worst = max(worst, err)
    # gradients flow through the all-to-alls
    g = jax.grad(lambda p_: moe_apply_ep(cfg, p_, x, mesh)[0].sum())(p)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert gn > 0, "no gradient through EP path"
print(f"WORST={worst:.3e}")
assert worst < 1e-5
"""


@pytest.mark.slow
def test_moe_ep_matches_einsum_path():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "WORST=" in out.stdout


def test_ep_factors():
    from repro.models.moe_ep import ep_factors
    assert ep_factors(8, 16) == (2, 1)
    assert ep_factors(16, 16) == (1, 1)
    assert ep_factors(4, 2) == (1, 2)
    with pytest.raises(ValueError):
        ep_factors(6, 16)
