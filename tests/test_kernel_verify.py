"""Static Pallas kernel verifier (`repro.analysis.kernel_model` +
`kernel_verify`): the shipped kernels must verify clean at every config
shape, and a mutation-tested negative suite proves each rule actually
fires — every programmatically injected bug class must be caught by the
*matching* rule (a verifier that passes everything proves nothing)."""
import ast

import pytest

from repro.analysis import kernel_model as km
from repro.analysis import kernel_verify as kv


@pytest.fixture(scope="module")
def models():
    return {m.name: m for m in km.lint_models()}


def rules(findings):
    return {f.rule for f in findings}


# ----------------------------------------------------- model extraction ----

def test_extracts_all_kernels(models):
    assert set(models) == {"bgmv_shrink", "bgmv_expand", "mbgmv_shrink",
                           "mbgmv_expand", "flash_attention",
                           "paged_attention"}
    for m in models.values():
        assert m.grid, m.name
        assert m.out_specs, m.name
        assert m.kernel_ast is not None, m.name
        assert m.path.endswith(".py"), m.name


def test_param_roles_line_up(models):
    roles = models["paged_attention"].param_roles()
    assert roles["bt_ref"] == "scalar"
    assert roles["q_ref"] == "input"
    assert roles["o_ref"] == "output"
    assert roles["acc_ref"] == "scratch"


def test_index_map_evaluates_with_scalars(models):
    m = models["paged_attention"]
    # the K-page spec gathers through the prefetched block table
    kspec = m.in_specs[1]
    c = m.eval_index(kspec, (0, 0, 0))
    assert all(isinstance(x, int) for x in c)


def test_vmem_footprint_counts_double_buffering(models):
    m = models["bgmv_shrink"]
    fp = m.vmem_footprint()
    assert fp["total_bytes"] == \
        2 * (fp["in_bytes"] + fp["out_bytes"]) + fp["scratch_bytes"]
    assert fp["total_bytes"] > 0


def test_clamped_scalar_detected_through_closure(models):
    m = models["paged_attention"]
    # page = lambda ...: jnp.maximum(bt[b, j], 0) is a closure the K/V and
    # pos-page index maps call — the clamp must be traced through it
    assert kv.clamped_scalar_operands(m, m.in_specs[1]) == {0}
    assert kv.clamped_scalar_operands(m, m.in_specs[0]) == set()


def test_mamba_has_no_attention_models():
    case = km.case_from_config(__import__(
        "repro.configs.base", fromlist=["get_config"]
    ).get_config("mamba2-130m"))
    names = {m.name for m in km.build_models(case)}
    assert "flash_attention" not in names
    assert "paged_attention" not in names


# ------------------------------------------------------------ clean runs ----

def test_shipped_kernels_verify_clean(models):
    findings = kv.verify_models(list(models.values()))
    assert findings == [], [f.render() for f in findings]


def test_all_configs_verify_clean_and_within_budget():
    for label, case_models in km.config_models():
        findings = kv.verify_models(case_models)
        assert findings == [], (label, [f.render() for f in findings])
        for m in case_models:
            fp = m.vmem_footprint()
            assert fp["total_bytes"] <= kv.VMEM_BUDGET_BYTES, \
                (label, m.name, fp)


# -------------------------------------------------- mutation suite (>=6) ----

def test_mutation_oob_index_map_caught(models):
    # off-by-one page gather: the clamped block-table index map shifted by
    # +1 block walks past the page pool
    mutant = kv.shift_index_map(models["paged_attention"], 1, 0)
    assert rules(kv.verify_model(mutant)) == {"kernel-bounds"}


def test_mutation_negative_index_map_caught(models):
    mutant = kv.shift_index_map(models["bgmv_shrink"], 1, 0, delta=-1)
    assert "kernel-bounds" in rules(kv.verify_model(mutant))


def test_mutation_noncontiguous_revisit_caught(models):
    # reversing the grid makes output revisits strided: the classic TPU
    # revisit race that interpret mode cannot see
    mutant = kv.swap_grid_order(models["flash_attention"])
    assert "kernel-race" in rules(kv.verify_model(mutant))


def test_mutation_missing_scratch_init_caught(models):
    mutant = kv.drop_when_block(models["paged_attention"], "init")
    found = kv.verify_model(mutant)
    assert rules(found) == {"kernel-scratch"}
    assert any("initialization" in f.message for f in found)


def test_mutation_missing_flush_caught(models):
    mutant = kv.drop_when_block(models["flash_attention"], "flush")
    found = kv.verify_model(mutant)
    assert rules(found) == {"kernel-scratch"}
    assert any("flush" in f.message for f in found)


def test_mutation_clamp_without_guard_caught(models):
    # removing the pl.when(bt >= 0) guard leaves the clamped gather's
    # stale/foreign page contributing to the output — isolation bug
    mutant = kv.drop_when_block(models["paged_attention"], "data")
    found = kv.verify_model(mutant)
    assert "kernel-bounds" in rules(found)
    assert any("clamps scalar operand" in f.message for f in found)


def test_mutation_missing_preferred_element_type_caught(models):
    for name in ("mbgmv_expand", "flash_attention", "paged_attention"):
        mutant = kv.strip_preferred_element_type(models[name])
        found = kv.verify_model(mutant)
        assert "kernel-dtype" in rules(found), name
        assert any("preferred_element_type" in f.message
                   for f in found), name


def test_mutation_broken_carry_caught(models):
    mutant = kv.break_carry(models["flash_attention"], "acc_ref")
    found = kv.verify_model(mutant)
    assert "kernel-scratch" in rules(found)
    assert any("carry" in f.message for f in found)


def test_mutation_vmem_budget_violation_caught(models):
    m = models["flash_attention"]
    fp = m.vmem_footprint()
    found = kv.verify_model(m, vmem_budget=fp["total_bytes"] - 1)
    assert rules(found) == {"kernel-vmem"}


def test_drop_when_block_requires_a_match(models):
    # bgmv_expand has no flush-guarded block: the mutation helper must
    # refuse rather than silently produce an unmutated "mutant"
    with pytest.raises(ValueError):
        kv.drop_when_block(models["bgmv_expand"], "flush")


# ------------------------------------------------- guard classification ----

def test_guard_classification(models):
    body = kv.KernelBody(models["paged_attention"])
    kinds = []
    for pred in body.guard_preds:
        kinds.append(body.classify_guard(pred)[0])
    assert "init" in kinds and "flush" in kinds and "data" in kinds


def test_mutated_ast_is_still_parseable(models):
    mutant = kv.drop_when_block(models["paged_attention"], "init")
    # the transform must leave a structurally valid function AST behind
    assert isinstance(mutant.kernel_ast, ast.FunctionDef)
    compile(ast.Module(body=[mutant.kernel_ast], type_ignores=[]),
            "<mutant>", "exec")
    # and must not have touched the original model
    body = kv.KernelBody(models["paged_attention"])
    assert any(body.classify_guard(p)[0] == "init"
               for p in body.guard_preds)
