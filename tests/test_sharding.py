"""Logical->physical sharding rules, incl. hypothesis properties of the
divisibility guard."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import batch_axes, logical_to_physical, mesh_axis_sizes


@pytest.fixture(scope="module")
def mesh():
    # 1 real device: build a trivial mesh with named axes of size 1 is useless
    # for divisibility tests — use an abstract mesh over the same device
    # repeated is illegal, so emulate sizes via a fake mesh object.
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    return FakeMesh()


def test_prune_non_dividing(mesh):
    # whisper: 6 heads on a 16-way model axis -> pruned
    assert logical_to_physical(("embed", "heads", None), (384, 6, 64),
                               mesh) == P(None, None, None)
    # 48 heads divide -> sharded
    assert logical_to_physical(("embed", "heads", None), (6144, 48, 128),
                               mesh) == P(None, "model", None)


def test_axis_used_once(mesh):
    # experts takes "data" first; embed_fsdp then cannot reuse it
    spec = logical_to_physical(("experts", "embed_fsdp", "mlp"),
                               (16, 6144, 10752), mesh)
    assert spec == P("data", None, "model")
    # experts not divisible (8 % 16): embed_fsdp gets data instead
    spec = logical_to_physical(("experts", "embed_fsdp", "mlp"),
                               (8, 6144, 32768), mesh)
    assert spec == P(None, "data", "model")


def test_batch_multi_axis():
    class M3:
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)
    spec = logical_to_physical(("batch", None), (256, 4096), M3())
    assert spec == P(("pod", "data"), None)
    # batch=1 -> fully pruned
    assert logical_to_physical(("batch", None), (1, 4096), M3()) == P(None, None)
    assert batch_axes(M3()) == ("pod", "data")


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(1, 4096),
       ax=st.sampled_from(["vocab", "heads", "mlp", "batch", "experts",
                           None, "embed"]))
def test_property_spec_always_divides(mesh, dim, ax):
    spec = logical_to_physical((ax,), (dim,), mesh)
    entry = spec[0]
    sizes = mesh_axis_sizes(mesh)
    if entry is None:
        return
    axes = entry if isinstance(entry, tuple) else (entry,)
    prod = int(np.prod([sizes[a] for a in axes]))
    assert dim % prod == 0


@settings(max_examples=30, deadline=None)
@given(dims=st.lists(st.integers(1, 2048), min_size=1, max_size=4))
def test_property_no_axis_reused(mesh, dims):
    axes = ["mlp", "vocab", "heads", "qkv"][: len(dims)]
    spec = logical_to_physical(axes, dims, mesh)
    used = []
    for e in spec:
        if e is None:
            continue
        used.extend(e if isinstance(e, tuple) else [e])
    assert len(used) == len(set(used))
