"""Rank-aware cold-start steering under load observability (ROADMAP item:
`loading_ranks`/`link_busy_ms` steering was wired but unexercised): a fresh
cold start is routed away from a link-saturated server, and a request whose
adapter is already mid-upload somewhere rides that upload for free (the
`adapter_loading` branch of calc_cost) — exercised through the real
Cluster._stats / LoadTracker state, not synthetic ServerStats."""
import numpy as np

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import ServerStats, calc_cost, make_scheduler
from repro.serving.request import Request

CFG = get_config("llama2-7b")


def mk_req(rid, uid, t, tokens=64, out=4):
    return Request(rid=rid, adapter_uid=uid,
                   prompt=np.zeros(tokens, np.int32), max_new_tokens=out,
                   arrival_ms=t)


def two_server_cluster(extra_uids=()):
    perf = ServerPerfModel(CFG, kernel="bgmv")
    servers = [InferenceServer(CFG, mode="caraserve", max_batch=8,
                               numerics=False) for _ in range(2)]
    for s in servers:
        for uid in ("x", "fill0", "fill1", *extra_uids):
            s.register_adapter(AdapterSpec(uid, 64, CFG.name))
    cl = Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=None))
    return cl, perf


def test_fresh_cold_start_steered_off_saturated_link():
    """Both servers equally loaded and neither hosts `x` on device; server 0's
    host link is busy with speculative uploads, so the cold start must pay
    the link queue there — Algorithm 1 (async-load extension) routes to the
    idle-link server 1."""
    cl, _ = two_server_cluster(extra_uids=("p0", "p1"))
    s0, s1 = cl.servers
    s0.submit(mk_req(100, "fill0", 0.0))      # equal request counts
    s1.submit(mk_req(101, "fill1", 0.0))
    for uid in ("p0", "p1"):                   # saturate server 0's link
        assert s0.cold.load_async(uid, 0.0, demand=False) is not None
    assert s0.link_busy_ms() > 0.0 and s1.link_busy_ms() == 0.0
    assert cl._route(mk_req(0, "x", 0.0)) == 1
    # control: with both links idle the tie goes to server 0
    cl2, _ = two_server_cluster()
    cl2.servers[0].submit(mk_req(100, "fill0", 0.0))
    cl2.servers[1].submit(mk_req(101, "fill1", 0.0))
    assert cl2._route(mk_req(0, "x", 0.0)) == 0


def test_inflight_upload_gets_free_ride():
    """Server 0 is already uploading `x` (demand cold start): a second
    request for `x` rides that transfer — calc_cost's adapter_loading branch
    charges no second load, so server 0 wins despite its busy link."""
    cl, _ = two_server_cluster()
    s0, s1 = cl.servers
    s0.submit(mk_req(100, "fill0", 0.0))
    s1.submit(mk_req(101, "fill1", 0.0))
    ev = s0.cold.load_async("x", 0.0, demand=True)
    assert ev is not None and s0.link_busy_ms() > 0.0
    stats = cl._stats("x", 0.0)
    assert stats[0].adapter_loading and not stats[0].adapter_ready
    assert not stats[1].adapter_loading and not stats[1].adapter_ready
    assert cl._route(mk_req(0, "x", 0.0)) == 0


def test_calc_cost_adapter_loading_branch():
    """Unit view of the same property: mid-upload beats fresh-upload beats
    fresh-upload-behind-a-queue."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    load = perf.load_perf(64)
    base = dict(running_ranks=[64], queued_ranks=[], hosts_adapter=True,
                free_rows=7, n_requests=1)
    riding = ServerStats(**base, loading_ranks=[64], link_busy_ms=load / 2,
                         adapter_ready=False, adapter_loading=True)
    fresh = ServerStats(**base, adapter_ready=False)
    queued = ServerStats(**base, link_busy_ms=3 * load, adapter_ready=False)
    costs = [calc_cost(64, s, perf, None, 64.0)
             for s in (riding, fresh, queued)]
    assert costs[0] < costs[1] < costs[2]


def test_stats_expose_per_class_link_occupancy():
    """The link scheduler's per-class occupancy split reaches ServerStats:
    speculative uploads show up as prefetch_link_ms, cold starts as
    demand_link_ms — routing can tell cancellable link pressure apart from
    committed demand traffic."""
    cl, _ = two_server_cluster(extra_uids=("p0",))
    s0, s1 = cl.servers
    s0.cold.load_async("p0", 0.0, demand=False)
    s1.cold.load_async("x", 0.0, demand=True)
    stats = cl._stats("fill0", 0.0)
    assert stats[0].prefetch_link_ms > 0.0
    assert stats[0].demand_link_ms == 0.0
    assert stats[1].demand_link_ms > 0.0
    assert stats[1].prefetch_link_ms == 0.0


def test_simultaneous_cold_burst_spreads_across_servers():
    """End-to-end: a burst of distinct cold starts does not pile onto one
    server — queue depth and in-flight link occupancy push Algorithm 1 to
    alternate."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    servers = [InferenceServer(CFG, mode="caraserve", max_batch=8,
                               numerics=False) for _ in range(2)]
    uids = [f"ad{i}" for i in range(4)]
    for s in servers:
        for uid in uids:
            s.register_adapter(AdapterSpec(uid, 64, CFG.name))
    cl = Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=None))
    reqs = [mk_req(i, uids[i], float(i)) for i in range(4)]
    out, _ = cl.run(reqs)
    assert out["n"] == 4
    per_server = [len(s.states) for s in cl.servers]
    assert min(per_server) >= 1, per_server
    # wake events are classified at pop time: the cold burst's decode is
    # gated on upload completions, so some wakes must be load_done
    assert cl.event_counts["load_done"] > 0
    assert cl.event_counts["arrival"] == 4


def test_preempt_policy_discounts_cancellable_prefetch():
    """Cluster-scale use of the per-class link split: a demand request
    routed to a `preempt`-policy server will reclaim speculative link
    occupancy on arrival, so calc_cost discounts prefetch_link_ms from the
    queueing term there — identical occupancy on a fifo server is charged
    in full."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    load = perf.load_perf(64)
    base = dict(running_ranks=[64], queued_ranks=[], hosts_adapter=True,
                free_rows=7, n_requests=1, adapter_ready=False)
    fifo = ServerStats(**base, link_busy_ms=2 * load,
                       prefetch_link_ms=2 * load, link_policy="fifo")
    pre = ServerStats(**base, link_busy_ms=2 * load,
                      prefetch_link_ms=2 * load, link_policy="preempt")
    c_fifo = calc_cost(64, fifo, perf, None, 64.0)
    c_pre = calc_cost(64, pre, perf, None, 64.0)
    assert c_pre < c_fifo
    # the discount never goes below an idle link, and demand occupancy is
    # never discounted
    idle = ServerStats(**base, link_policy="preempt")
    assert c_pre >= calc_cost(64, idle, perf, None, 64.0)
    dem = ServerStats(**base, link_busy_ms=2 * load, link_policy="preempt")
    assert calc_cost(64, dem, perf, None, 64.0) == c_fifo


def test_demand_routed_to_preempt_server_with_prefetch_saturated_link():
    """End-to-end through Cluster._stats: both servers' links are equally
    saturated with speculative prefetch; server 1 runs the preempt policy,
    so the routing score treats its occupancy as reclaimable and sends the
    cold demand start there."""
    perf = ServerPerfModel(CFG, kernel="bgmv")
    servers = [
        InferenceServer(CFG, mode="caraserve", max_batch=8, numerics=False,
                        link_policy="fifo"),
        InferenceServer(CFG, mode="caraserve", max_batch=8, numerics=False,
                        link_policy="preempt"),
    ]
    for s in servers:
        for uid in ("x", "fill0", "fill1", "p0", "p1"):
            s.register_adapter(AdapterSpec(uid, 64, CFG.name))
    cl = Cluster(servers, make_scheduler("rank_aware", perf, slo_ms=None))
    servers[0].submit(mk_req(100, "fill0", 0.0))   # equal request counts
    servers[1].submit(mk_req(101, "fill1", 0.0))
    for s in servers:                              # saturate both links
        for uid in ("p0", "p1"):
            assert s.cold.load_async(uid, 0.0, demand=False) is not None
    stats = cl._stats("x", 0.0)
    assert stats[0].prefetch_link_ms > 0.0
    assert stats[1].prefetch_link_ms > 0.0
    assert stats[0].link_policy == "fifo"
    assert stats[1].link_policy == "preempt"
    assert cl._route(mk_req(0, "x", 0.0)) == 1
