"""JAX-aware lint (`repro.analysis.lint`): each rule must fire on a minimal
positive example and stay silent on the matching negative, waivers must
suppress, and the repository itself must lint clean."""
import os
import textwrap

from repro.analysis.lint import run_lint

HERE = os.path.dirname(os.path.abspath(__file__))
REPO_SRC = os.path.join(HERE, os.pardir, "src")


def lint(tmp_path, files):
    """Write a throwaway `repro` package and lint it."""
    root = tmp_path / "src"
    for rel, src in files.items():
        p = root / "repro" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint(str(root))


def rules(findings):
    return sorted(f.rule for f in findings)


# ----------------------------------------------------------- bare-assert ----

def test_bare_assert_positive(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        def check(x):
            assert x > 0
            return x
    """})
    assert rules(findings) == ["bare-assert"]


def test_bare_assert_negative(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        def check(x):
            if x <= 0:
                raise ValueError(x)
            return x
    """})
    assert findings == []


# ------------------------------------------------------------- host-sync ----

def test_host_sync_in_traced_code(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        import jax

        @jax.jit
        def step(x):
            return x.item()
    """})
    assert "host-sync" in rules(findings)


def test_host_sync_not_reachable_not_flagged(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        def snapshot(x):
            return x.item()
    """})
    assert findings == []


def test_host_sync_hot_module_needs_waiver(tmp_path):
    src = """
        import numpy as np

        def snapshot(x):
            return np.asarray(x)
    """
    findings, waived = lint(tmp_path, {"core/backend.py": src})
    assert rules(findings) == ["host-sync"] and waived == []


def test_host_sync_waiver_suppresses(tmp_path):
    findings, waived = lint(tmp_path, {"core/backend.py": """
        import numpy as np

        def snapshot(x):
            # lint: allow-host-sync -- intentional d2h snapshot for tests
            return np.asarray(x)
    """})
    assert findings == [] and rules(waived) == ["host-sync"]


def test_host_sync_waiver_in_comment_block_above(tmp_path):
    findings, waived = lint(tmp_path, {"core/backend.py": """
        import numpy as np

        def snapshot(x):
            # lint: allow-host-sync -- the drain is the designed d2h
            # point, several steps behind dispatch
            return np.asarray(x)
    """})
    assert findings == [] and rules(waived) == ["host-sync"]


def test_int_on_traced_value_flagged(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        import jax

        @jax.jit
        def step(x):
            return int(x)
    """})
    assert "host-sync" in rules(findings)


def test_int_on_static_value_not_flagged(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        import jax

        @jax.jit
        def step(x):
            return x * int(x.shape[0])
    """})
    assert findings == []


# -------------------------------------------------------------- jit-spec ----

def test_jit_spec_positive(tmp_path):
    findings, _ = lint(tmp_path, {"core/ops.py": """
        import jax

        def f(x):
            return x

        g = jax.jit(f)
    """})
    assert rules(findings) == ["jit-spec"]


def test_jit_spec_explicit_empty_is_fine(tmp_path):
    findings, _ = lint(tmp_path, {"core/ops.py": """
        import jax

        def f(x):
            return x

        g = jax.jit(f, static_argnums=())
        h = jax.jit(f, donate_argnums=(0,))
    """})
    assert findings == []


def test_jit_spec_outside_hot_prefixes_not_flagged(tmp_path):
    findings, _ = lint(tmp_path, {"training/opt.py": """
        import jax

        def f(x):
            return x

        g = jax.jit(f)
    """})
    assert findings == []


# --------------------------------------------------------- donated-reuse ----

def test_donated_reuse_positive(tmp_path):
    findings, _ = lint(tmp_path, {"core/run.py": """
        import jax

        def f(x):
            return x

        step = jax.jit(f, donate_argnums=(0,))

        def run(x):
            y = step(x)
            return x + y
    """})
    assert "donated-reuse" in rules(findings)


def test_donated_reuse_rebind_is_fine(tmp_path):
    findings, _ = lint(tmp_path, {"core/run.py": """
        import jax

        def f(x):
            return x

        step = jax.jit(f, donate_argnums=(0,))

        def run(x):
            x = step(x)
            return x + 1
    """})
    assert findings == []


# --------------------------------------------------------- pallas-oracle ----

PALLAS_WRAPPER = """
    import jax
    from jax.experimental import pallas as pl

    def double(x):
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
"""


def test_pallas_oracle_missing(tmp_path):
    findings, _ = lint(tmp_path, {"kernels/fast.py": PALLAS_WRAPPER})
    assert rules(findings) == ["pallas-oracle"]
    assert "double_ref" in findings[0].message


def test_pallas_oracle_present(tmp_path):
    findings, _ = lint(tmp_path, {
        "kernels/fast.py": PALLAS_WRAPPER,
        "kernels/ref.py": """
            def double_ref(x):
                return x * 2
        """})
    assert findings == []


def test_pallas_oracle_signature_drift(tmp_path):
    findings, _ = lint(tmp_path, {
        "kernels/fast.py": PALLAS_WRAPPER,
        "kernels/ref.py": """
            def double_ref(x, scale):
                return x * scale
        """})
    assert rules(findings) == ["pallas-oracle"]
    assert "drifted" in findings[0].message


def test_pallas_oracle_hardcoded_out_dtype(tmp_path):
    findings, _ = lint(tmp_path, {
        "kernels/fast.py": """
            import jax
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def double(x):
                return pl.pallas_call(
                    lambda x_ref, o_ref: None,
                    out_shape=jax.ShapeDtypeStruct(x.shape, jnp.int8),
                )(x)
        """,
        "kernels/ref.py": """
            def double_ref(x):
                return x * 2
        """})
    assert rules(findings) == ["pallas-oracle"]
    assert "dtype" in findings[0].message


def test_pallas_oracle_f32_accumulator_ok(tmp_path):
    findings, _ = lint(tmp_path, {
        "kernels/fast.py": """
            import jax
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def double(x):
                return pl.pallas_call(
                    lambda x_ref, o_ref: None,
                    out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
                )(x)
        """,
        "kernels/ref.py": """
            def double_ref(x):
                return x * 2
        """})
    assert findings == []


# ------------------------------------------------------------- tracer-if ----

def test_tracer_if_positive(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        import jax

        @jax.jit
        def step(x):
            if x > 0:
                return x
            return -x
    """})
    assert "tracer-if" in rules(findings)


def test_tracer_if_static_extractors_exempt(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        import jax

        @jax.jit
        def step(x, cache=None):
            if x.shape[0] > 2:
                x = x * 2
            if cache is None:
                return x
            if "k" in cache:
                return x + cache["k"]
            return x
    """})
    assert findings == []


def test_tracer_if_cross_module_reachability(tmp_path):
    """Tracedness flows through a call into another module."""
    findings, _ = lint(tmp_path, {
        "a.py": """
            import jax
            from repro.b import helper

            @jax.jit
            def step(x):
                return helper(x)
        """,
        "b.py": """
            def helper(v):
                if v > 0:
                    return v
                return -v
        """})
    assert "tracer-if" in rules(findings)


def test_tracer_if_static_argnames_respected(tmp_path):
    findings, _ = lint(tmp_path, {"util.py": """
        import functools

        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def step(x, mode):
            if mode == "fast":
                return x * 2
            return x
    """})
    assert findings == []


# ------------------------------------------------------------ repository ----

def test_repository_lints_clean():
    """The acceptance gate: zero un-waived findings over src/."""
    findings, _ = run_lint(REPO_SRC)
    assert findings == [], "\n".join(f.render() for f in findings)


# --------------------------------------------------------- unused-waiver ----

def lint_report(tmp_path, files):
    from repro.analysis.lint import run_lint_report
    root = tmp_path / "src"
    for rel, src in files.items():
        p = root / "repro" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint_report(str(root))


def test_unused_waiver_flagged(tmp_path):
    report = lint_report(tmp_path, {"util.py": """
        def check(x):
            # lint: allow-bare-assert  # stale: the assert below was removed
            if x <= 0:
                raise ValueError(x)
            return x
    """})
    assert report.findings == []
    assert [f.rule for f in report.unused_waivers] == ["unused-waiver"]


def test_used_waiver_not_flagged(tmp_path):
    report = lint_report(tmp_path, {"util.py": """
        def check(x):
            assert x > 0  # lint: allow-bare-assert  # invariant, documented
            return x
    """})
    assert report.findings == []
    assert len(report.waived) == 1
    assert report.unused_waivers == []


def test_waiver_syntax_in_docstring_not_flagged(tmp_path):
    """Only real comment tokens are waivers — the rule-catalog docstring
    mentions the marker syntax without being one."""
    report = lint_report(tmp_path, {"util.py": '''
        """Waive findings with ``# lint: allow-bare-assert`` comments."""

        def check(x):
            return x
    '''})
    assert report.unused_waivers == []


def test_report_to_dict_round_trips(tmp_path):
    import json
    report = lint_report(tmp_path, {"util.py": """
        def check(x):
            assert x > 0
            return x
    """})
    d = json.loads(json.dumps(report.to_dict()))
    assert d["findings"][0]["rule"] == "bare-assert"
    assert set(d) == {"findings", "waived", "unused_waivers"}


# ----------------------------------------------------------- kernel rules ----

def test_repository_kernel_rules_ran():
    """The kernel-* static verification is wired into the linter (not just
    the standalone kverify CLI): the real kernels must have been modeled
    and produced zero un-waived kernel findings."""
    from repro.analysis.lint import Linter
    linter = Linter(REPO_SRC)
    findings = linter.run()
    kernel_findings = [f for f in findings
                       if f.rule.startswith("kernel-")]
    assert kernel_findings == [], \
        "\n".join(f.render() for f in kernel_findings)


def test_repository_has_no_unused_waivers():
    from repro.analysis.lint import run_lint_report
    report = run_lint_report(REPO_SRC)
    assert report.unused_waivers == [], \
        "\n".join(f.render() for f in report.unused_waivers)
