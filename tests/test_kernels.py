"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
ref.py pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bgmv import bgmv, bgmv_expand, bgmv_shrink
from repro.kernels.flash import flash_attention
from repro.kernels.mbgmv import mbgmv


def make_pool(key, slots, d_in, d_out, r_max, ranks, dtype):
    ks = jax.random.split(key, 2)
    a = (jax.random.normal(ks[0], (slots, d_in, r_max)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[1], (slots, r_max, d_out)) * 0.05).astype(dtype)
    rm = jnp.arange(r_max)[None] < ranks[:, None]
    return a * rm[:, None, :].astype(dtype), b * rm[:, :, None].astype(dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("d_in,d_out,r_max", [(256, 128, 16), (1024, 512, 64),
                                              (384, 768, 32)])
def test_bgmv_matches_oracle(dtype, tol, d_in, d_out, r_max):
    key = jax.random.PRNGKey(0)
    slots, B = 4, 5
    ranks = jnp.array([r_max, r_max // 2, max(r_max // 4, 1), 1])
    a, b = make_pool(key, slots, d_in, d_out, r_max, ranks, dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d_in)).astype(dtype)
    idx = jnp.array([0, 3, 1, -1, 2])
    got = bgmv(x, a, b, idx)
    want = ref.bgmv_ref(x, a, b, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("rank_block", [8, 16])
def test_mbgmv_matches_oracle_and_bgmv(dtype, tol, rank_block):
    key = jax.random.PRNGKey(2)
    slots, B, d_in, d_out, r_max = 4, 6, 512, 256, 64
    ranks = jnp.array([64, 32, 16, 8])
    a, b = make_pool(key, slots, d_in, d_out, r_max, ranks, dtype)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, d_in)).astype(dtype)
    idx = jnp.array([0, 1, 2, 3, -1, 1])
    got = mbgmv(x, a, b, idx, ranks, rank_block=rank_block)
    want = ref.mbgmv_ref(x, a, b, idx, ranks, rank_block=rank_block)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    # zero-padded pools: padding path == skipping path (paper numerics)
    want_bgmv = ref.bgmv_ref(x, a, b, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want_bgmv, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 7), d_block=st.sampled_from([64, 128, 256]))
def test_bgmv_shrink_property(B, d_block):
    slots, d_in, r = 3, 512, 16
    key = jax.random.PRNGKey(B)
    a = jax.random.normal(key, (slots, d_in, r)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(B + 9), (B, d_in))
    idx = jnp.arange(B) % slots
    got = bgmv_shrink(x, a, idx, d_block=d_block)
    want = ref.bgmv_shrink_ref(x, a, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("L,H,KV,hd", [(200, 4, 4, 64), (130, 8, 2, 32)])
def test_flash_attention_matches_oracle(dtype, tol, causal, window, L, H, KV,
                                        hd):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, L, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, L, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, L, hd)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_block_shape_independence():
    """Result must not depend on BlockSpec tile choice."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (1, 2, 257, 64))
    k = jax.random.normal(ks[1], (1, 2, 257, 64))
    v = jax.random.normal(ks[2], (1, 2, 257, 64))
    outs = [flash_attention(q, k, v, bq=bq, bk=bk)
            for bq, bk in [(32, 64), (128, 128), (256, 32)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


# ------------------------------------------------------- lora dispatch ----

def test_lora_delta_modes_agree_heterogeneous_ranks():
    """The jitted public dispatcher: bgmv (pad-to-max), mbgmv (rank-block
    skip), and the jnp oracle agree on a pool of heterogeneous ranks,
    including no-adapter rows (idx -1)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(3)
    d_in, d_out, r_max, slots, B = 256, 128, 16, 5, 7
    ranks = jnp.array([16, 8, 3, 1, 12])
    a, b = make_pool(key, slots, d_in, d_out, r_max, ranks, jnp.float32)
    x = (jax.random.normal(jax.random.PRNGKey(4), (B, d_in)) * 0.1)
    idx = jnp.array([0, 1, 2, 3, 4, -1, 2])
    want = np.asarray(ops.lora_delta(x, a, b, idx, mode="ref"))
    for mode, kw in (("bgmv", {}), ("mbgmv", {"ranks": ranks}),
                     ("mbgmv", {"ranks": ranks, "rank_block": 8})):
        got = np.asarray(ops.lora_delta(x, a, b, idx, mode=mode, **kw))
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    assert np.all(want[5] == 0)          # idx -1 -> zero delta
    # the wrappers themselves stay callable post-jit
    np.testing.assert_allclose(
        np.asarray(ops.lora_delta_mbgmv(x, a, b, idx, ranks)), want,
        atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ops.lora_delta_bgmv(x, a, b, idx)),
                               want, atol=2e-5, rtol=2e-5)


# ------------------------------------------------------ paged attention ----

def _paged_case(seed, B, H, KV, hd, ps, P, W):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(P, KV, ps, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(P, KV, ps, hd)), jnp.float32)
    pp = np.full((P, ps), -1, np.int32)
    bt = np.full((B, W), -1, np.int32)
    pos = np.zeros((B,), np.int32)
    free = list(range(P))
    for b in range(B):
        n = int(rng.integers(1, W + 1))
        used = int(rng.integers(1, n * ps + 1))
        pos[b] = used - 1
        for j in range(n):
            pg = free.pop()
            bt[b, j] = pg
            filled = np.arange(ps) + j * ps
            pp[pg] = np.where(filled < used, filled, -1)
    return q, k, v, jnp.asarray(pp), jnp.asarray(bt), jnp.asarray(pos)


@pytest.mark.parametrize("B,H,KV,hd,ps,P,W", [
    (4, 8, 4, 32, 16, 12, 4),        # partial fills, unclaimed pages
    (2, 4, 4, 64, 32, 6, 2),         # MHA-style (H == KV groups of 1)
    (3, 8, 2, 16, 8, 24, 5),         # deep tables, big GQA group
])
def test_paged_attention_matches_oracle(B, H, KV, hd, ps, P, W):
    from repro.kernels.paged import paged_attention
    q, k, v, pp, bt, pos = _paged_case(hash((B, H, ps)) % 97, B, H, KV, hd,
                                       ps, P, W)
    got = paged_attention(q, k, v, pp, bt, pos)
    want = ref.paged_attention_ref(q, k, v, pp, bt, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_ignores_foreign_pages():
    """Rows must never attend pages their block table does not own: giving
    page 0 (owned by row 0) huge keys may not change any other row."""
    from repro.kernels.paged import paged_attention
    q, k, v, pp, bt, pos = _paged_case(5, 3, 4, 2, 16, 8, 12, 3)
    base = np.asarray(ref.paged_attention_ref(q, k, v, pp, bt, pos))
    k2 = k.at[int(bt[0, 0])].mul(100.0)
    got = np.asarray(paged_attention(q, k2, v, pp, bt, pos))
    want = np.asarray(ref.paged_attention_ref(q, k2, v, pp, bt, pos))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(got[1:], base[1:], atol=2e-5, rtol=2e-5)


# ---------------------------------------------- conformance sweep (paged) ----

def _edge_case(seed, B, H, KV, hd, ps, P, W):
    """Random claimed layout, then force the adversarial edges the verifier
    models symbolically: an all-unclaimed row, a pos=0 row, and a claimed
    but fully-masked (lazily grown, not yet written) page."""
    q, k, v, pp, bt, pos = _paged_case(seed, B, H, KV, hd, ps, P, W)
    pp, bt, pos = np.asarray(pp).copy(), np.asarray(bt).copy(), \
        np.asarray(pos).copy()
    bt[0] = -1                               # row 0: nothing claimed at all
    pos[0] = 0
    if B > 1:
        pos[1] = 0                           # row 1: first token only
    last = B - 1
    if W > 1 and bt[last, 1] < 0:            # row B-1: claim a page whose
        free = set(range(P)) - set(bt[bt >= 0].tolist())
        bt[last, 1] = free.pop()             # slots are all still empty
    if bt[last, 1] >= 0:
        pp[bt[last, 1]] = -1
    return q, k, v, jnp.asarray(pp), jnp.asarray(bt), jnp.asarray(pos)


@pytest.mark.parametrize("ps", [8, 32])
@pytest.mark.parametrize("W", [2, 5])
@pytest.mark.parametrize("group", [1, 4])
@pytest.mark.parametrize("B", [1, 4])
def test_paged_attention_conformance_sweep(ps, W, group, B):
    """Interpret-mode kernel == jnp oracle across (page size, table width,
    GQA group, batch) including all-unclaimed rows, pos=0, and a claimed
    fully-masked page — the inputs whose garbage paths only the mask-aware
    online softmax keeps at exactly zero."""
    from repro.kernels.paged import paged_attention
    KV = 2
    H, hd, P = KV * group, 16, W * B + 2
    q, k, v, pp, bt, pos = _edge_case(hash((ps, W, group, B)) % 251,
                                      B, H, KV, hd, ps, P, W)
    got = np.asarray(paged_attention(q, k, v, pp, bt, pos))
    want = np.asarray(ref.paged_attention_ref(q, k, v, pp, bt, pos))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # the all-unclaimed row is *defined* to be zeros, not softmax garbage
    np.testing.assert_array_equal(got[0], np.zeros_like(got[0]))


# ------------------------------------------------------- shape validation ----

def test_paged_attention_shape_validation():
    from repro.kernels.paged import paged_attention
    q, k, v, pp, bt, pos = _paged_case(7, 2, 4, 2, 16, 8, 6, 2)
    with pytest.raises(ValueError, match="not divisible"):
        paged_attention(q[:, :3], k, v, pp, bt, pos)       # H % KV
    with pytest.raises(ValueError, match="k_pages .* v_pages"):
        paged_attention(q, k, v[:, :, :4], pp, bt, pos)
    with pytest.raises(ValueError, match="pos_pages"):
        paged_attention(q, k, v, pp[:, :4], bt, pos)
    with pytest.raises(ValueError, match="batch"):
        paged_attention(q, k, v, pp, bt[:1], pos)
    with pytest.raises(ValueError, match="batch"):
        paged_attention(q, k, v, pp, bt, pos[:1])


def test_flash_attention_shape_validation():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 6, 64, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 4, 64, 16)), jnp.float32)
    with pytest.raises(ValueError, match="not divisible"):
        flash_attention(q, k, k)
    k = jnp.asarray(rng.normal(size=(2, 2, 64, 16)), jnp.float32)
    with pytest.raises(ValueError, match="k .* != v"):
        flash_attention(q, k, k[:, :, :32])


def test_bgmv_shape_validation():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 256)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(2, 128, 16)), jnp.float32)
    idx = jnp.zeros((3,), jnp.int32)
    with pytest.raises(ValueError, match="disagree on d_in"):
        bgmv_shrink(x, a, idx)
    a = jnp.asarray(rng.normal(size=(2, 256, 16)), jnp.float32)
    with pytest.raises(ValueError, match="idx"):
        bgmv_shrink(x, a, jnp.zeros((4,), jnp.int32))
    y = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)
    with pytest.raises(ValueError, match="disagree on rank"):
        bgmv_expand(y, b, idx)
    # a non-divisor block request is snapped to the largest divisor, never
    # silently truncating columns: the result must still match the oracle
    b = jnp.asarray(rng.normal(size=(2, 16, 64)), jnp.float32)
    got = bgmv_expand(y, b, idx, o_block=33)
    want = ref.bgmv_expand_ref(y, b, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_mbgmv_shape_validation():
    from repro.kernels.mbgmv import mbgmv_expand, mbgmv_shrink
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 128)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(3, 128, 32)), jnp.float32)
    ranks = jnp.full((3,), 16, jnp.int32)
    idx = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError, match="disagree on d_in"):
        mbgmv_shrink(x[:, :64], a, idx, ranks)
    with pytest.raises(ValueError, match="ranks"):
        mbgmv_shrink(x, a, idx, ranks[:2])
    with pytest.raises(ValueError, match="rank_block"):
        mbgmv_shrink(x, a, idx, ranks, rank_block=24)
    y = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(3, 32, 64)), jnp.float32)
    with pytest.raises(ValueError, match="disagree on r_max"):
        mbgmv_expand(y[:, :16], b, idx, ranks)
    with pytest.raises(ValueError, match="idx"):
        mbgmv_expand(y, b, jnp.zeros((5,), jnp.int32), ranks)
