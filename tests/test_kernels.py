"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
ref.py pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.bgmv import bgmv, bgmv_expand, bgmv_shrink
from repro.kernels.flash import flash_attention
from repro.kernels.mbgmv import mbgmv


def make_pool(key, slots, d_in, d_out, r_max, ranks, dtype):
    ks = jax.random.split(key, 2)
    a = (jax.random.normal(ks[0], (slots, d_in, r_max)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[1], (slots, r_max, d_out)) * 0.05).astype(dtype)
    rm = jnp.arange(r_max)[None] < ranks[:, None]
    return a * rm[:, None, :].astype(dtype), b * rm[:, :, None].astype(dtype)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("d_in,d_out,r_max", [(256, 128, 16), (1024, 512, 64),
                                              (384, 768, 32)])
def test_bgmv_matches_oracle(dtype, tol, d_in, d_out, r_max):
    key = jax.random.PRNGKey(0)
    slots, B = 4, 5
    ranks = jnp.array([r_max, r_max // 2, max(r_max // 4, 1), 1])
    a, b = make_pool(key, slots, d_in, d_out, r_max, ranks, dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d_in)).astype(dtype)
    idx = jnp.array([0, 3, 1, -1, 2])
    got = bgmv(x, a, b, idx)
    want = ref.bgmv_ref(x, a, b, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("rank_block", [8, 16])
def test_mbgmv_matches_oracle_and_bgmv(dtype, tol, rank_block):
    key = jax.random.PRNGKey(2)
    slots, B, d_in, d_out, r_max = 4, 6, 512, 256, 64
    ranks = jnp.array([64, 32, 16, 8])
    a, b = make_pool(key, slots, d_in, d_out, r_max, ranks, dtype)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, d_in)).astype(dtype)
    idx = jnp.array([0, 1, 2, 3, -1, 1])
    got = mbgmv(x, a, b, idx, ranks, rank_block=rank_block)
    want = ref.mbgmv_ref(x, a, b, idx, ranks, rank_block=rank_block)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    # zero-padded pools: padding path == skipping path (paper numerics)
    want_bgmv = ref.bgmv_ref(x, a, b, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want_bgmv, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 7), d_block=st.sampled_from([64, 128, 256]))
def test_bgmv_shrink_property(B, d_block):
    slots, d_in, r = 3, 512, 16
    key = jax.random.PRNGKey(B)
    a = jax.random.normal(key, (slots, d_in, r)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(B + 9), (B, d_in))
    idx = jnp.arange(B) % slots
    got = bgmv_shrink(x, a, idx, d_block=d_block)
    want = ref.bgmv_shrink_ref(x, a, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("L,H,KV,hd", [(200, 4, 4, 64), (130, 8, 2, 32)])
def test_flash_attention_matches_oracle(dtype, tol, causal, window, L, H, KV,
                                        hd):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, L, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, L, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, L, hd)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_block_shape_independence():
    """Result must not depend on BlockSpec tile choice."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (1, 2, 257, 64))
    k = jax.random.normal(ks[1], (1, 2, 257, 64))
    v = jax.random.normal(ks[2], (1, 2, 257, 64))
    outs = [flash_attention(q, k, v, bq=bq, bk=bk)
            for bq, bk in [(32, 64), (128, 128), (256, 32)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)
