"""LoRA semantics: merged-weights equivalence, pool management, delta paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import lora as lora_lib
from repro.models import model
from repro.models.param import split


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama2-7b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, params


def test_merged_weights_equivalence(setup):
    """y = x(W + AB) must equal base y + batched LoRA delta (paper Eq. 1)."""
    cfg, params = setup
    spec = lora_lib.AdapterSpec("ad0", rank=4, base_model=cfg.name)
    w = lora_lib.make_adapter_weights(cfg, spec, dtype=jnp.float32)
    B, L = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab)

    # path 1: lora arg through the model
    pool = lora_lib.pool_init(cfg)
    pool = lora_lib.pool_insert(pool, cfg, w, slot=1, rank=4)
    lora = {"pool": pool, "idx": jnp.ones((B,), jnp.int32), "mode": "bgmv"}
    got, _ = model.prefill(cfg, params, {"tokens": toks}, lora=lora)

    # path 2: merge AB into the q/k/v projections
    merged = jax.tree.map(lambda x: x, params)
    import copy
    blocks = {k: v for k, v in params["blocks"].items()}
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    for tgt, nh in (("q", H), ("k", KV), ("v", KV)):
        delta = jnp.einsum("ldr,lro->ldo", w[tgt]["a"], w[tgt]["b"])
        wkey = {"q": "wq", "k": "wk", "v": "wv"}[tgt]
        old = blocks["attn"][wkey]["w"]          # (Llayers, d, nh, hd)
        blocks["attn"] = dict(blocks["attn"])
        blocks["attn"][wkey] = dict(blocks["attn"][wkey])
        blocks["attn"][wkey]["w"] = old + delta.reshape(old.shape)
    merged = dict(params)
    merged["blocks"] = blocks
    want, _ = model.prefill(cfg, merged, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=5e-4)


def test_no_adapter_is_base_model(setup):
    cfg, params = setup
    B, L = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, cfg.vocab)
    pool = lora_lib.pool_init(cfg)
    lora = {"pool": pool, "idx": jnp.full((B,), -1, jnp.int32),
            "mode": "bgmv"}
    got, _ = model.prefill(cfg, params, {"tokens": toks}, lora=lora)
    want, _ = model.prefill(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_heterogeneous_batch_mixes_adapters(setup):
    """Row b must receive exactly adapter idx[b]'s delta."""
    cfg, params = setup
    specs = [lora_lib.AdapterSpec(f"a{i}", rank=2 ** (i + 1),
                                  base_model=cfg.name) for i in range(3)]
    pool = lora_lib.pool_init(cfg)
    for i, s in enumerate(specs):
        pool = lora_lib.pool_insert(
            pool, cfg, lora_lib.make_adapter_weights(cfg, s), i,
            min(s.rank, cfg.lora.max_rank))
    L = 5
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, L), 0, cfg.vocab)
    mixed, _ = model.prefill(cfg, params, {"tokens": toks},
                             lora={"pool": pool,
                                   "idx": jnp.array([0, 1, 2]),
                                   "mode": "bgmv"})
    for b in range(3):
        solo, _ = model.prefill(
            cfg, params, {"tokens": toks[b:b + 1]},
            lora={"pool": pool, "idx": jnp.array([b]), "mode": "bgmv"})
        np.testing.assert_allclose(np.asarray(mixed[b]),
                                   np.asarray(solo[0]), atol=2e-4, rtol=2e-4)


def test_bgmv_mbgmv_model_equivalence(setup):
    cfg, params = setup
    spec = lora_lib.AdapterSpec("ad", rank=3, base_model=cfg.name)
    pool = lora_lib.pool_init(cfg)
    pool = lora_lib.pool_insert(
        pool, cfg, lora_lib.make_adapter_weights(cfg, spec), 0, 3)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 7), 0, cfg.vocab)
    outs = []
    for mode in ("bgmv", "mbgmv"):
        o, _ = model.prefill(cfg, params, {"tokens": toks},
                             lora={"pool": pool,
                                   "idx": jnp.zeros((2,), jnp.int32),
                                   "mode": mode})
        outs.append(np.asarray(o))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)


def test_device_pool_lru_and_pinning():
    cfg = get_config("llama2-7b").smoke()
    pool = lora_lib.DevicePool(cfg, n_slots=2, materialize=False)
    assert pool.insert("a", None, 4) == 0
    assert pool.insert("b", None, 8) == 1
    assert pool.lookup("a") == 0          # refreshes LRU
    assert pool.insert("c", None, 2) == 1  # evicts b (LRU)
    assert pool.lookup("b") is None
    # pinned slots are not evictable
    assert pool.insert("d", None, 2, pinned=(0, 1)) is None


def test_adapter_nbytes_scales_with_rank():
    cfg = get_config("llama2-7b")
    s8 = lora_lib.AdapterSpec("x", 8, cfg.name).nbytes(cfg)
    s64 = lora_lib.AdapterSpec("y", 64, cfg.name).nbytes(cfg)
    assert abs(s64 / s8 - 8.0) < 1e-6
    # rank-64 q/k/v adapter of llama2-7b ~ 100 MiB (paper sec 2.3)
    assert 50e6 < s64 < 250e6
