"""Decode-with-cache must reproduce full-prefill logits for every family
(catches KV ring-buffer, RoPE-at-write, SSD-state and recurrence bugs)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoEConfig, get_config
from repro.models import model
from repro.models.param import split

ARCHS = ["yi-9b", "dbrx-132b", "mamba2-130m", "recurrentgemma-2b",
         "whisper-tiny", "phi-3-vision-4.2b", "qwen2-72b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equivalence(arch):
    cfg = get_config(arch).smoke()
    if cfg.moe:   # avoid capacity-drop nondeterminism between seq lengths
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.n_experts, cfg.moe.top_k,
                               capacity_factor=float(cfg.moe.n_experts)))
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    B, L, extra = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + extra), 0,
                              cfg.vocab)

    def mkbatch(t):
        b = {"tokens": t}
        if cfg.family in ("audio", "encdec"):
            b["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
        if cfg.family == "vlm":
            b["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(3), (B, cfg.n_prefix_tokens, cfg.d_model))
        return b

    full, _ = model.prefill(cfg, params, mkbatch(toks))
    logits, cache = model.prefill(cfg, params, mkbatch(toks[:, :L]),
                                  cache_slots=L + 8)
    offset = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    last = logits[:, -1]
    for step in range(extra):
        want = full[:, offset + L + step - 1]
        scale = float(jnp.abs(want).max()) + 1e-9
        err = float(jnp.abs(last - want).max()) / scale
        assert err < 1e-4, (arch, step, err)
        pos = jnp.full((B,), offset + L + step, jnp.int32)
        last, cache = model.decode(cfg, params, cache,
                                   toks[:, L + step][:, None], pos)
        last = last[:, -1]
