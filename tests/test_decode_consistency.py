"""Decode-with-cache must reproduce full-prefill logits for every family
(catches KV ring-buffer, RoPE-at-write, SSD-state and recurrence bugs),
and the device-resident decode pipeline's megastep path must be bitwise-
identical to single-stepping (tokens AND KV cache)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.models import model
from repro.models.param import split
from repro.serving.request import Request

ARCHS = ["yi-9b", "dbrx-132b", "mamba2-130m", "recurrentgemma-2b",
         "whisper-tiny", "phi-3-vision-4.2b", "qwen2-72b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equivalence(arch):
    cfg = get_config(arch).smoke()
    if cfg.moe:   # avoid capacity-drop nondeterminism between seq lengths
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.n_experts, cfg.moe.top_k,
                               capacity_factor=float(cfg.moe.n_experts)))
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    B, L, extra = 2, 10, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + extra), 0,
                              cfg.vocab)

    def mkbatch(t):
        b = {"tokens": t}
        if cfg.family in ("audio", "encdec"):
            b["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
        if cfg.family == "vlm":
            b["prefix_embeds"] = jax.random.normal(
                jax.random.PRNGKey(3), (B, cfg.n_prefix_tokens, cfg.d_model))
        return b

    full, _ = model.prefill(cfg, params, mkbatch(toks))
    logits, cache = model.prefill(cfg, params, mkbatch(toks[:, :L]),
                                  cache_slots=L + 8)
    offset = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    last = logits[:, -1]
    for step in range(extra):
        want = full[:, offset + L + step - 1]
        scale = float(jnp.abs(want).max()) + 1e-9
        err = float(jnp.abs(last - want).max()) / scale
        assert err < 1e-4, (arch, step, err)
        pos = jnp.full((B,), offset + L + step, jnp.int32)
        last, cache = model.decode(cfg, params, cache,
                                   toks[:, L + step][:, None], pos)
        last = last[:, -1]


# ------------------------- device-resident decode pipeline parity -------

def _run_pipeline_server(megastep, pipeline="fused", max_new=(9, 5, 7),
                         memory="auto", page_size=32):
    """Cached-mode numerics server over a fixed overlapping trace; the
    per-request max_new spread makes rows hit their stop targets at
    different megastep iterations (exercising the per-row freeze)."""
    cfg = get_config("llama2-7b").smoke()
    srv = InferenceServer(cfg, mode="cached", max_batch=4, cache_slots=64,
                          numerics=True, seed=0, pipeline=pipeline,
                          megastep=megastep, memory=memory,
                          page_size=page_size)
    rng = np.random.default_rng(11)
    reqs = []
    for i, n in enumerate(max_new):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
        prompt = rng.integers(0, cfg.vocab, 5 + i).astype(np.int32)
        reqs.append(Request(rid=i, adapter_uid=f"ad{i}", prompt=prompt,
                            max_new_tokens=n, arrival_ms=0.0))
    srv.run(reqs)
    return srv


def test_megastep_bitwise_matches_single_steps():
    """Megastep-K greedy decode == K single fused steps, bitwise: every
    request's token stream, every token timestamp (the timeline bills K
    shrinking-batch iterations), and every KV-cache leaf."""
    single = _run_pipeline_server(megastep=0)
    mega = _run_pipeline_server(megastep=8)
    assert mega.backend.transfer_stats["megasteps"] > 0
    assert single.backend.transfer_stats["megasteps"] == 0
    for a, b in zip(single.states, mega.states):
        assert a.generated == b.generated, a.req.rid
        assert a.token_times_ms == b.token_times_ms, a.req.rid
    leaves_a = jax.tree.leaves(single.backend.cache)
    leaves_b = jax.tree.leaves(mega.backend.cache)
    for la, lb in zip(leaves_a, leaves_b):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_fused_matches_perstep_baseline():
    """The fused pipeline (device sampling + async readback) reproduces
    the legacy per-step path (host sampling off full logits) exactly."""
    legacy = _run_pipeline_server(megastep=0, pipeline="perstep")
    fused = _run_pipeline_server(megastep=0, pipeline="fused")
    for a, b in zip(legacy.states, fused.states):
        assert a.generated == b.generated, a.req.rid
        assert a.token_times_ms == b.token_times_ms, a.req.rid


@pytest.mark.parametrize("page_size", [16, 32, 64])
def test_paged_decode_matches_dense(page_size):
    """Paged (block-table) decode is token-for-token identical to the
    dense per-row slab under greedy sampling — tokens, timestamps, and
    each row's reconstructed KV cache — for every page size that tiles
    the ring."""
    dense = _run_pipeline_server(megastep=0, memory="dense")
    paged = _run_pipeline_server(megastep=0, memory="paged",
                                 page_size=page_size)
    assert paged.backend.paged and not dense.backend.paged
    for a, b in zip(dense.states, paged.states):
        assert a.generated == b.generated, a.req.rid
        assert a.token_times_ms == b.token_times_ms, a.req.rid


def test_paged_megastep_matches_dense_megastep():
    """Megastep parity across memory planes: K fused paged iterations ==
    K fused dense iterations, token-for-token (frozen rows drop their
    page writes via the OOB scatter exactly like dense rows)."""
    dense = _run_pipeline_server(megastep=8, memory="dense")
    paged = _run_pipeline_server(megastep=8, memory="paged")
    assert paged.backend.transfer_stats["megasteps"] > 0
    for a, b in zip(dense.states, paged.states):
        assert a.generated == b.generated, a.req.rid
        assert a.token_times_ms == b.token_times_ms, a.req.rid
    # reconstructing each retired row's final cache from its (freed but
    # unreused) pages reproduces the dense rows' written slots
    import repro.serving.cache as cache_lib
    for st in paged.states:
        got = cache_lib.gather_pages(paged.backend.cache, st.kv_pages)
        want = cache_lib.gather_row(dense.backend.cache, st.row)
        wpos = np.asarray(want["pos"])
        gpos = np.asarray(got["pos"])
        W = gpos.shape[-1]          # the claim covers prompt + max_new only
        assert np.all(wpos[:, :, W:] < 0), st.req.rid   # nothing beyond it
        written = wpos[:, :, :W] >= 0
        assert np.array_equal(gpos[written], wpos[:, :, :W][written]), \
            st.req.rid


# ------------------------------- chunked prefill parity -----------------

def _run_chunked_server(chunk_budget, prompts, max_new, arrivals=None,
                        cache_slots=64):
    """Cached-mode paged numerics server; chunk_budget=0 is the monolithic
    baseline arm."""
    cfg = get_config("llama2-7b").smoke()
    srv = InferenceServer(cfg, mode="cached", max_batch=4,
                          cache_slots=cache_slots,
                          numerics=True, seed=0, pipeline="fused",
                          megastep=0, memory="paged", page_size=16,
                          chunk_budget=chunk_budget)
    rng = np.random.default_rng(23)
    reqs = []
    for i, (pl, n) in enumerate(zip(prompts, max_new)):
        srv.register_adapter(AdapterSpec(f"ad{i}", rank=8,
                                         base_model=cfg.name))
        prompt = rng.integers(0, cfg.vocab, pl).astype(np.int32)
        t = arrivals[i] if arrivals is not None else 0.0
        reqs.append(Request(rid=i, adapter_uid=f"ad{i}", prompt=prompt,
                            max_new_tokens=n, arrival_ms=t))
    srv.run(reqs)
    return srv


@pytest.mark.parametrize("prompt_len,n_chunks", [(24, 2), (61, 4)])
def test_chunked_prefill_bitwise_matches_monolithic(prompt_len, n_chunks):
    """A prompt fed through the chunked path (16-token chunks, partial
    final chunk) produces the same first sampled token, the same decode
    continuation, and bitwise-identical post-prefill KV pages as one
    monolithic `prefill_admitted` call. The masked softmax zeroes
    pad/unwritten contributions *exactly* (NEG_INF -> exp underflows to
    0.0) and both views put absolute position p in slot p of an
    equal-width reduction, so bucketed chunk widths are bitwise no-ops.

    One request per server: in a multi-request run an early-retiring
    row's freed pages get reclaimed by a later row's chunk claims, so
    end-of-run gathers would read overwritten data (token parity under
    that regime is covered by the interleave test below). Here the sole
    request's pages are freed at retirement but never reused, so the
    final pool still holds its post-run KV."""
    import repro.serving.cache as cache_lib
    chunk = _run_chunked_server(16, (prompt_len,), (2,))
    mono = _run_chunked_server(0, (prompt_len,), (2,))
    assert chunk.backend.transfer_stats["prefill_chunks"] == n_chunks
    assert mono.backend.transfer_stats["prefill_chunks"] == 0
    (a,), (b,) = mono.states, chunk.states
    assert len(a.generated) == a.req.max_new_tokens
    assert a.generated == b.generated
    # page *ids* may differ (chunk-by-chunk claims vs one upfront claim
    # draw from the allocator in different orders); gather_pages maps both
    # into the same position-indexed dense view, where parity must be exact
    ga = cache_lib.gather_pages(mono.backend.cache, a.kv_pages)
    gb = cache_lib.gather_pages(chunk.backend.cache, b.kv_pages)
    pa, pb = np.asarray(ga["pos"]), np.asarray(gb["pos"])
    assert np.array_equal(pa, pb)
    written = (pa >= 0)[:, :, None, :, None]
    for leaf in ("k", "v"):
        ka, kb = np.asarray(ga[leaf]), np.asarray(gb[leaf])
        assert np.array_equal(np.where(written, ka, 0),
                              np.where(written, kb, 0)), leaf


def test_chunked_interleave_token_parity_under_load():
    """Chunks riding live decode iterations (staggered arrivals, mixed
    decode+prefill steps) leave every request's token stream identical to
    the monolithic arm — interference control changes the timeline, never
    the numerics."""
    prompts, max_new = (30, 44, 25), (8, 6, 7)
    arrivals = [0.0, 10.0, 20.0]
    chunk = _run_chunked_server(16, prompts, max_new, arrivals)
    mono = _run_chunked_server(0, prompts, max_new, arrivals)
    assert chunk.backend.transfer_stats["prefill_chunks"] > 0
    for a, b in zip(mono.states, chunk.states):
        assert a.generated == b.generated, a.req.rid
        assert len(b.generated) == b.req.max_new_tokens, b.req.rid


def test_fused_decode_steady_state_zero_h2d():
    """A fused decode iteration performs zero host->device transfers in
    steady state: h2d crossings come only from events (prefill, staging
    miss, active-set change on retirement) — stretching one request's
    output adds decode iterations but not a single extra upload. The
    legacy per-step path uploads >= 3 arrays every iteration."""
    short = _run_pipeline_server(megastep=0, max_new=(9, 5, 7))
    long = _run_pipeline_server(megastep=0, max_new=(19, 5, 7))
    s, l = short.backend.transfer_stats, long.backend.transfer_stats
    assert l["decode_steps"] >= s["decode_steps"] + 10
    assert l["h2d"] == s["h2d"]          # same events => same uploads
    perstep = _run_pipeline_server(megastep=0, pipeline="perstep")
    pstats = perstep.backend.transfer_stats
    assert pstats["h2d"] >= 3 * pstats["decode_steps"]
