"""Module-level oracles: MoE vs dense-ensemble, SSD vs naive recurrence,
RG-LRU associative scan vs sequential loop, chunked vs direct attention,
ring-buffer cache properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import layers, moe as moe_mod, rglru, ssm as ssm_mod
from repro.models.param import split


# ------------------------------------------------------------------ MoE ----

def test_moe_matches_dense_oracle():
    """With capacity >= all tokens, scatter-dispatch MoE == explicit per-token
    top-k mixture computed densely."""
    cfg = dataclasses.replace(
        get_config("dbrx-132b").smoke(),
        moe=dataclasses.replace(get_config("dbrx-132b").smoke().moe,
                                capacity_factor=8.0))
    p, _ = split(moe_mod.moe_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    got, aux = moe_mod.moe_apply(cfg, p, x)

    logits = (x @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for e in range(cfg.moe.n_experts):
        h = jax.nn.silu(x @ p["w1"]["w"][e]) * (x @ p["w3"]["w"][e])
        out_e = h @ p["w2"]["w"][e]
        w_e = ((gi == e) * gv).sum(-1)
        want = want + out_e * w_e[..., None].astype(out_e.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) >= 1.0 - 1e-3        # balanced lower bound is 1


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(
        get_config("dbrx-132m" if False else "dbrx-132b").smoke(),
        moe=dataclasses.replace(get_config("dbrx-132b").smoke().moe,
                                capacity_factor=0.25))
    p, _ = split(moe_mod.moe_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    got, _ = moe_mod.moe_apply(cfg, p, x)
    assert not jnp.isnan(got).any()        # drops, but stays finite


# ------------------------------------------------------------------ SSD ----

def naive_ssm(x, dt, A, B, C, D):
    """Step-by-step recurrence oracle."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    g = B.shape[2]
    rep = h // g
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    S = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    for t in range(l):
        decay = np.exp(dtn[:, t] * An[None])             # (b,h)
        upd = np.einsum("bhp,bhn->bhpn", xn[:, t] * dtn[:, t][..., None],
                        Bh[:, t])
        S = S * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bhn->bhp", S, Ch[:, t]) \
            + xn[:, t] * np.asarray(D)[None, :, None]
    return ys, S


@pytest.mark.parametrize("l,chunk", [(16, 4), (13, 8), (32, 32)])
def test_ssd_chunked_matches_naive(l, chunk):
    b, h, p, g, n = 2, 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, l, g, n)) * 0.3
    D = jnp.ones((h,))
    y, S = ssm_mod.ssd_chunked(x, dt, A, B, C, D, chunk)
    y_ref, S_ref = naive_ssm(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(S), S_ref, atol=1e-3, rtol=1e-3)


def test_ssd_step_matches_chunked_tail():
    b, l, h, p, g, n = 1, 9, 2, 4, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n)) * 0.3
    C = jax.random.normal(ks[4], (b, l, g, n)) * 0.3
    D = jnp.zeros((h,))
    y_full, _ = ssm_mod.ssd_chunked(x, dt, A, B, C, D, 4)
    _, S_prefix = ssm_mod.ssd_chunked(x[:, :-1], dt[:, :-1], A, B[:, :-1],
                                      C[:, :-1], D, 4)
    y_t, _ = ssm_mod.ssd_step(x[:, -1], dt[:, -1], A, B[:, -1], C[:, -1],
                              D, S_prefix)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------- RG-LRU ----

def test_rglru_scan_matches_sequential():
    cfg = get_config("recurrentgemma-2b").smoke()
    p, _ = split(rglru.rglru_block_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 7, cfg.d_model),
                          jnp.float32)
    y, cache = rglru.rglru_block_apply(cfg, p, x)
    # sequential: feed tokens one at a time through the decode step
    c = rglru.rglru_cache_init(cfg, 2)
    outs = []
    for t in range(7):
        yt, c = rglru.rglru_block_step(cfg, p, x[:, t:t + 1], c)
        outs.append(yt)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(c["h"]),
                               atol=2e-4, rtol=2e-4)


# ----------------------------------------------------------- attention ----

def test_chunked_matches_direct():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, L, H, KV, hd = 2, 300, 8, 2, 32
    q = jax.random.normal(ks[0], (B, L, H, hd))
    k = jax.random.normal(ks[1], (B, L, KV, hd))
    v = jax.random.normal(ks[2], (B, L, KV, hd))
    for window in (None, 64):
        direct = layers.attn_direct(
            q, k, v, layers.causal_mask(L, L, window=window))
        chunked = layers.attn_chunked(q, k, v, window=window, block=128)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                                   atol=2e-5, rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(L=st.integers(2, 40), slots=st.sampled_from([8, 16]),
       steps=st.integers(1, 10))
def test_ring_cache_property(L, slots, steps):
    """After prefill(L)+N decode writes, the cache holds exactly the last
    min(slots, L+N) positions under the ring invariant slot = pos % slots."""
    B, KV, hd = 1, 2, 4
    cache = layers.cache_init(B, KV, slots, hd, jnp.float32)
    k = jnp.arange(B * L * KV * hd, dtype=jnp.float32).reshape(B, L, KV, hd)
    pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    cache = layers.cache_write_prefill(cache, k, k, pos)
    for s in range(steps):
        p = L + s
        kt = jnp.full((B, 1, KV, hd), float(p))
        cache = layers.cache_write_token(cache, kt, kt,
                                         jnp.array([p], jnp.int32))
    live = sorted(int(x) for x in np.asarray(cache["pos"][0]) if x >= 0)
    total = L + steps
    want = list(range(max(0, total - slots), total))
    assert live == want
