"""End-to-end system behaviour: train a LoRA adapter on the synthetic
pipeline, serve it through the CaraServe engine, and verify the paper's
qualitative claims hold on the timeline metrics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.data.pipeline import DataConfig, packed_batches
from repro.models import model
from repro.models.param import split
from repro.serving.request import Request
from repro.training import optim, train


def test_train_then_serve_roundtrip():
    """Full life-cycle: base model -> LoRA fine-tune -> registered adapter ->
    served generation through the engine."""
    cfg = get_config("llama2-7b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))

    adapter = train.init_lora_adapter(cfg, rank=4, rng=jax.random.PRNGKey(1))
    ocfg = optim.AdamWConfig(lr=5e-2, warmup_steps=0, total_steps=30,
                             weight_decay=0.0)
    state = optim.init(adapter)
    step = jax.jit(train.make_lora_train_step(cfg, ocfg, rank=4))
    it = packed_batches(DataConfig(vocab=cfg.vocab, seq_len=32, batch=4))
    for _ in range(10):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        adapter, state, _ = step(adapter, state, params, b)

    srv = InferenceServer(cfg, mode="caraserve", max_batch=2,
                          cache_slots=64, numerics=True, params=params)
    srv.register_adapter(AdapterSpec("tuned", rank=4, base_model=cfg.name))
    srv.store._weights["tuned"] = {
        t: {"a": np.asarray(adapter[t]["a"]),
            "b": np.asarray(adapter[t]["b"])} for t in adapter}
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab
    out = srv.run([Request(rid=0, adapter_uid="tuned", prompt=prompt,
                           max_new_tokens=6, arrival_ms=0.0)])
    assert out["n"] == 1
    assert len(srv.states[0].generated) == 6


def test_paper_claim_cold_start_fraction():
    """Paper Fig 3-left / sec 2.3: under continuous batching, cold starts
    cumulatively delay in-flight decoding, and the inflation (ONDMD vs
    CACHED time-per-token) grows with the aggregate load."""
    cfg = get_config("llama2-7b")
    from repro.traces import gen
    inflation = []
    for rps in (3.0, 9.0):
        tpt = {}
        for mode in ("cached", "ondemand"):
            srv = InferenceServer(cfg, mode=mode, max_batch=16,
                                  numerics=False)
            rng = np.random.default_rng(0)
            adapters = gen.make_adapters(256, cfg.name, rng, uniform_rank=64)
            for ad in adapters:
                srv.register_adapter(ad)
            reqs = gen.synthetic_trace(adapters, rps=rps, duration_s=10,
                                       vocab=100, seed=1)
            out = srv.run(reqs)
            if mode == "ondemand":
                assert out["cold_starts"] == out["n"]   # distinct adapters
            tpt[mode] = out["tpt_mean"]
        inflation.append(tpt["ondemand"] / tpt["cached"])
    assert inflation[0] > 1.02            # cold starts visibly inflate TPT
    assert inflation[1] > inflation[0]    # and it worsens with load


def test_caraserve_beats_slora_e2e():
    """Headline claim (sec 7.2): CaraServe outperforms S-LoRA on TTFT and
    request latency on a cold-start-heavy synthetic trace."""
    cfg = get_config("llama2-7b")
    from repro.traces import gen
    rng = np.random.default_rng(3)
    adapters = gen.make_adapters(64, cfg.name, rng, uniform_rank=64)
    res = {}
    for mode, kernel in (("caraserve", "bgmv"), ("slora", "mbgmv"),
                         ("cached", "bgmv")):
        srv = InferenceServer(cfg, mode=mode, kernel=kernel, max_batch=16,
                              numerics=False)
        for ad in adapters:
            srv.register_adapter(ad)
        reqs = gen.synthetic_trace(adapters, rps=9.0, duration_s=10,
                                   vocab=100, seed=4)
        res[mode] = srv.run(reqs)
    assert res["caraserve"]["ttft_mean"] < res["slora"]["ttft_mean"]
    assert res["caraserve"]["latency_mean"] <= res["slora"]["latency_mean"]
    # rivals the CACHED oracle (paper: within ~22% on TTFT)
    assert res["caraserve"]["ttft_mean"] < 1.5 * res["cached"]["ttft_mean"]
