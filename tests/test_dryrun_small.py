"""Dry-run machinery validation in a subprocess (so the 512-device XLA flag
never leaks into this test process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, jax
from repro.launch.dryrun import run_combo
rec = run_combo("whisper-tiny", "decode_32k", multi_pod=False,
                out_dir="/tmp/dryrun_test")
print("REC=" + json.dumps({k: rec[k] for k in
      ("status", "chips", "fits_16g", "scan_corrected")}))
rec2 = run_combo("mamba2-130m", "long_500k", multi_pod=True,
                 out_dir="/tmp/dryrun_test")
print("REC2=" + json.dumps({k: rec2[k] for k in ("status", "chips")}))
rec3 = run_combo("whisper-tiny", "long_500k", multi_pod=False,
                 out_dir="/tmp/dryrun_test")
print("REC3=" + json.dumps({k: rec3[k] for k in ("status",)}))
"""


@pytest.mark.slow
def test_dryrun_machinery_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    recs = {}
    for line in out.stdout.splitlines():
        if line.startswith("REC"):
            key, payload = line.split("=", 1)
            recs[key] = json.loads(payload)
    assert recs["REC"]["status"] == "ok"
    assert recs["REC"]["chips"] == 256
    assert recs["REC"]["scan_corrected"]
    assert recs["REC2"]["status"] == "ok"      # multi-pod: 512 chips
    assert recs["REC2"]["chips"] == 512
    assert recs["REC3"]["status"] == "skipped"  # the documented skip


def test_mesh_functions_do_not_touch_devices_on_import():
    """Importing mesh.py must not initialize jax device state."""
    code = ("import repro.launch.mesh as m; "
            "import jax; print('ok')")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0 and "ok" in out.stdout
