"""Beyond-paper perf features: int8 KV cache numerics, seq-parallel flag,
serve-TP sharding rules, MoE variant equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import layers, model
from repro.models.param import split
from repro.sharding import RULES, serve_rules


def test_int8_cache_roundtrip():
    B, KV, S, hd = 2, 2, 8, 16
    c = layers.cache_init(B, KV, S, hd, jnp.float32, quantized=True)
    assert c["k"].dtype == jnp.int8
    k = jax.random.normal(jax.random.PRNGKey(0), (B, 3, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(3), (B, 3))
    c = layers.cache_write_prefill(c, k, k, pos)
    ck, cv = layers.cache_kv_for_attn(c, jnp.float32)
    got = np.asarray(ck[:, :, :3]).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, np.asarray(k), atol=2e-2, rtol=2e-2)


def test_int8_cache_decode_close_to_fp():
    cfg = get_config("qwen2-72b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    B, L = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L + 2), 0,
                              cfg.vocab)
    outs = {}
    for dt in ("", "int8"):
        c = dataclasses.replace(cfg, kv_cache_dtype=dt)
        logits, cache = model.prefill(c, params, {"tokens": toks[:, :L]},
                                      cache_slots=L + 4)
        lg, cache = model.decode(c, params, cache, toks[:, L:L + 1],
                                 jnp.full((B,), L, jnp.int32))
        lg2, _ = model.decode(c, params, cache, toks[:, L + 1:L + 2],
                              jnp.full((B,), L + 1, jnp.int32))
        outs[dt] = np.asarray(lg2[:, -1], np.float32)
    scale = np.abs(outs[""]).max()
    assert np.abs(outs["int8"] - outs[""]).max() / scale < 0.08


def test_seq_parallel_same_numerics():
    """seq_parallel is a sharding hint only — identical math on one device."""
    cfg = get_config("yi-9b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    a, _ = model.prefill(cfg, params, {"tokens": toks})
    b, _ = model.prefill(dataclasses.replace(cfg, seq_parallel=True),
                         params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_moe_gather_variant_same_numerics():
    cfg = get_config("dbrx-132b").smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    a, _ = model.prefill(cfg, params, {"tokens": toks})
    b, _ = model.prefill(dataclasses.replace(cfg, moe_gather_weights=True),
                         params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_serve_rules_drop_fsdp():
    r = serve_rules()
    assert r["embed_fsdp"] == ()
    assert r["mlp_fsdp"] == ("model",)
    assert RULES["embed_fsdp"] == ("data",)   # training rules untouched
