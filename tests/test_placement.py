"""Placement plane: policy assignment, placement-aware routing,
register-on-miss, popularity-driven rebalance, and the prefetch
reserve-before-evict fix."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.core.perf_model import ServerPerfModel
from repro.core.placement import (HashPlacement, Placement,
                                  make_placement_policy)
from repro.core.scheduler import make_scheduler
from repro.serving.request import Request
from repro.traces import gen

CFG = get_config("llama2-7b")


def mk_adapters(n, seed=0, uniform_rank=None):
    return gen.make_adapters(n, CFG.name, np.random.default_rng(seed),
                             uniform_rank=uniform_rank)


def mk_servers(n, mode="caraserve", max_batch=8):
    return [InferenceServer(CFG, mode=mode, max_batch=max_batch,
                            numerics=False) for _ in range(n)]


def mk_req(rid, uid, t, tokens=64, out=4, slo=None):
    return Request(rid=rid, adapter_uid=uid,
                   prompt=np.zeros(tokens, np.int32), max_new_tokens=out,
                   arrival_ms=t, slo_tpt_ms=slo)


# ------------------------------------------------------------ policies ----

def test_full_replication_covers_every_server():
    ads = mk_adapters(8)
    pl = make_placement_policy("full").assign(ads, 4)
    for a in ads:
        assert pl.hosts(a.uid) == [0, 1, 2, 3]
    assert pl.total_replicas() == 32


def test_hash_placement_deterministic_and_k_replicated():
    ads = mk_adapters(32)
    p1 = HashPlacement(replication=2).assign(ads, 6)
    p2 = HashPlacement(replication=2).assign(ads, 6)
    for a in ads:
        assert p1.hosts(a.uid) == p2.hosts(a.uid)
        assert p1.n_replicas(a.uid) == 2
    # sharded, not full: no server hosts everything
    assert all(len(p1.server_adapters(i)) < len(ads) for i in range(6))


def test_rank_balanced_evens_rank_mass():
    ads = mk_adapters(40, seed=3)
    pl = make_placement_policy("rank_balanced").assign(ads, 4)
    mass = [0.0] * 4
    for a in ads:
        (i,) = pl.hosts(a.uid)
        mass[i] += a.rank
    # greedy LPT bound: spread no worse than the heaviest single item
    assert max(mass) - min(mass) <= max(a.rank for a in ads)


def test_popularity_placement_replicates_hot_adapters():
    ads = mk_adapters(32, seed=1)
    pop = {a.uid: p for a, p in
           zip(ads, gen.zipf_popularity(len(ads), 1.1))}
    pl = make_placement_policy("popularity", spread=2.0).assign(
        ads, 8, popularity=pop)
    hot = max(ads, key=lambda a: pop[a.uid])
    cold = min(ads, key=lambda a: pop[a.uid])
    assert pl.n_replicas(hot.uid) > pl.n_replicas(cold.uid)
    assert all(pl.n_replicas(a.uid) >= 1 for a in ads)


def test_popularity_placement_spreads_without_prior():
    """Adapters absent from the popularity prior (or no prior at all) fall
    back to rank-balanced spreading — not all onto one server."""
    ads = mk_adapters(64, seed=2)
    pl = make_placement_policy("popularity").assign(ads, 8, popularity=None)
    counts = [len(pl.server_adapters(i)) for i in range(8)]
    assert min(counts) > 0
    assert max(counts) <= 2 * (len(ads) // 8)


def test_placement_mutation_guards():
    pl = Placement({"a": [0]}, 2)
    assert not pl.drop_replica("a", 0)          # never below one replica
    assert pl.add_replica("a", 1)
    assert not pl.add_replica("a", 1)           # idempotent
    assert pl.drop_replica("a", 0)
    assert pl.hosts("a") == [1]


# ----------------------------------------------------- sharded routing ----

def test_sharded_cluster_routes_only_to_hosting_servers():
    ads = mk_adapters(16)
    pl = HashPlacement(replication=1).assign(ads, 4)
    reqs = gen.maf_trace(ads, rps=30, duration_s=3, vocab=100, seed=1)
    cl = Cluster(mk_servers(4), make_scheduler("most_idle"),
                 placement=pl, specs=ads)
    out, states = cl.run(reqs)
    assert out["n"] == len(reqs)
    # every replica alive + no SLO notion => no miss installs, and every
    # request executed on a server its adapter is placed on
    assert cl.placement_stats["miss_installs"] == 0
    for i, s in enumerate(cl.servers):
        for st in s.states:
            assert i in pl.hosts(st.req.adapter_uid), (i, st.req.adapter_uid)


def test_cluster_materializes_shards_on_bare_servers():
    ads = mk_adapters(8)
    pl = HashPlacement(replication=1).assign(ads, 2)
    cl = Cluster(mk_servers(2), make_scheduler("most_idle"),
                 placement=pl, specs=ads)
    for a in ads:
        for i in range(2):
            assert (a.uid in cl.servers[i].store) == (i in pl.hosts(a.uid))


def test_register_on_miss_when_no_replica_alive():
    ads = mk_adapters(4)
    pl = HashPlacement(replication=1).assign(ads, 3)
    cl = Cluster(mk_servers(3), make_scheduler("most_idle"),
                 placement=pl, specs=ads)
    uid = ads[0].uid
    (home,) = pl.hosts(uid)
    cl.set_down(home)
    out, states = cl.run([mk_req(0, uid, 5.0)])
    assert out["n"] == 1
    assert cl.placement_stats["miss_installs"] == 1
    new_hosts = [i for i in pl.hosts(uid) if i != home]
    assert len(new_hosts) == 1 and new_hosts[0] != home
    assert len(cl.servers[home].states) == 0
    assert len(cl.servers[new_hosts[0]].states) == 1
    # the miss replica was installed mid-run, stamped with the miss time
    assert cl.servers[new_hosts[0]].store.registered_ms[uid] == 5.0


def test_register_on_miss_when_replicas_slo_saturated():
    """A hot adapter pinned to one server: once that server would break the
    decode SLO, the rank-aware scheduler opens the candidate set and a new
    replica is installed on the fly (hot-adapter replication emerges)."""
    ads = mk_adapters(2, uniform_rank=64)
    hot, other = ads[0].uid, ads[1].uid
    perf = ServerPerfModel(CFG, kernel="bgmv")
    slo = perf.dec_perf([64] * 3)     # breaks at ~3 concurrent rank-64s
    pl = Placement({hot: [0], other: [1]}, 2)
    cl = Cluster(mk_servers(2, max_batch=8),
                 make_scheduler("rank_aware", perf, slo_ms=slo),
                 placement=pl, specs=ads)
    reqs = [mk_req(i, hot, float(i), out=16, slo=slo) for i in range(8)]
    out, _ = cl.run(reqs)
    assert out["n"] == len(reqs)
    assert cl.placement_stats["miss_installs"] >= 1
    assert pl.n_replicas(hot) >= 2
    assert len(cl.servers[1].states) >= 1     # overflow actually served


# --------------------------------------------------------- rebalance ----

def test_rebalance_follows_popularity():
    """Replica targets track the aggregated popularity EWMA: hot adapters
    gain replicas, over-replicated cold ones are trimmed."""
    ads = mk_adapters(4, uniform_rank=16)
    hot, cold = ads[0].uid, ads[1].uid
    pl = Placement({a.uid: [i % 4] for i, a in enumerate(ads)}, 4)
    for _ in range(2):
        pl.add_replica(cold, (pl.hosts(cold)[-1] + 1) % 4)
    cl = Cluster(mk_servers(4), make_scheduler("most_idle"),
                 placement=pl, specs=ads, rebalance_every_ms=100.0,
                 replica_spread=3.0)
    # drive popularity through the public path: a hot-skewed arrival mix
    reqs = [mk_req(i, hot if i % 8 else cold, float(i) * 5.0)
            for i in range(64)]
    out, _ = cl.run(reqs)
    assert out["n"] == len(reqs)
    assert cl.event_counts["rebalance"] > 0
    assert cl.placement_stats["replica_adds"] > 0
    assert cl.placement_stats["replica_drops"] > 0
    assert pl.n_replicas(hot) > 1
    assert pl.n_replicas(cold) < 3


def test_rebalance_readd_does_not_duplicate_resident_slot():
    """Dropping a replica keeps its pool slot; re-adding it later must not
    reserve a second slot / start a redundant upload for the same uid."""
    ads = mk_adapters(2, uniform_rank=16)
    hot = ads[0].uid
    pl = Placement({ads[0].uid: [0, 1], ads[1].uid: [1]}, 2)
    cl = Cluster(mk_servers(2), make_scheduler("most_idle"),
                 placement=pl, specs=ads, replica_spread=4.0)
    srv = cl.servers[1]
    srv.cold._insert(hot)                       # resident + ready
    pl.drop_replica(hot, 1)
    for i in range(8):                          # make `hot` clearly hot
        cl.servers[0].submit(mk_req(i, hot, float(i)))
    cl._rebalance(8.0)
    assert 1 in pl.hosts(hot)                   # replica re-added
    assert srv.pool.slot_uid.count(hot) == 1    # no duplicate slot
    assert srv.cold.tracker.pending_for(hot) is None   # no second upload


def test_rebalance_deterministic():
    def once():
        ads = mk_adapters(8)
        pl = HashPlacement(replication=1).assign(ads, 4)
        cl = Cluster(mk_servers(4), make_scheduler("most_idle"),
                     placement=pl, specs=ads, rebalance_every_ms=200.0)
        reqs = gen.maf_trace(ads, rps=25, duration_s=3, vocab=100, seed=2)
        out, _ = cl.run(reqs)
        return out, cl.event_counts, cl.placement_stats
    assert once() == once()


# ------------------------------------------------------------- traces ----

def test_zipf_rng_permutes_hot_adapter():
    base = gen.zipf_popularity(16)
    perm = gen.zipf_popularity(16, rng=np.random.default_rng(0))
    assert np.allclose(sorted(base), sorted(perm))
    assert not np.allclose(base, perm)     # adapter 0 no longer pinned hot
    assert abs(perm.sum() - 1.0) < 1e-9


def test_trace_popularity_shares():
    ads = mk_adapters(8)
    reqs = gen.maf_trace(ads, rps=50, duration_s=4, vocab=100, seed=5)
    pop = gen.trace_popularity(reqs)
    assert abs(sum(pop.values()) - 1.0) < 1e-9
    assert max(pop.values()) > 2.0 / len(ads)   # still skewed


def test_drifting_trace_moves_hot_set():
    ads = mk_adapters(16)
    reqs = gen.drifting_maf_trace(ads, rps=120, duration_s=6, vocab=100,
                                  seed=0, n_phases=3)
    third = 2000.0
    head = gen.trace_popularity([r for r in reqs if r.arrival_ms < third])
    tail = gen.trace_popularity([r for r in reqs
                                 if r.arrival_ms >= 2 * third])
    hot_head = max(head, key=head.get)
    hot_tail = max(tail, key=tail.get)
    assert hot_head != hot_tail


# ---------------------------------------------------- prefetch fix ----

def _resident_server(uids, n_slots):
    srv = InferenceServer(CFG, mode="caraserve", max_batch=4, numerics=False,
                          prefetch=True, pool_slots=n_slots)
    for u in uids:
        srv.register_adapter(AdapterSpec(u, 16, CFG.name))
    return srv


def test_prefetch_reserve_failure_evicts_nothing(monkeypatch):
    """Reserve-first: when the reservation cannot be honoured, the resident
    victim must survive (the old evict-then-load order lost it)."""
    srv = _resident_server(["a", "b", "hot"], n_slots=2)
    for u in ("a", "b"):
        srv.cold._insert(u)
    srv.admission._popularity = {"hot": 100.0, "a": 1.0, "b": 0.1}
    before = list(srv.pool.slot_uid)
    monkeypatch.setattr(srv.cold, "load_async", lambda *a, **k: None)
    srv.admission.prefetch_tick(0.0)
    assert srv.pool.slot_uid == before


def test_prefetch_overwrites_least_popular_victim():
    srv = _resident_server(["a", "b", "hot"], n_slots=2)
    for u in ("a", "b"):
        srv.cold._insert(u)
    srv.admission._popularity = {"hot": 100.0, "a": 1.0, "b": 0.1}
    srv.admission.prefetch_tick(0.0)
    assert "hot" in srv.pool.slot_uid          # upload reserved in place
    assert "a" in srv.pool.slot_uid            # more popular resident kept
    assert "b" not in srv.pool.slot_uid        # least popular replaced
    hot_slot = srv.pool.slot_uid.index("hot")
    assert not srv.pool.is_ready(hot_slot)     # upload in flight, not landed


def test_popularity_tracked_without_prefetch():
    srv = InferenceServer(CFG, mode="caraserve", max_batch=4, numerics=False)
    srv.register_adapter(AdapterSpec("u", 16, CFG.name))
    srv.submit(mk_req(0, "u", 0.0))
    assert srv.admission.popularity().get("u", 0.0) > 0.0


def test_popularity_fades_in_simulated_time():
    """The EWMA is time-indexed: a server whose traffic dries up reports
    faded scores at the rebalance instant, not its frozen peak."""
    srv = InferenceServer(CFG, mode="caraserve", max_batch=4, numerics=False)
    for u in ("hot", "late"):
        srv.register_adapter(AdapterSpec(u, 16, CFG.name))
    for i in range(10):
        srv.submit(mk_req(i, "hot", float(i)))
    peak = srv.admission.popularity(10.0)["hot"]
    faded = srv.admission.popularity(10.0 + 1e5)["hot"]
    assert faded < 1e-3 * peak
    # a late arrival on another adapter outweighs the decayed burst
    srv.submit(mk_req(10, "late", 1e5))
    pop = srv.admission.popularity(1e5)
    assert pop["late"] > pop["hot"]


def test_unknown_adapter_raises_lookup_error():
    ads = mk_adapters(2)
    pl = HashPlacement(replication=1).assign(ads, 2)
    cl = Cluster(mk_servers(2), make_scheduler("most_idle"),
                 placement=pl, specs=ads)
    with pytest.raises(LookupError):
        cl._route(mk_req(0, "never-registered", 0.0))
    assert cl.placement_stats["miss_installs"] == 0


# ----------------------------------------------------- partial outage ----

def test_register_on_miss_skips_down_servers():
    """With every replica and all-but-one spare server down, the miss
    install must land on the sole alive server — never a dead one."""
    ads = mk_adapters(1)
    uid = ads[0].uid
    pl = Placement({uid: [0]}, 4)
    cl = Cluster(mk_servers(4), make_scheduler("most_idle"),
                 placement=pl, specs=ads)
    for i in (0, 1, 2):
        cl.set_down(i)
    out, _ = cl.run([mk_req(0, uid, 5.0)])
    assert out["n"] == 1
    assert len(cl.servers[3].states) == 1
    assert 3 in pl.hosts(uid)
    assert all(len(cl.servers[i].states) == 0 for i in (0, 1, 2))


def test_rebalance_never_adds_replicas_on_down_servers():
    """The popularity rebalance pass must treat a down server as
    non-existent: replicas of the hot adapter spread over survivors
    only."""
    ads = mk_adapters(4, uniform_rank=16)
    hot = ads[0].uid
    pl = Placement({a.uid: [0] for a in ads}, 4)
    cl = Cluster(mk_servers(4), make_scheduler("most_idle"),
                 placement=pl, specs=ads, rebalance_every_ms=20.0,
                 replica_spread=4.0)
    cl.set_down(3)
    reqs = [mk_req(i, hot, 2.0 * i) for i in range(40)]
    out, _ = cl.run(reqs)
    assert out["n"] == len(reqs)
    assert cl.placement_stats["replica_adds"] >= 1
    assert 3 not in pl.hosts(hot)
    assert len(cl.servers[3].states) == 0


def test_hosting_heals_on_restart():
    """A crashed replica rejoins warm: after the scripted restart the
    server hosts its adapters again, the cluster re-warms the hottest
    through the prefetch path, and post-restart arrivals land on it."""
    from repro.core.faults import FaultEvent, FaultPlane
    ads = mk_adapters(2, uniform_rank=16)
    hot = ads[0].uid
    pl = Placement({ads[0].uid: [1], ads[1].uid: [0]}, 2)
    faults = FaultPlane([FaultEvent(30.0, "crash", 1),
                         FaultEvent(80.0, "restart", 1)], seed=0)
    cl = Cluster(mk_servers(2), make_scheduler("most_idle"),
                 placement=pl, specs=ads, faults=faults)
    reqs = [mk_req(i, hot, 10.0 * i, out=2) for i in range(30)]
    out, _ = cl.run(reqs)
    assert out["n"] == len(reqs)
    assert cl.fault_stats == {"crashes": 1, "restarts": 1,
                              "drained": cl.fault_stats["drained"],
                              "failovers": cl.fault_stats["failovers"],
                              "shed": 0}
    assert 1 in pl.hosts(hot)                  # hosting set intact
    # post-restart arrivals are served by the rejoined replica again
    post = [s for s in cl.servers[1].states if s.req.arrival_ms > 80.0]
    assert post, "restarted server never served again"
    # the rejoin was warm: its hottest hosted adapter was prefetched and
    # is resident (the warm upload, not a demand cold start, paid for it)
    assert cl.servers[1].pool.lookup(hot) is not None
