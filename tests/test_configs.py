"""Config registry: every assigned architecture loads with the exact assigned
hyper-parameters; smoke reductions stay within the mandated bounds."""
import pytest

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, combo_is_supported,
                                get_config)

EXPECT = {
    "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
                         d_ff=1536, vocab=51865),
    "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                              n_kv_heads=1, d_ff=7680, vocab=256000),
    "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
                      d_ff=10752, vocab=100352),
    "mistral-large-123b": dict(n_layers=88, d_model=12288, n_heads=96,
                               n_kv_heads=8, d_ff=28672, vocab=32768),
    "phi-3-vision-4.2b": dict(n_layers=32, d_model=3072, n_heads=32,
                              n_kv_heads=32, d_ff=8192, vocab=32064),
    "command-r-35b": dict(n_layers=40, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=22528, vocab=256000),
    "yi-9b": dict(n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
                  d_ff=11008, vocab=64000),
    "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
                        d_ff=32768, vocab=131072),
    "mamba2-130m": dict(n_layers=24, d_model=768, vocab=50280),
    "qwen2-72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                      d_ff=29568, vocab=152064),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_config_exact(arch):
    cfg = get_config(arch)
    for k, v in EXPECT[arch].items():
        assert getattr(cfg, k) == v, (arch, k)
    assert cfg.citation


def test_moe_shapes():
    assert get_config("dbrx-132b").moe.n_experts == 16
    assert get_config("dbrx-132b").moe.top_k == 4
    assert get_config("grok-1-314b").moe.n_experts == 8
    assert get_config("grok-1-314b").moe.top_k == 2


def test_special_flags():
    assert get_config("qwen2-72b").qkv_bias
    assert not get_config("command-r-35b").qkv_bias
    assert get_config("mamba2-130m").ssm.state_dim == 128
    assert get_config("recurrentgemma-2b").hybrid.pattern == \
        ("rglru", "rglru", "attn")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduction_bounds(arch):
    s = get_config(arch).smoke()
    assert s.n_layers <= 3 and s.d_model <= 512
    if s.moe:
        assert s.moe.n_experts <= 4
    assert s.family == get_config(arch).family


def test_param_counts_order_of_magnitude():
    assert 100e9 < get_config("mistral-large-123b").param_count() < 140e9
    assert 250e9 < get_config("grok-1-314b").param_count() < 340e9
    assert 100e6 < get_config("mamba2-130m").param_count() < 220e6
    assert 60e9 < get_config("qwen2-72b").param_count() < 80e9
    # MoE active < total
    g = get_config("grok-1-314b")
    assert g.active_param_count() < 0.45 * g.param_count()


def test_combo_support_matrix():
    """39 of 40 combos run; whisper long_500k is the documented skip."""
    n_ok = 0
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES.values():
            ok, why = combo_is_supported(get_config(arch), shape)
            n_ok += ok
            if not ok:
                assert arch == "whisper-tiny" and shape.name == "long_500k"
    assert n_ok == 39
