"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of the
same family runs one forward + one decode + one train step on CPU, asserting
output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model
from repro.models.param import split
from repro.training import optim, train


def make_batch(cfg, B, L, key=0):
    rng = jax.random.PRNGKey(key)
    ks = jax.random.split(rng, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, L), 0, cfg.vocab)}
    if cfg.family in ("audio", "encdec"):
        batch["enc_embeds"] = jax.random.normal(
            ks[1], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_decode_train(arch):
    cfg = get_config(arch).smoke()
    params, axes = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    B, L = 2, 12
    batch = make_batch(cfg, B, L)

    # forward (prefill) + cache
    logits, cache = model.prefill(cfg, params, batch, cache_slots=L + 4)
    offset = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (B, L + offset, cfg.vocab)
    assert not jnp.isnan(logits).any()
    assert cache is not None

    # one decode step
    lg2, cache2 = model.decode(cfg, params, cache,
                               jnp.zeros((B, 1), jnp.int32),
                               jnp.full((B,), offset + L, jnp.int32))
    assert lg2.shape == (B, 1, cfg.vocab)
    assert not jnp.isnan(lg2).any()

    # one train step
    ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = optim.init(params)
    step = train.make_train_step(cfg, ocfg, accum=1)
    batch_t = dict(batch, loss_mask=jnp.ones((B, L), jnp.int32))
    new_params, state, metrics = jax.jit(step)(params, state, batch_t)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_smoke_sliding_window_decode(arch):
    """long-context decode path: window-limited cache still sane."""
    cfg = get_config(arch).smoke()
    params, _ = split(model.init_params(cfg, jax.random.PRNGKey(0)))
    B, L = 2, 24                       # longer than smoke window (16/8)
    batch = make_batch(cfg, B, L)
    window = cfg.sliding_window if cfg.family == "dense" else None
    logits, cache = model.prefill(cfg, params, batch, cache_slots=L,
                                  window=window)
    lg, _ = model.decode(cfg, params, cache, jnp.zeros((B, 1), jnp.int32),
                         jnp.full((B,), L, jnp.int32), window=window)
    assert not jnp.isnan(lg).any()
