"""Pure-JAX AdamW with decoupled weight decay, global-norm clipping and a
linear-warmup cosine schedule. Optimizer state is a pytree matching params;
fp32 moments regardless of param dtype."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"   # bf16 halves optimizer memory (>=70B)


class AdamWState(NamedTuple):
    step: Any
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params, moments_dtype=jnp.float32) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, jnp.dtype(moments_dtype))
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = mu_n / b1c
        vhat = nu_n / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p_n = p.astype(jnp.float32) - lr * (delta + wd)
        return p_n.astype(p.dtype), mu_n.astype(mdt), nu_n.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}
