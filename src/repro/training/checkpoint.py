"""Checkpointing: flattened-pytree .npz snapshots with structure manifest,
atomic writes, and step-indexed retention."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree: Any, step: Optional[int] = None,
         extra: Optional[dict] = None):
    """Atomic save of any pytree of arrays."""
    leaves, treedef = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def to_np(x):
        a = np.asarray(x)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = np.asarray(x, dtype=np.float32)   # npz-safe; exact for bf16
        return a

    payload = {f"leaf_{i}": to_np(x) for i, x in enumerate(leaves)}
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves),
                "step": step, "extra": extra or {}}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    np.savez(tmp, __manifest__=json.dumps(manifest), **payload)
    os.remove(tmp)                       # mkstemp placeholder
    os.replace(tmp + ".npz", path)       # savez appended .npz


def load(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of `like` (shape/dtype validated)."""
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["__manifest__"]))
        leaves = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    ref_leaves, treedef = _flatten(like)
    if len(leaves) != len(ref_leaves):
        raise ValueError(
            f"leaf count mismatch: {len(leaves)} != {len(ref_leaves)}")
    import jax.numpy as jnp
    out = []
    for got, ref in zip(leaves, ref_leaves):
        if got.shape != ref.shape:
            raise ValueError(
                f"shape mismatch: {got.shape} != {ref.shape}")
        out.append(jnp.asarray(got).astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.startswith("ckpt_") and f.endswith(".npz"):
            try:
                steps.append(int(f[5:-4]))
            except ValueError:
                pass
    return max(steps) if steps else None


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step}.npz")


def retain(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted([int(f[5:-4]) for f in os.listdir(ckpt_dir)
                    if f.startswith("ckpt_") and f.endswith(".npz")])
    for s in steps[:-keep]:
        os.remove(step_path(ckpt_dir, s))
