"""Training step builders: full fine-tuning and LoRA-adapter training
(the substrate that produces the adapters CaraServe serves).

train_step supports gradient accumulation over `cfg.accum_steps` microbatches
(lax.scan) — with per-layer remat in the model this is what bounds the
activation footprint of train_4k on the >=70B architectures (DESIGN.md sec 5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.training import optim


def _microbatches(batch, accum: int):
    """(B, ...) -> (accum, B/accum, ...)."""
    def rs(x):
        b = x.shape[0]
        if b % accum:
            raise ValueError(
                f"batch ({b}) must be a multiple of accum ({accum})")
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(rs, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                    accum: Optional[int] = None):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).
    Full fine-tuning of all params."""
    accum = accum or cfg.accum_steps

    def loss_fn(params, mb):
        return model_lib.loss(cfg, params, mb)

    # grad-accum buffer dtype follows the optimizer-moments memory toggle
    acc_dtype = jnp.dtype(cfg.opt_moments_dtype)

    def train_step(params, opt_state, batch):
        if accum > 1:
            mbs = _microbatches(batch, accum)

            def body(acc, mb):
                (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc, l_acc = acc
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              params)
            (grads, ltot), _ = jax.lax.scan(body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = ltot / accum
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        params, opt_state, stats = optim.apply(opt_cfg, params, grads,
                                               opt_state)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def make_lora_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                         rank: int):
    """LoRA fine-tuning: base params frozen; gradients flow only to the
    adapter (a single-slot pool, the same structure the engine serves)."""
    from repro.core import lora as lora_lib

    def loss_fn(adapter, params, batch):
        pool = {t: {"a": adapter[t]["a"][:, None],
                    "b": adapter[t]["b"][:, None]} for t in adapter}
        pool["ranks"] = jnp.full((1,), rank, jnp.int32)
        B = batch["tokens"].shape[0]
        lora = {"pool": pool, "idx": jnp.zeros((B,), jnp.int32),
                "mode": "bgmv"}
        return model_lib.loss(cfg, params, batch, lora=lora)

    def train_step(adapter, opt_state, params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            adapter, params, batch)
        adapter, opt_state, stats = optim.apply(opt_cfg, adapter, grads,
                                                opt_state)
        return adapter, opt_state, {"loss": loss, **stats}

    return train_step


def init_lora_adapter(cfg: ModelConfig, rank: int, rng):
    """Trainable adapter pytree {target: {a,b}} with layer-leading dims;
    B zero-init (standard LoRA) so training starts at the base model."""
    from repro.core.lora import lora_target_dims
    L = cfg.n_layers + cfg.n_enc_layers
    r_max = cfg.lora.max_rank
    rank = min(rank, r_max)
    out = {}
    keys = jax.random.split(rng, len(cfg.lora.targets))
    for k, tgt in zip(keys, cfg.lora.targets):
        d_in, d_out = lora_target_dims(cfg, tgt)
        a = jax.random.normal(k, (L, d_in, r_max), jnp.float32) * d_in ** -0.5
        a = a * (jnp.arange(r_max)[None, None] < rank)
        out[tgt] = {"a": a.astype(cfg.jdtype),
                    "b": jnp.zeros((L, r_max, d_out), cfg.jdtype)}
    return out
