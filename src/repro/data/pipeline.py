"""Token data pipeline: synthetic corpus -> document packing -> fixed-length
batches with loss masks; deterministic, shardable by (host, n_hosts)."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    doc_len_mean: float = 180.0
    doc_len_std: float = 0.6     # lognormal sigma
    bos: int = 1
    eos: int = 2


class SyntheticCorpus:
    """Markov-ish synthetic token stream: documents with topic-biased token
    distributions so models can actually reduce loss on it."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def documents(self, rng) -> Iterator[np.ndarray]:
        c = self.cfg
        n_topics = 32
        topic_bias = None
        while True:
            topic = rng.integers(n_topics)
            tr = np.random.default_rng(topic + 7919)
            logits = tr.normal(0, 2.0, c.vocab)
            p = np.exp(logits - logits.max())
            p /= p.sum()
            length = int(np.clip(rng.lognormal(np.log(c.doc_len_mean),
                                               c.doc_len_std), 8, 4 * c.seq_len))
            toks = rng.choice(c.vocab, size=length, p=p)
            yield np.concatenate([[c.bos], toks, [c.eos]]).astype(np.int32)


def packed_batches(cfg: DataConfig, host: int = 0, n_hosts: int = 1
                   ) -> Iterator[dict]:
    """Yields {tokens: (B, L) int32, loss_mask: (B, L) int32} forever.
    Documents are packed back-to-back; loss_mask zeroes padding."""
    rng = np.random.default_rng(cfg.seed * 1000003 + host)
    corpus = SyntheticCorpus(cfg)
    docs = corpus.documents(rng)
    buf = np.zeros(0, np.int32)
    while True:
        tokens = np.zeros((cfg.batch, cfg.seq_len), np.int32)
        mask = np.zeros((cfg.batch, cfg.seq_len), np.int32)
        for b in range(cfg.batch):
            while len(buf) < cfg.seq_len:
                buf = np.concatenate([buf, next(docs)])
            tokens[b] = buf[:cfg.seq_len]
            mask[b] = 1
            buf = buf[cfg.seq_len:]
        yield {"tokens": tokens, "loss_mask": mask}
