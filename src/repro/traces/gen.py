"""Workload generators (paper sec 7.1).

* synthetic: Poisson aggregate arrivals, each request targeting a distinct
  adapter (every request cold-starts — Punica's setting).
* maf_like: MAF-style skewed adapter popularity (the offline stand-in for the
  Azure Functions trace: Zipf-distributed invocation probabilities matching
  the shape of paper Fig 12), Poisson arrivals.
* Request lengths follow an Alpaca-like distribution (lognormal prompt/output
  lengths clipped to the serving window).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.lora import AdapterSpec
from repro.serving.request import Request

RANK_CHOICES = (8, 16, 32, 64)


def alpaca_lengths(rng, n, max_prompt=128, max_out=128, scale=1.0):
    """Alpaca-like prompt/response token lengths."""
    p = np.clip(rng.lognormal(3.3, 0.8, n) * scale, 4, max_prompt)
    o = np.clip(rng.lognormal(3.9, 0.9, n) * scale, 4, max_out)
    return p.astype(int), o.astype(int)


def make_adapters(n, base_model, rng, ranks=RANK_CHOICES,
                  uniform_rank: Optional[int] = None) -> List[AdapterSpec]:
    return [AdapterSpec(uid=f"lora-{i}",
                        rank=int(uniform_rank or rng.choice(ranks)),
                        base_model=base_model) for i in range(n)]


def zipf_popularity(n, a=1.1, rng=None):
    """Invocation probability mass, shaped like paper Fig 12. With `rng`
    the mass is permuted across adapters, so which adapter is hot is
    seed-dependent — without this, adapter 0 was *always* the hottest and
    placement/prefetch experiments were accidentally aligned with adapter
    registration order."""
    w = 1.0 / np.arange(1, n + 1) ** a
    p = w / w.sum()
    if rng is not None:
        p = rng.permutation(p)
    return p


def trace_popularity(requests: Sequence[Request]) -> dict:
    """Empirical per-adapter request share of a trace (the popularity prior
    handed to popularity-aware placement; a warmup prefix works too)."""
    counts: dict = {}
    for r in requests:
        counts[r.adapter_uid] = counts.get(r.adapter_uid, 0) + 1
    total = max(sum(counts.values()), 1)
    return {u: c / total for u, c in counts.items()}


def poisson_arrivals(rng, rps: float, duration_s: float):
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / rps)
        if t > duration_s:
            return np.array(out)
        out.append(t)


def _build_requests(rng, arrivals, plens, olens, pick, vocab,
                    slo_tpt_ms) -> List[Request]:
    """Shared request-construction loop. `pick(i, t_s)` chooses the adapter
    for the i-th arrival (called in-loop so generators that draw the
    adapter from `rng` keep their stream order)."""
    reqs = []
    for i, t in enumerate(arrivals):
        ad = pick(i, t)
        prompt = rng.integers(0, vocab, plens[i]).astype(np.int32)
        reqs.append(Request(rid=i, adapter_uid=ad.uid, prompt=prompt,
                            max_new_tokens=int(olens[i]),
                            arrival_ms=float(t * 1e3),
                            slo_tpt_ms=slo_tpt_ms))
    return reqs


def synthetic_trace(adapters: Sequence[AdapterSpec], rps: float,
                    duration_s: float, vocab: int, seed: int = 0,
                    distinct: bool = True, slo_tpt_ms: Optional[float] = None,
                    max_prompt=128, max_out=128) -> List[Request]:
    """Poisson aggregate; `distinct` cycles adapters so that every request
    triggers a load (paper sec 7.1 synthetic workload)."""
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(rng, rps, duration_s)
    plens, olens = alpaca_lengths(rng, len(arrivals), max_prompt, max_out)
    pick = (lambda i, t: adapters[i % len(adapters)]) if distinct \
        else (lambda i, t: adapters[int(rng.integers(len(adapters)))])
    return _build_requests(rng, arrivals, plens, olens, pick, vocab,
                           slo_tpt_ms)


def maf_trace(adapters: Sequence[AdapterSpec], rps: float, duration_s: float,
              vocab: int, seed: int = 0, zipf_a: float = 1.1,
              slo_tpt_ms: Optional[float] = None,
              max_prompt=128, max_out=128) -> List[Request]:
    """Skewed-popularity production-like workload (paper Fig 12/14)."""
    rng = np.random.default_rng(seed)
    pop = zipf_popularity(len(adapters), zipf_a, rng)
    arrivals = poisson_arrivals(rng, rps, duration_s)
    n = len(arrivals)
    plens, olens = alpaca_lengths(rng, n, max_prompt, max_out)
    picks = rng.choice(len(adapters), size=n, p=pop)
    return _build_requests(rng, arrivals, plens, olens,
                           lambda i, t: adapters[int(picks[i])], vocab,
                           slo_tpt_ms)


def bimodal_prompt_trace(adapters: Sequence[AdapterSpec], rps: float,
                         duration_s: float, vocab: int, seed: int = 0,
                         zipf_a: float = 1.1, long_frac: float = 0.2,
                         short_prompt: int = 64, long_prompt: int = 512,
                         long_tail: float = 2.5, max_prompt: int = 2048,
                         max_out: int = 128,
                         slo_tpt_ms: Optional[float] = None
                         ) -> List[Request]:
    """Prefill-interference workload: MAF-style skewed popularity over
    Poisson arrivals, with a *bimodal* prompt-length mixture — a
    `long_frac` share of requests carries a long prompt (Pareto-tailed
    above `long_prompt`, shape `long_tail`, clipped to `max_prompt`), the
    rest an Alpaca-like short prompt around `short_prompt`. Long prompts
    are where monolithic prefill stalls the resident decode batch; this
    trace makes that interference measurable (bench_chunked's P99
    inter-token latency gate) while keeping the arrival/popularity
    machinery of `maf_trace`."""
    rng = np.random.default_rng(seed)
    pop = zipf_popularity(len(adapters), zipf_a, rng)
    arrivals = poisson_arrivals(rng, rps, duration_s)
    n = len(arrivals)
    plens, olens = alpaca_lengths(rng, n, short_prompt, max_out)
    is_long = rng.random(n) < long_frac
    tail = (long_prompt * rng.pareto(long_tail, n) + long_prompt)
    plens = np.where(is_long, np.clip(tail, long_prompt, max_prompt),
                     plens).astype(int)
    picks = rng.choice(len(adapters), size=n, p=pop)
    return _build_requests(rng, arrivals, plens, olens,
                           lambda i, t: adapters[int(picks[i])], vocab,
                           slo_tpt_ms)


def drifting_maf_trace(adapters: Sequence[AdapterSpec], rps: float,
                       duration_s: float, vocab: int, seed: int = 0,
                       zipf_a: float = 1.1, n_phases: int = 3,
                       slo_tpt_ms: Optional[float] = None,
                       max_prompt=128, max_out=128) -> List[Request]:
    """Placement-stressing workload: MAF-style skew whose *hot set drifts* —
    the Zipf mass is re-permuted every ``duration/n_phases`` seconds, so a
    static placement tuned to the opening phase goes stale and the cluster
    must register-on-miss / rebalance replicas to follow the traffic."""
    rng = np.random.default_rng(seed)
    pops = [zipf_popularity(len(adapters), zipf_a, rng)
            for _ in range(n_phases)]
    arrivals = poisson_arrivals(rng, rps, duration_s)
    plens, olens = alpaca_lengths(rng, len(arrivals), max_prompt, max_out)
    phase_s = duration_s / n_phases

    def pick(i, t):
        pop = pops[min(int(t / phase_s), n_phases - 1)]
        return adapters[int(rng.choice(len(adapters), p=pop))]

    return _build_requests(rng, arrivals, plens, olens, pick, vocab,
                           slo_tpt_ms)
