"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, extract memory/cost/collective analyses, and emit the
roofline table rows (EXPERIMENTS.md sec Dry-run / sec Roofline).

MUST be the process entrypoint: the XLA flag below creates 512 placeholder
host devices and jax locks the device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out d/]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import jax_compat  # noqa: E402
from repro import roofline, sharding as shd                     # noqa: E402
from repro.configs.base import (INPUT_SHAPES, ModelConfig,      # noqa: E402
                                all_arch_ids, combo_is_supported, get_config)
from repro.core import lora as lora_lib                         # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.models import model as model_lib                     # noqa: E402
from repro.models.param import split                            # noqa: E402
from repro.training import optim, train as train_lib            # noqa: E402


def _cost_dict(cost):
    """compiled.cost_analysis() returns a dict (new jax) or a one-element
    list of dicts per device (old jax); normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def _shardings_for(mesh, axes_tree, shapes_tree):
    return shd.tree_shardings(mesh, axes_tree, shapes_tree)


def _replicated(mesh):
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


def _batch_shardings(mesh, batch_tree):
    axes = model_lib.batch_logical_axes(batch_tree)
    return shd.tree_shardings(mesh, axes, batch_tree)


def build_train(cfg: ModelConfig, shape, mesh):
    p_shapes, p_axes = model_lib.abstract_params(cfg)
    opt_shapes = jax.eval_shape(
        lambda p: optim.init(p, jnp.dtype(cfg.opt_moments_dtype)), p_shapes)
    p_shard = _shardings_for(mesh, p_axes, p_shapes)
    opt_shard = optim.AdamWState(
        step=_replicated(mesh),
        mu=jax.tree.map(lambda _, s: s, opt_shapes.mu, p_shard),
        nu=jax.tree.map(lambda _, s: s, opt_shapes.nu, p_shard))
    specs = model_lib.input_specs(cfg, shape)
    batch = specs["batch"]
    b_shard = _batch_shardings(mesh, batch)
    ocfg = optim.AdamWConfig(moments_dtype=cfg.opt_moments_dtype)
    step = train_lib.make_train_step(cfg, ocfg)
    args = (p_shapes, opt_shapes, batch)
    in_shardings = (p_shard, opt_shard, b_shard)
    return step, args, in_shardings


def build_prefill(cfg: ModelConfig, shape, mesh):
    rules = shd.serve_rules() if cfg.serve_tp else None
    p_shapes, p_axes = model_lib.abstract_params(cfg)
    p_shard = shd.tree_shardings(mesh, p_axes, p_shapes, rules)
    specs = model_lib.input_specs(cfg, shape)
    batch = specs["batch"]
    b_shard = _batch_shardings(mesh, batch)
    pool_box = lora_lib.pool_abstract(cfg)
    pool_shapes, pool_axes = split(pool_box)
    pool_shard = shd.tree_shardings(mesh, pool_axes, pool_shapes, rules)
    B = shape.global_batch
    idx = jax.ShapeDtypeStruct((B,), jnp.int32)
    idx_shard = shd.named_sharding(mesh, ("batch",), (B,))

    def fn(params, batch, pool, idx):
        lora = {"pool": pool, "idx": idx, "mode": "mbgmv"}
        logits, cache = model_lib.prefill(cfg, params, batch, lora=lora,
                                          cache_slots=shape.seq_len,
                                          last_only=True)
        return logits, cache

    return fn, (p_shapes, batch, pool_shapes, idx), \
        (p_shard, b_shard, pool_shard, idx_shard)


def build_decode(cfg: ModelConfig, shape, mesh):
    rules = shd.serve_rules() if cfg.serve_tp else None
    p_shapes, p_axes = model_lib.abstract_params(cfg)
    p_shard = shd.tree_shardings(mesh, p_axes, p_shapes, rules)
    specs = model_lib.input_specs(cfg, shape)
    cache = specs["cache"]
    cache_axes = model_lib.cache_logical_axes(cfg, cache)
    cache_shard = shd.tree_shardings(mesh, cache_axes, cache)
    pool_box = lora_lib.pool_abstract(cfg)
    pool_shapes, pool_axes = split(pool_box)
    pool_shard = shd.tree_shardings(mesh, pool_axes, pool_shapes, rules)
    B = shape.global_batch
    tok_shard = shd.named_sharding(mesh, ("batch", None), (B, 1))
    pos_shard = shd.named_sharding(mesh, ("batch",), (B,))
    idx_shard = shd.named_sharding(mesh, ("batch",), (B,))
    window = model_lib.decode_window(cfg, shape.seq_len)

    def fn(params, cache, toks, pos, pool, idx):
        lora = {"pool": pool, "idx": idx, "mode": "mbgmv"}
        return model_lib.decode(cfg, params, cache, toks, pos, lora=lora,
                                window=window)

    args = (p_shapes, cache, specs["tokens_t"], specs["pos"], pool_shapes,
            jax.ShapeDtypeStruct((B,), jnp.int32))
    in_sh = (p_shard, cache_shard, tok_shard, pos_shard, pool_shard,
             idx_shard)
    return fn, args, in_sh


def _builder(kind):
    return {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}[kind]


def analytic_bytes_per_chip(args, in_shardings) -> float:
    """True per-chip residency of the step's persistent inputs (params, opt
    state, cache, pool, batch) from the actual shardings — the XLA-CPU
    temp accounting is an upper bound without TPU buffer optimizations."""
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(args), jax.tree.leaves(
            in_shardings, is_leaf=lambda x: isinstance(
                x, jax.sharding.NamedSharding))):
        n = 1
        for d in leaf.shape:
            n *= d
        shards = 1
        sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
        for entry in sh.spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= sizes[ax]
        total += n * jnp.dtype(leaf.dtype).itemsize / shards
    return total


def _probe_costs(cfg: ModelConfig, shape, mesh):
    """Lower+compile unrolled 1- and 2-unit probes and linearly extrapolate
    per-device totals (XLA cost analysis counts while-loop bodies once; the
    probes contain no loops, so probe costs are exact for their depth)."""
    out = {}
    for k in (1, 2):
        pcfg = cfg.probe(k)
        fn, args, in_sh = _builder(shape.kind)(pcfg, shape, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        cost = _cost_dict(compiled.cost_analysis())
        coll = roofline.collective_bytes(compiled.as_text())
        out[k] = (float(cost.get("flops", 0.0)),
                  float(cost.get("bytes accessed", 0.0)),
                  float(sum(coll.values())))
    step = cfg.probe(2).n_layers - cfg.probe(1).n_layers
    m = cfg.n_layers / step          # layer-units at full depth
    f1, b1, c1 = out[1]
    f2, b2, c2 = out[2]
    corr = lambda v1, v2: v1 + (m - 1) * (v2 - v1)
    return {"flops": corr(f1, f2), "bytes": corr(b1, b2),
            "coll": max(corr(c1, c2), 0.0),
            "per_layer": {"flops": f2 - f1, "bytes": b2 - b1,
                          "coll": c2 - c1}}


OPTS = ("serve_tp", "kv8", "moe2d", "moe_gather", "moe_ep", "seqpar")


def apply_opts(cfg: ModelConfig, opts) -> ModelConfig:
    """Perf-iteration knobs (EXPERIMENTS.md sec Perf)."""
    import dataclasses
    kw = {}
    if "serve_tp" in opts:
        kw["serve_tp"] = True
    if "kv8" in opts:
        kw["kv_cache_dtype"] = "int8"
    if "moe2d" in opts:
        kw["moe_2d_ff"] = True
    if "moe_gather" in opts:
        kw["moe_gather_weights"] = True
    if "moe_ep" in opts:
        kw["moe_ep"] = True
    if "seqpar" in opts:
        kw["seq_parallel"] = True
    return dataclasses.replace(cfg, **kw) if kw else cfg


def run_combo(arch: str, shape_name: str, multi_pod: bool = False,
              out_dir: str = "experiments/dryrun", save_hlo: bool = False,
              probes: bool = True, opts=()):
    cfg = apply_opts(get_config(arch), opts)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tagext = ("+" + "+".join(sorted(opts))) if opts else ""
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name + tagext,
           "status": "ok", "opts": sorted(opts)}
    ok, why = combo_is_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    with jax_compat.set_mesh(mesh):
        if shape.kind == "train":
            fn, args, in_sh = build_train(cfg, shape, mesh)
            donate = ()
        elif shape.kind == "prefill":
            fn, args, in_sh = build_prefill(cfg, shape, mesh)
            donate = ()
        else:
            fn, args, in_sh = build_decode(cfg, shape, mesh)
            donate = (1,)                      # cache aliasing
        rec["analytic_input_bytes_per_chip"] = analytic_bytes_per_chip(
            args, in_sh)
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    if save_hlo:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(
                out_dir, f"{arch}_{shape_name}_{mesh_name}.hlo"), "w") as f:
            f.write(hlo)
    # cost_analysis()/HLO text describe the per-device SPMD program; raw
    # numbers count scan bodies once, the probe-corrected totals fix that.
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    if probes:
        with jax_compat.set_mesh(mesh):
            pc = _probe_costs(cfg, shape, mesh)
        flops, bytes_hbm, coll_total = pc["flops"], pc["bytes"], pc["coll"]
        rec["probe_per_layer"] = pc["per_layer"]
        rec["scan_corrected"] = True
    terms = roofline.roofline_terms(flops, bytes_hbm, coll_total, chips,
                                    per_device=True)
    mflops = roofline.model_flops(cfg, shape)
    rec.update({
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_dev": flops,
        "hlo_flops_total": flops * chips,
        "hlo_bytes_per_dev": bytes_hbm,
        "collective_bytes": coll,
        "collective_total_per_dev": coll_total,
        "roofline": terms,
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / (flops * chips)) if flops else None,
        "memory_analysis": {
            k: getattr(mem, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)},
    })
    ma = rec["memory_analysis"]
    if ma.get("argument_size_in_bytes") is not None:
        # memory_analysis is per-device for SPMD executables
        live = (ma.get("argument_size_in_bytes", 0)
                + ma.get("output_size_in_bytes", 0)
                - ma.get("alias_size_in_bytes", 0)
                + ma.get("temp_size_in_bytes", 0))
        rec["bytes_per_chip"] = live
        rec["fits_16g"] = live < 16 * 2 ** 30
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated perf knobs: " + ",".join(OPTS))
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    archs = all_arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}" \
                    + (f" [{args.opt}]" if opts else "")
                try:
                    rec = run_combo(arch, shape, mp, args.out,
                                    args.save_hlo, opts=opts)
                except Exception as e:          # a failure here is a bug
                    mname = ("pod2x16x16" if mp else "pod16x16") \
                        + (("+" + "+".join(sorted(opts))) if opts else "")
                    rec = {"arch": arch, "shape": shape, "mesh": mname,
                           "status": "FAILED", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                path = os.path.join(
                    args.out, f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"c/m/x={r['compute_s']:.4f}/{r['memory_s']:.4f}/"
                          f"{r['collective_s']:.4f}s", flush=True)
                else:
                    print(f"[{rec['status']}] {tag}: "
                          f"{rec.get('reason', rec.get('error', ''))}",
                          flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} FAILED")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
