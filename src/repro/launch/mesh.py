"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model").
    Multi-pod: 2x16x16 = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run via "
            f"launch/dryrun.py which sets xla_force_host_platform_device_count")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(shape), axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for tests (requires >= data*model host devices)."""
    import numpy as np
    devs = jax.devices()
    n = data * model
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(data, model), ("data", "model"))
