"""Training launcher: full fine-tuning or LoRA-adapter training on the
synthetic pipeline, with checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \\
      --steps 200 --lora-rank 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, packed_batches
from repro.models import model as model_lib
from repro.models.param import split
from repro.training import checkpoint, optim, train as train_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lora-rank", type=int, default=0,
                    help=">0: train a LoRA adapter instead of full params")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params, _ = split(model_lib.init_params(cfg, jax.random.PRNGKey(args.seed)))
    ocfg = optim.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                             total_steps=args.steps)
    data = packed_batches(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                     batch=args.batch, seed=args.seed))

    if args.lora_rank > 0:
        adapter = train_lib.init_lora_adapter(cfg, args.lora_rank,
                                              jax.random.PRNGKey(args.seed + 1))
        state = optim.init(adapter)
        step_fn = jax.jit(train_lib.make_lora_train_step(cfg, ocfg,
                                                         args.lora_rank))
        what = adapter
    else:
        state = optim.init(params)
        step_fn = jax.jit(train_lib.make_train_step(cfg, ocfg, accum=1))
        what = params

    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        if args.lora_rank > 0:
            what, state, m = step_fn(what, state, params, batch)
        else:
            what, state, m = step_fn(what, state, batch)
            params = what
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time() - t0) / step:.2f}s/step)", flush=True)
        if args.ckpt_dir and step % args.ckpt_every == 0:
            checkpoint.save(checkpoint.step_path(args.ckpt_dir, step),
                            {"model": what, "opt": state}, step=step)
            checkpoint.retain(args.ckpt_dir, keep=3)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
