"""Serving launcher: run a CaraServe inference server (or a scheduler-fronted
cluster) over a generated trace and report the paper's three metrics.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \\
      --mode caraserve --kernel bgmv --rps 6 --duration 10
  PYTHONPATH=src python -m repro.launch.serve --cluster 8 --policy rank_aware
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import get_config
from repro.core.cluster import Cluster
from repro.core.engine import InferenceServer
from repro.core.perf_model import ServerPerfModel
from repro.core.scheduler import make_scheduler
from repro.traces import gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model (CPU-runnable numerics)")
    ap.add_argument("--mode", default="caraserve",
                    choices=["cached", "ondemand", "slora", "caraserve"])
    ap.add_argument("--kernel", default="bgmv", choices=["bgmv", "mbgmv"])
    ap.add_argument("--rps", type=float, default=6.0)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--n-adapters", type=int, default=32)
    ap.add_argument("--rank", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--trace", default="maf", choices=["maf", "synthetic"])
    ap.add_argument("--cluster", type=int, default=0,
                    help="run N servers behind the scheduler (timing-only)")
    ap.add_argument("--policy", default="rank_aware",
                    choices=["rank_aware", "most_idle", "first_fit",
                             "random"])
    ap.add_argument("--slo-scale", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    serve_cfg = cfg.smoke() if args.smoke else cfg
    rng = np.random.default_rng(args.seed)
    adapters = gen.make_adapters(args.n_adapters, cfg.name, rng,
                                 uniform_rank=args.rank)
    perf = ServerPerfModel(cfg, kernel=args.kernel)
    slo = args.slo_scale * perf.dec_perf([64] * args.max_batch)
    mk = gen.maf_trace if args.trace == "maf" else gen.synthetic_trace
    reqs = mk(adapters, rps=args.rps, duration_s=args.duration,
              vocab=serve_cfg.vocab, seed=args.seed, slo_tpt_ms=slo)
    print(f"{len(reqs)} requests, SLO={slo:.1f} ms/token")

    if args.cluster:
        servers = []
        for _ in range(args.cluster):
            srv = InferenceServer(cfg, mode=args.mode, kernel=args.kernel,
                                  max_batch=args.max_batch, numerics=False)
            for ad in adapters:
                srv.register_adapter(ad)
            servers.append(srv)
        sched = make_scheduler(args.policy, perf, slo_ms=slo) \
            if args.policy == "rank_aware" else make_scheduler(args.policy)
        out, _ = Cluster(servers, sched).run(reqs)
    else:
        srv = InferenceServer(serve_cfg, mode=args.mode, kernel=args.kernel,
                              max_batch=args.max_batch,
                              numerics=args.smoke, seed=args.seed)
        for ad in adapters:
            srv.register_adapter(ad)
        out = srv.run(reqs)

    for k, v in out.items():
        print(f"  {k:16s} {v:.3f}" if isinstance(v, float) else
              f"  {k:16s} {v}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
