"""Config system: ModelConfig dataclass, input-shape specs, registry.

Every assigned architecture registers a full-size config (used only by the
dry-run, via ShapeDtypeStruct) and a reduced smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) that actually runs on CPU.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128       # N
    head_dim: int = 64         # P
    n_groups: int = 1          # G (B/C groups)
    conv_width: int = 4
    expand: int = 2            # d_inner = expand * d_model
    chunk: int = 256           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style pattern: `pattern` repeats over layers."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")  # 1:2 attn:rec
    window: int = 2048          # local attention window
    lru_width: Optional[int] = None  # defaults to d_model


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    """Paper setting: adapters on W_q, W_k, W_v (sec 7.1). For attention-free
    blocks (SSM) the adapter attaches to in_proj/out_proj instead."""
    max_rank: int = 64          # pool padding rank (BGMV pads to this)
    n_slots: int = 8            # device-resident adapter slots per server
    rank_block: int = 16        # MBGMV rank-block granularity (TPU lanes)
    targets: Tuple[str, ...] = ("q", "k", "v")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_act: str = "silu"       # silu (SwiGLU) | gelu (plain 2-mat MLP)
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10000.0
    pos: str = "rope"           # rope | learned (whisper)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500         # stubbed conv-frontend output frames
    max_ctx: int = 32768        # learned-position table size (whisper real:448;
                                # sized up so prefill_32k/decode_32k lower)
    # VLM prefix stub
    n_prefix_tokens: int = 0    # patch embeddings prepended (phi-3-vision)
    # long-context handling
    sliding_window: Optional[int] = None  # if set, window attention available
    # distribution
    fsdp_weights: bool = False  # 2D (data x model) weight sharding for big models
    remat: bool = True
    accum_steps: int = 1        # grad-accum microbatches in train_step
    dtype: str = "bfloat16"
    opt_moments_dtype: str = "float32"  # bf16 on the biggest archs (memory)
    unroll_layers: bool = False # python-loop layers (dry-run cost probes)
    moe_2d_ff: bool = False     # expert d_ff over (data x model) [REFUTED:
                                # reshards activations, see sec Perf]
    moe_gather_weights: bool = False  # constrain expert-einsum outputs to
                                # batch sharding -> per-layer weight
                                # all-gather instead of activation reshard
    moe_ep: bool = False        # expert parallelism via shard_map all-to-all
                                # (models/moe_ep.py, sec Perf B)
    moe_ep_shards: int = 16     # expert-parallel width (= data-axis size of
                                # the production mesh); weights stored in EP
                                # layout so no per-layer resharding
    seq_parallel: bool = False  # shard residual-stream L over model in train
    kv_cache_dtype: str = ""    # "int8" -> quantized KV cache (serving)
    serve_tp: bool = False      # serving: TP-only weights (no FSDP gathers)
    citation: str = ""

    def probe(self, k: int) -> "ModelConfig":
        """k-layer unrolled variant for scan-corrected cost extrapolation
        (launch/dryrun.py): XLA cost analysis counts while bodies once, so
        totals are derived from probe(1)/probe(2) lowers."""
        n = 3 * k if self.hybrid else k
        return dataclasses.replace(
            self, n_layers=n,
            n_enc_layers=(k if self.n_enc_layers else 0),
            accum_steps=1, unroll_layers=True)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per = d * (2 * d_in + 2 * s.n_groups * s.state_dim) + d_in * d
            return emb + self.n_layers * per
        attn = d * self.hd * self.n_heads + 2 * d * self.hd * self.n_kv_heads \
            + self.hd * self.n_heads * d
        n_mats = 2 if self.mlp_act == "gelu" else 3   # silu/geglu are gated
        mlp = n_mats * d * f
        if self.moe:
            mlp = self.moe.n_experts * mlp + d * self.moe.n_experts
        per = attn + mlp
        n_blocks = self.n_layers + self.n_enc_layers
        return emb + n_blocks * per

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_mats = 2 if self.mlp_act == "gelu" else 3
        dense_total = self.param_count() - self.n_layers * (
            self.moe.n_experts * n_mats * d * f + d * self.moe.n_experts)
        return dense_total + self.n_layers * self.moe.top_k * n_mats * d * f

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2 if not self.hybrid else 3,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_seq=16,
            n_prefix_tokens=4 if self.n_prefix_tokens else 0,
            fsdp_weights=False,
            accum_steps=1,
            dtype="float32",
        )
        if self.moe:
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(self.moe.top_k, 2))
        if self.ssm:
            kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, conv_width=4,
                                  expand=2, chunk=8)
        if self.hybrid:
            kw["hybrid"] = HybridConfig(window=8)
        if self.sliding_window:
            kw["sliding_window"] = 16
        kw["lora"] = LoRAConfig(max_rank=8, n_slots=4, rank_block=4,
                                targets=self.lora.targets)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS = [
    "whisper-tiny", "recurrentgemma-2b", "dbrx-132b", "mistral-large-123b",
    "phi-3-vision-4.2b", "command-r-35b", "yi-9b", "grok-1-314b",
    "mamba2-130m", "qwen2-72b",
]

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def all_arch_ids():
    return list(ARCH_IDS)


def combo_is_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """The one documented skip: whisper long_500k (DESIGN.md sec 4)."""
    if shape.name == "long_500k":
        if cfg.family in ("encdec", "audio"):
            return False, ("encoder-decoder over 30s audio has no 500k-token "
                           "decode semantics (decoder ctx 448); skipped per "
                           "DESIGN.md sec 4")
        if cfg.family in ("dense", "moe", "vlm") and not cfg.sliding_window:
            return False, "full-attention arch without sliding-window variant"
    return True, ""
