"""llama2-70b — paper Table 2 multi-GPU row (4x A100 -> TP=4)."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32000,
    mlp_act="silu",
    sliding_window=4096,
    fsdp_weights=True,
    accum_steps=16,
    opt_moments_dtype="bfloat16",
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2307.09288 (paper Table 2)",
))
