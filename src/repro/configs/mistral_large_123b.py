"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    mlp_act="silu",
    sliding_window=4096,
    fsdp_weights=True,
    opt_moments_dtype="bfloat16",
    accum_steps=16,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
))
