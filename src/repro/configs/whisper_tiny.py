"""whisper-tiny [audio/enc-dec] — arXiv:2212.04356.

Transformer backbone only; the mel-spectrogram + conv feature extractor is a
stub per the carve-out: input_specs() provides (B, 1500, 384) frame embeddings.
"""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers
    n_enc_layers=4,        # encoder layers
    enc_seq=1500,          # 30 s of audio -> 1500 frames after conv frontend
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    mlp_act="gelu",
    norm="layernorm",
    pos="learned",
    qkv_bias=True,         # whisper uses biases on q/v (we apply to qkv)
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2212.04356",
))
