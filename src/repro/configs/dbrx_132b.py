"""dbrx-132b [moe] — 16 experts top-4, fine-grained. hf:databricks/dbrx-base."""
from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    mlp_act="silu",
    moe=MoEConfig(n_experts=16, top_k=4),
    sliding_window=4096,   # windowed variant for long_500k (DESIGN.md sec 4)
    fsdp_weights=True,
    opt_moments_dtype="bfloat16",
    accum_steps=16,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="hf:databricks/dbrx-base",
))
