"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2. arXiv:2402.19427."""
from repro.configs.base import HybridConfig, LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,           # pattern (rglru, rglru, attn) repeating
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,          # MQA on the local-attention layers
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    mlp_act="gelu",        # gated gelu in the paper; plain-gelu GLU here
    accum_steps=2,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"), window=2048),
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2402.19427",
))
