"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).
hf:microsoft/Phi-3-vision-128k-instruct.

The ViT/SigLIP encoder + projector is a stub per the carve-out: input_specs()
provides (B, 576, 3072) patch embeddings prepended to the token sequence.
"""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,         # MHA
    d_ff=8192,
    vocab=32064,
    mlp_act="silu",
    n_prefix_tokens=576,   # 24x24 CLIP patches
    sliding_window=4096,
    accum_steps=4,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
))
