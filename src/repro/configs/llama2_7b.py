"""llama2-7b — the paper's own evaluation model (Table 2). arXiv:2307.09288."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
    mlp_act="silu",
    sliding_window=4096,
    accum_steps=4,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2307.09288 (paper Table 2)",
))
