"""yi-9b [dense] — llama-arch GQA. arXiv:2403.04652."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    mlp_act="silu",
    sliding_window=4096,
    accum_steps=4,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2403.04652",
))
