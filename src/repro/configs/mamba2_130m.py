"""mamba2-130m [ssm] — SSD (state-space duality). arXiv:2405.21060.

Attention-free: LoRA attaches to in_proj/out_proj (DESIGN.md
sec Arch-applicability) — the paper's q/k/v targets do not exist here.
"""
from repro.configs.base import LoRAConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, conv_width=4,
                  expand=2, chunk=256),
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("in_proj", "out_proj")),
    citation="arXiv:2405.21060",
))
