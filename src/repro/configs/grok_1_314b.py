"""grok-1-314b [moe] — 8 experts top-2. hf:xai-org/grok-1."""
from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    mlp_act="geglu",
    moe=MoEConfig(n_experts=8, top_k=2),
    sliding_window=4096,
    fsdp_weights=True,
    opt_moments_dtype="bfloat16",
    accum_steps=16,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="hf:xai-org/grok-1",
))
