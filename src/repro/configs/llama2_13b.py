"""llama2-13b — paper Table 2 multi-GPU row (2x A10 -> TP=2)."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=13824,
    vocab=32000,
    mlp_act="silu",
    sliding_window=4096,
    accum_steps=4,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2307.09288 (paper Table 2)",
))
