"""qwen2-72b [dense] — GQA, QKV bias. arXiv:2407.10671."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    mlp_act="silu",
    qkv_bias=True,
    sliding_window=4096,
    fsdp_weights=True,
    opt_moments_dtype="bfloat16",
    accum_steps=16,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="arXiv:2407.10671",
))
