"""command-r-35b [dense] — GQA, no-bias. hf:CohereForAI/c4ai-command-r-v01."""
from repro.configs.base import LoRAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    mlp_act="silu",
    qkv_bias=False,
    sliding_window=4096,
    accum_steps=8,
    lora=LoRAConfig(max_rank=64, n_slots=8, targets=("q", "k", "v")),
    citation="hf:CohereForAI/c4ai-command-r-v01",
))
