"""Request/response types and per-request serving metrics (paper sec 7.1:
time-to-first-token, time-per-token, request latency)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    adapter_uid: str
    prompt: np.ndarray                 # (L,) int32 token ids
    max_new_tokens: int
    arrival_ms: float = 0.0
    slo_tpt_ms: Optional[float] = None # time-per-token SLO

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclasses.dataclass
class RequestState:
    req: Request
    row: int = -1                      # batch row in the engine
    phase: str = "queued"              # queued | loading | prefill | decode | done
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_ms: Optional[float] = None
    finish_ms: Optional[float] = None
    token_times_ms: List[float] = dataclasses.field(default_factory=list)
    cold_start: bool = False
    assist_used: bool = False          # CPU-assisted prefill engaged
    ready_ms: float = 0.0              # decode may include this request after
    load_finish_ms: Optional[float] = None  # adapter upload completion
    flip_ms: Optional[float] = None    # CPU-assist -> device pool switch
    # tokens sampled on device but not yet read back to `generated` (the
    # numerics plane's async readback queue); the engine's control flow
    # counts them via `issued` so completion never waits on a host sync
    pending_tokens: int = 0
    # paged memory plane: physical KV pages claimed for this request —
    # prompt pages at admission, grown lazily as decode crosses page
    # boundaries (logical page j of the row's block table -> kv_pages[j]);
    # freed when the row is released or the request is preempted.
    kv_pages: List[int] = dataclasses.field(default_factory=list)
    # KV over-subscription: when the allocator runs dry mid-decode the
    # victim policy preempts rows — pages are freed and the request goes
    # back on the queue with a resume plan ("swap" re-uploads the saved
    # page payload through the link scheduler; "recompute" rebuilds KV by
    # re-prefilling prompt + generated-so-far). `resume_pos` is the next
    # decode position at preemption time == KV slots that must be restored.
    preempted: bool = False           # queued awaiting resume
    preemptions: int = 0              # times this request was preempted
    resume_kind: str = ""             # "swap" | "recompute" while queued
    resume_pos: int = 0
    swap_payload: Optional[object] = None   # host copy of the KV pages
    kv_resume_ms: float = 0.0         # swap-in upload completes (link time)
    # chunked prefill: prompt tokens whose KV has been materialized so far.
    # Monolithic admissions set this to prompt_len in one shot; the chunked
    # path advances it chunk by chunk, and a preemptive swap of a
    # half-prefilled row preserves it so resume restores chunk progress.
    prefill_pos: int = 0
    # failure plane (core/faults.py): `shed` marks a request the cluster
    # or admission plane rejected under brownout (phase "shed", never
    # completes — counted as an SLO miss, not a lost request); `recovered`
    # counts crash failovers (drained off a dead server and re-admitted on
    # a survivor); `assist_decode` flags a decode row currently riding the
    # CPU-assist path because its adapter upload is mid-retry.
    shed: bool = False
    recovered: int = 0
    assist_decode: bool = False

    @property
    def issued(self) -> int:
        """Tokens produced for this request, whether or not their values
        have crossed back to the host yet."""
        return len(self.generated) + self.pending_tokens

    @property
    def done(self) -> bool:
        return self.issued >= self.req.max_new_tokens

    # ------------------------------------------------------- metrics ----
    def ttft_ms(self) -> float:
        return self.first_token_ms - self.req.arrival_ms

    def tpt_ms(self) -> float:
        """Average time per output token (perceived speed)."""
        n = max(len(self.generated), 1)
        return (self.finish_ms - self.req.arrival_ms) / n

    def latency_ms(self) -> float:
        return self.finish_ms - self.req.arrival_ms

    def slo_met(self) -> bool:
        if self.req.slo_tpt_ms is None:
            return True
        return self.tpt_ms() <= self.req.slo_tpt_ms

    def itl_ms(self) -> List[float]:
        """Inter-token latencies: gaps between consecutive emitted tokens.
        The first token's wait is TTFT, not ITL, so a request contributes
        len(token_times_ms) - 1 samples."""
        ts = self.token_times_ms
        return [ts[i + 1] - ts[i] for i in range(len(ts) - 1)]


def itl_percentiles(samples) -> dict:
    """P50/P99/mean over a pool of inter-token-latency gaps (ms)."""
    arr = np.asarray(list(samples), dtype=np.float64)
    if arr.size == 0:
        return {"n_gaps": 0, "itl_mean_ms": 0.0,
                "itl_p50_ms": 0.0, "itl_p99_ms": 0.0}
    return {
        "n_gaps": int(arr.size),
        "itl_mean_ms": float(arr.mean()),
        "itl_p50_ms": float(np.median(arr)),
        "itl_p99_ms": float(np.percentile(arr, 99)),
    }


def summarize(states) -> dict:
    """Aggregate serving metrics. Shed requests (brownout rejections)
    never complete: they are excluded from the latency pools but count
    against `slo_attainment` — shedding is a controlled SLO miss, not a
    free pass — and `n + shed` accounts for every submitted request
    (the zero-lost invariant the chaos bench asserts)."""
    done = [s for s in states if s.finish_ms is not None]
    n_shed = sum(1 for s in states if getattr(s, "shed", False))
    if not done:
        return {"n": 0, "shed": int(n_shed)}
    ttft = np.array([s.ttft_ms() for s in done])
    tpt = np.array([s.tpt_ms() for s in done])
    lat = np.array([s.latency_ms() for s in done])
    met = sum(s.slo_met() for s in done)
    return {
        "n": len(done),
        "ttft_mean": float(ttft.mean()), "ttft_p50": float(np.median(ttft)),
        "ttft_p99": float(np.percentile(ttft, 99)),
        "tpt_mean": float(tpt.mean()), "tpt_p50": float(np.median(tpt)),
        "tpt_p99": float(np.percentile(tpt, 99)),
        "latency_mean": float(lat.mean()),
        "latency_p50": float(np.median(lat)),
        "latency_p99": float(np.percentile(lat, 99)),
        "slo_attainment": float(met / (len(done) + n_shed)),
        "cold_starts": int(sum(s.cold_start for s in done)),
        "assisted": int(sum(s.assist_used for s in done)),
        "flipped": int(sum(s.flip_ms is not None for s in done)),
        "preempted": int(sum(s.preemptions > 0 for s in done)),
        "preemptions": int(sum(s.preemptions for s in done)),
        "shed": int(n_shed),
        "recovered": int(sum(s.recovered > 0 for s in done)),
        "failovers": int(sum(s.recovered for s in done)),
        **itl_percentiles(g for s in done for g in s.itl_ms()),
    }
