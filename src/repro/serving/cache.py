"""KV-cache memory plane for continuous batching.

Two layouts:

* **Dense rows** (the seed layout, still used by recurrent/hybrid/enc-dec
  families and the legacy per-step pipeline): a fixed ``max_batch`` slab of
  ``cache_slots``-deep rows; requests claim/free whole rows and per-request
  prefill caches are scattered into the pool row. Stacked (scan) caches
  carry batch on axis 1 (layer-leading); per-layer list caches
  (hybrid/enc-dec) carry batch on axis 0. Every row pays for the longest
  prompt the server might ever admit.

* **Paged** (S-LoRA-style unified paging): a fixed pool of
  ``(page_size, kv_heads, head_dim)`` pages shared by every request, plus a
  per-row *block table* mapping logical page ``j`` of a row to a physical
  page id (``-1`` = unclaimed). A request claims only its *prompt* pages at
  admission (``ceil(min(prompt, cache_slots) / page_size)``); the block
  table then grows lazily during decode — one page claimed each time the
  row's write position crosses a page boundary (``pages_for_tokens`` /
  ``boundary_steps`` are the arithmetic) — and everything is freed at
  retirement. Admission is therefore gated by *actual* memory demand and
  the pool can be over-subscribed: the sum of admitted lifetime footprints
  may exceed ``n_pages``, with mid-decode exhaustion resolved by preempting
  victim rows (swap via ``extract_pages``/``insert_pages``, or
  drop-and-recompute through the batched prefill path). ``PageAllocator``
  is the single id space both the KV block tables and the LoRA
  ``DevicePool`` draw from — KV and adapter pages can never alias, and
  either side can reclaim the other's cold capacity
  (``core/lora.DevicePool.shed_cold``).

``zeros_paged`` / ``scatter_pages`` / ``gather_pages`` are the paged
counterparts of ``zeros_like_batched`` / ``scatter_rows`` / ``gather_row``;
they page the uniform layered transformer layout only
(k/v ``(L, B, KV, S, hd)``, pos ``(L, B, S)`` — see
``models.model.supports_paged``)."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizers


def _batch_axis(cache) -> int:
    return 0 if isinstance(cache, list) else 1


def scatter_row(pool_cache, row_cache, row: int):
    """Insert a single-request cache (batch dim = 1) at `row`."""
    ax = _batch_axis(pool_cache)

    def put(dst, src):
        idx = [slice(None)] * dst.ndim
        idx[ax] = row
        return dst.at[tuple(idx)].set(jnp.squeeze(src, axis=ax))

    return jax.tree.map(put, pool_cache, row_cache)


def scatter_rows(pool_cache, row_caches, rows):
    """Vectorized multi-row insert: one scatter writes every admitted
    request's prefill cache into its pool row (replacing N per-request
    `scatter_row` dispatches). `row_caches` carries batch Nb on the same
    axis as the pool; `rows` is (Nb,) int32 of target rows — padding
    entries point past the pool (row >= max_batch) and are dropped by the
    scatter's out-of-bounds mode, so bucketed prefill batches need no
    select."""
    ax = _batch_axis(pool_cache)

    def put(dst, src):
        dstm = jnp.moveaxis(dst, ax, 0)
        srcm = jnp.moveaxis(src, ax, 0)
        out = dstm.at[rows].set(srcm, mode="drop")
        return jnp.moveaxis(out, 0, ax)

    return jax.tree.map(put, pool_cache, row_caches)


def gather_row(pool_cache, row: int):
    ax = _batch_axis(pool_cache)

    def take(x):
        idx = [slice(None)] * x.ndim
        idx[ax] = slice(row, row + 1)
        return x[tuple(idx)]

    return jax.tree.map(take, pool_cache)


def zeros_like_batched(row_cache_abstract, max_batch: int):
    """Build the pool from a batch-1 abstract cache tree."""
    ax = _batch_axis(row_cache_abstract)

    def mk(x):
        shape = list(x.shape)
        shape[ax] = max_batch
        if hasattr(x, "dtype") and x.dtype == jnp.int32:
            return jnp.full(shape, -1, jnp.int32)
        return jnp.zeros(shape, x.dtype)

    return jax.tree.map(mk, row_cache_abstract)


# ------------------------------------------------------------ paged pool ----

def kv_page_nbytes(cfg, page_size: int) -> int:
    """HBM bytes of one KV page: k+v payload for `page_size` token slots
    across every layer (the unit of the unified KV/LoRA page accounting)."""
    itemsize = jnp.dtype(cfg.jdtype).itemsize
    return 2 * cfg.n_layers * cfg.n_kv_heads * page_size * cfg.hd * itemsize


class PageAllocator:
    """One fixed pool of device pages shared by KV block tables and LoRA
    adapter slots (S-LoRA's unified memory, PAPERS.md). Page ids live in a
    single space ``[0, n_pages)``: a page claimed for a row's KV can never
    simultaneously back an adapter, and vice versa. Claims are all-or-
    nothing; ``free`` rejects double-frees. ``owner_of`` exposes the tag a
    page was claimed under (``kv:<rid>`` / ``adapter:<uid>``) for tests and
    telemetry. ``on_free`` (optional callback, invoked after every free)
    lets the admission plane re-check deferred requests on each page-free
    event instead of only on its own admit attempts."""

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError(f"n_pages must be positive, got {n_pages}")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._owner: Dict[int, str] = {}
        self.on_free = None
        # PageSan (REPRO_SANITIZE=1): shadow ownership + quarantine. Freed
        # pages sit in quarantine instead of the free list until capacity
        # pressure, so stale block-table references hit a dead page and are
        # reported as use-after-free. Capacity-neutral: `free_pages` counts
        # quarantined pages and `claim` recycles them on demand.
        self.san = (sanitizers.PageSan(n_pages)
                    if sanitizers.enabled() else None)

    @property
    def free_pages(self) -> int:
        n = len(self._free)
        if self.san is not None:
            n += len(self.san.quarantine)
        return n

    @property
    def used_pages(self) -> int:
        return self.n_pages - self.free_pages

    def claim(self, n: int, owner: str) -> Optional[List[int]]:
        """Claim `n` pages under `owner`, or None (and no change) if fewer
        than `n` are free."""
        if n < 0:
            raise ValueError(f"cannot claim a negative page count ({n})")
        if n > self.free_pages:
            return None
        if self.san is not None and n > len(self._free):
            # capacity pressure: recycle quarantined pages, oldest first
            self._free[:0] = self.san.take_quarantined(n - len(self._free))
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._owner[i] = owner
        if self.san is not None:
            self.san.on_claim(ids, owner)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        if self.san is not None:
            self.san.pre_free(ids)
        for i in ids:
            if i not in self._owner:
                raise ValueError(f"page {i} freed but not claimed")
            del self._owner[i]
            if self.san is None:
                self._free.append(i)
        if self.san is not None:
            self.san.on_free(ids)   # -> quarantine, not the free list
        if ids and self.on_free is not None:
            self.on_free()

    def owner_of(self, page: int) -> Optional[str]:
        return self._owner.get(page)

    def owned_by(self, prefix: str) -> List[int]:
        return [p for p, o in self._owner.items() if o.startswith(prefix)]


def zeros_paged(row_cache_abstract, n_pages: int, page_size: int):
    """Paged counterpart of `zeros_like_batched`: build the physical page
    pool from a batch-1 abstract cache tree of the layered transformer
    layout. k/v (L, 1, KV, S, hd) -> (L, n_pages, KV, page_size, hd);
    pos (L, 1, S) -> (L, n_pages, page_size), -1 = empty slot."""
    def mk(x):
        nd = len(x.shape)
        if nd == 5:              # k / v
            L, _, kvh, _, hd = x.shape
            shape = (L, n_pages, kvh, page_size, hd)
        elif nd == 3:            # pos
            L = x.shape[0]
            shape = (L, n_pages, page_size)
        else:
            raise ValueError(
                f"unpageable cache leaf of ndim {nd} — paged layout "
                "supports the uniform layered k/v/pos cache only")
        if hasattr(x, "dtype") and x.dtype == jnp.int32:
            return jnp.full(shape, -1, jnp.int32)
        return jnp.zeros(shape, x.dtype)

    return jax.tree.map(mk, row_cache_abstract)


def scatter_pages(pool_cache, row_caches, page_ids):
    """Paged counterpart of `scatter_rows`: one vectorized write moves every
    admitted request's prefill cache into its freshly claimed pages.

    `row_caches` carries batch Nb on axis 1 with a slot depth Sp that is a
    multiple of the pool's page_size; `page_ids` is (Nb, Sp // page_size)
    int32 of physical destination pages — entries < 0 (shorter requests /
    padding rows of a bucketed prefill) are routed out of bounds and
    dropped by the scatter, so no select is needed."""
    n_pages = jax.tree.leaves(pool_cache)[0].shape[1]
    ids = jnp.where(page_ids >= 0, page_ids, n_pages).reshape(-1)

    def put(dst, src):
        if dst.ndim == 5:        # k / v: (L, P, KV, ps, hd)
            ps = dst.shape[3]
            L, Nb, kvh, Sp, hd = src.shape
            s = src.reshape(L, Nb, kvh, Sp // ps, ps, hd)
            s = s.transpose(0, 1, 3, 2, 4, 5).reshape(L, -1, kvh, ps, hd)
        else:                    # pos: (L, P, ps)
            ps = dst.shape[2]
            L, Nb, Sp = src.shape
            s = src.reshape(L, -1, ps)
        return dst.at[:, ids].set(s, mode="drop")

    return jax.tree.map(put, pool_cache, row_caches)


# --------------------------------------------- lazy growth / preemption ----

def pages_for_tokens(tokens: int, page_size: int) -> int:
    """Pages needed to hold `tokens` KV slots."""
    return -(-max(int(tokens), 0) // page_size)


def boundary_steps(pos: int, n_claimed: int, page_size: int,
                   width: int) -> Optional[int]:
    """Decode steps a row can take before its ring write position crosses
    into an unclaimed logical page — the boundary-claim event that megastep
    planning must not fuse across. `pos` is the next write position,
    `n_claimed` the row's claimed-page count (claims are a logical prefix),
    `width` the block-table width. None = fully grown: the ring wraps onto
    already-claimed pages and no boundary event can occur. A result <= 0
    means the *current* write needs a page claimed first."""
    if n_claimed >= width:
        return None
    slot = int(pos) % (width * page_size)
    return n_claimed * page_size - slot


def clear_pages(pool_cache, page_ids):
    """Scrub reclaimed pages before reuse by invalidating their position
    slots (pos = -1). Lazily grown block tables hand a row pages that may
    carry a previous tenant's entries; stale pos values would become
    visible to attention once the new row's clock passes them. k/v payload
    can stay — it is masked by pos < 0."""
    ids = jnp.asarray(page_ids, jnp.int32)

    def clr(x):
        return x.at[:, ids].set(-1, mode="drop") if x.ndim == 3 else x

    return jax.tree.map(clr, pool_cache)


def extract_pages(pool_cache, page_ids):
    """Swap-out: device -> host copy of a row's claimed pages (k/v payload
    and pos), keyed by position in `page_ids`. The returned tree is host
    numpy, so the physical pages can be freed and reused immediately."""
    ids = jnp.asarray(page_ids, jnp.int32)
    # lint: allow-host-sync — swap-out IS the d2h copy; pages are freed
    # for reuse the moment the host holds the payload
    return jax.tree.map(lambda x: np.asarray(x[:, ids]), pool_cache)


def insert_pages(pool_cache, payload, page_ids):
    """Swap-in: write an `extract_pages` payload into freshly claimed pages
    (ids may differ from the originals — the block table re-maps). Every
    slot of the destination pages is overwritten, so no prior clear is
    needed."""
    ids = jnp.asarray(page_ids, jnp.int32)
    return jax.tree.map(
        lambda dst, src: dst.at[:, ids].set(jnp.asarray(src, dst.dtype)),
        pool_cache, payload)


def tree_nbytes(tree) -> int:
    # .nbytes is metadata on both numpy and jax arrays — no device sync
    return int(sum(x.nbytes for x in jax.tree.leaves(tree)))


def gather_pages(pool_cache, page_ids):
    """Paged counterpart of `gather_row`: reconstruct one row's cache in
    the dense batch-1 layout from its block-table pages. `page_ids` is the
    row's (W,) logical->physical map; unclaimed (< 0) logical pages come
    back as empty (k/v zeros, pos -1)."""
    ids = jnp.asarray(page_ids, jnp.int32)
    safe = jnp.maximum(ids, 0)
    valid = ids >= 0

    def take(x):
        if x.ndim == 5:          # (L, P, KV, ps, hd)
            L, _, kvh, ps, hd = x.shape
            g = x[:, safe]                               # (L, W, KV, ps, hd)
            g = jnp.where(valid[None, :, None, None, None], g, 0)
            g = g.transpose(0, 2, 1, 3, 4).reshape(L, 1, kvh, -1, hd)
        else:                    # (L, P, ps)
            L, _, ps = x.shape
            g = x[:, safe]                               # (L, W, ps)
            g = jnp.where(valid[None, :, None], g, -1)
            g = g.reshape(L, 1, -1)
        return g

    return jax.tree.map(take, pool_cache)
