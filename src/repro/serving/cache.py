"""Batched KV-cache pool for continuous batching: fixed max_batch rows;
requests claim/free rows; per-request prefill caches are scattered into the
pool row. Stacked (scan) caches carry batch on axis 1 (layer-leading);
per-layer list caches (hybrid/enc-dec) carry batch on axis 0."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _batch_axis(cache) -> int:
    return 0 if isinstance(cache, list) else 1


def scatter_row(pool_cache, row_cache, row: int):
    """Insert a single-request cache (batch dim = 1) at `row`."""
    ax = _batch_axis(pool_cache)

    def put(dst, src):
        idx = [slice(None)] * dst.ndim
        idx[ax] = row
        return dst.at[tuple(idx)].set(jnp.squeeze(src, axis=ax))

    return jax.tree.map(put, pool_cache, row_cache)


def scatter_rows(pool_cache, row_caches, rows):
    """Vectorized multi-row insert: one scatter writes every admitted
    request's prefill cache into its pool row (replacing N per-request
    `scatter_row` dispatches). `row_caches` carries batch Nb on the same
    axis as the pool; `rows` is (Nb,) int32 of target rows — padding
    entries point past the pool (row >= max_batch) and are dropped by the
    scatter's out-of-bounds mode, so bucketed prefill batches need no
    select."""
    ax = _batch_axis(pool_cache)

    def put(dst, src):
        dstm = jnp.moveaxis(dst, ax, 0)
        srcm = jnp.moveaxis(src, ax, 0)
        out = dstm.at[rows].set(srcm, mode="drop")
        return jnp.moveaxis(out, 0, ax)

    return jax.tree.map(put, pool_cache, row_caches)


def gather_row(pool_cache, row: int):
    ax = _batch_axis(pool_cache)

    def take(x):
        idx = [slice(None)] * x.ndim
        idx[ax] = slice(row, row + 1)
        return x[tuple(idx)]

    return jax.tree.map(take, pool_cache)


def zeros_like_batched(row_cache_abstract, max_batch: int):
    """Build the pool from a batch-1 abstract cache tree."""
    ax = _batch_axis(row_cache_abstract)

    def mk(x):
        shape = list(x.shape)
        shape[ax] = max_batch
        if hasattr(x, "dtype") and x.dtype == jnp.int32:
            return jnp.full(shape, -1, jnp.int32)
        return jnp.zeros(shape, x.dtype)

    return jax.tree.map(mk, row_cache_abstract)
