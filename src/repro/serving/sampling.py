"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, *, temperature: float = 0.0, rng=None):
    """logits: (B, V) -> (B,) int32. temperature<=0 -> greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert rng is not None
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)
