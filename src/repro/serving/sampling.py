"""Token sampling for the serving engine.

`sample` is jit-safe by construction: `temperature` is a static python
float (the backend closes over it via functools.partial), so greedy
sampling traces to a plain argmax with no rng operand, while stochastic
sampling threads an explicit PRNG key. The device-resident decode
pipeline keeps one key as part of its donated step state and advances it
with `split_key` inside the jitted step/megastep bodies — the key never
round-trips through the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, *, temperature: float = 0.0, rng=None):
    """logits: (B, V) -> (B,) int32. temperature<=0 -> greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        raise ValueError("temperature sampling needs an rng key")
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


def split_key(rng):
    """Advance a threaded sampling key one step: (next_carry, subkey).

    Called unconditionally inside the jitted decode bodies (even under
    greedy sampling, where the subkey is unused) so the carried key
    advances identically in the single-step and megastep paths — a
    temperature>0 megastep is then bitwise-reproducible against the same
    number of single steps."""
    nxt, sub = jax.random.split(rng)
    return nxt, sub
