"""Version-tolerant wrappers for JAX APIs that moved between releases.

The codebase targets the current JAX mesh/shard_map API (`jax.set_mesh`,
`jax.sharding.get_abstract_mesh`, `jax.shard_map`); older jaxlibs (0.4.x,
the pinned toolchain here) expose the same functionality under
`jax.experimental.shard_map` and the thread-local physical mesh set by the
``with mesh:`` context. Import from this module instead of reaching into
`jax.*` directly so both generations lower identically.
"""
from __future__ import annotations

import jax


def get_abstract_mesh():
    """The mesh visible at trace time: the abstract mesh when the runtime
    provides one, else the thread-local physical mesh (``with mesh:``).
    Always returns an object with ``.axis_names`` (possibly empty)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if getattr(am, "axis_names", ()):
            return am
    except AttributeError:
        am = None
    try:
        from jax._src import mesh as _mesh_src
        if am is None and hasattr(_mesh_src, "get_abstract_mesh"):
            cand = _mesh_src.get_abstract_mesh()
            if getattr(cand, "axis_names", ()):
                return cand
        pm = _mesh_src.thread_resources.env.physical_mesh
        if am is None or getattr(pm, "axis_names", ()):
            return pm
    except Exception:
        pass
    return am if am is not None else _EMPTY_MESH


class _EmptyMesh:
    """Stand-in when no mesh machinery is reachable: no named axes."""
    axis_names = ()


_EMPTY_MESH = _EmptyMesh()


def current_axis_names():
    return tuple(getattr(get_abstract_mesh(), "axis_names", ()))


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    New JAX: `jax.set_mesh`. Old JAX: the Mesh object itself is a context
    manager that sets the thread-local physical mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs.setdefault("check_rep", False)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
