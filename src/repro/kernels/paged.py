"""Paged decode attention Pallas TPU kernel — the decode hot-spot of the
paged memory plane (vLLM PagedAttention / S-LoRA unified paging, adapted to
TPU).

One decode token per row attends over that row's block table: grid
(B, H, W) walks the row's W logical pages; the physical page id is read
from the scalar-prefetched block table *before* the grid step, so the DMA
engine pulls K/V page tiles HBM->VMEM directly (the same
index_map-as-gather idiom as bgmv.py) — the gathered (B, KV, S, hd) dense
view the jnp oracle materializes never exists. Unclaimed logical pages
(block_table < 0) skip their whole grid step via pl.when; empty slots
inside a claimed page are masked by their cached position. Online softmax
with VMEM scratch accumulators, GQA via index_map head folding.

Validated against kernels.ref.paged_attention_ref in interpret mode (the
CPU fallback, like flash.py). models/layers.py routes paged decode here by
default on TPU backends (`paged_attn_decode`, impl switch
`layers.PAGED_ATTN_IMPL`); the pure-jnp gather path remains the CPU /
bitwise-parity fallback.

Statically verified by `analysis.kernel_verify` (lint rules `kernel-*`,
CLI `tools/kverify.py`): the block-table gather's clamp
(`jnp.maximum(bt[b, j], 0)`) is proved paired with the
`pl.when(bt_ref[b, j] >= 0)` guard — the tenant-isolation invariant
(clamp without guard silently attends a foreign row's page) — plus
online-softmax scratch init/flush/carry over the W revisit dim, bounds
with `-1` sentinel tables, and the VMEM budget at every `configs/`
shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, pp_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, ps, hd, scale):
    b, j = pl.program_id(0), pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(bt_ref[b, j] >= 0)
    def _():
        q = q_ref[0, 0].astype(jnp.float32).reshape(1, hd)
        k = k_ref[0, 0].astype(jnp.float32)                       # (ps, hd)
        s = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
        kpos = pp_ref[...].reshape(ps, 1)
        ok = jnp.logical_and(kpos >= 0, kpos <= pos_ref[b])
        s = jnp.where(ok, s, NEG_INF)                             # (ps, 1)
        m_prev = m_ref[...]                                       # (1, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=0, keepdims=True))
        # mask-aware p: when every slot of the page is masked, s == m_new ==
        # NEG_INF and exp(s - m_new) would be 1, silently attending garbage;
        # zeroing by the mask keeps fully-empty pages (lazily grown but not
        # yet written) and fully-masked rows contributing exactly nothing
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)                # (ps, 1)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=0, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                       # (ps, hd)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.T, v, preferred_element_type=jnp.float32)           # (1, hd)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l)[0].astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, pos_pages, block_table, pos, *,
                    interpret=None):
    """q: (B, H, hd); k_pages/v_pages: (P, KV, ps, hd); pos_pages: (P, ps);
    block_table: (B, W) int32 (-1 = unclaimed); pos: (B,) -> (B, H, hd)."""
    B, H, hd = q.shape
    P, KV, ps = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    W = block_table.shape[1]
    if H % KV:
        raise ValueError(f"paged_attention: H ({H}) not divisible by KV "
                         f"({KV}) — q {q.shape} vs k_pages {k_pages.shape}")
    if k_pages.shape != v_pages.shape:
        raise ValueError(f"paged_attention: k_pages {k_pages.shape} != "
                         f"v_pages {v_pages.shape}")
    if pos_pages.shape != (P, ps):
        raise ValueError(f"paged_attention: pos_pages {pos_pages.shape} "
                         f"must be ({P}, {ps}) to match k_pages "
                         f"{k_pages.shape}")
    if block_table.shape[0] != B or pos.shape != (B,):
        raise ValueError(f"paged_attention: block_table "
                         f"{block_table.shape} / pos {pos.shape} must lead "
                         f"with batch {B} (q {q.shape})")
    group = H // KV
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    kern = functools.partial(_paged_kernel, ps=ps, hd=hd, scale=hd ** -0.5)
    page = lambda b, h, j, bt, p: jnp.maximum(bt[b, j], 0)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, H, W),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda b, h, j, bt, p: (b, h, 0)),
                pl.BlockSpec((1, 1, ps, hd),
                             lambda b, h, j, bt, p:
                             (page(b, h, j, bt, p), h // group, 0, 0)),
                pl.BlockSpec((1, 1, ps, hd),
                             lambda b, h, j, bt, p:
                             (page(b, h, j, bt, p), h // group, 0, 0)),
                pl.BlockSpec((1, ps),
                             lambda b, h, j, bt, p:
                             (page(b, h, j, bt, p), 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda b, h, j, bt, p: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(pos, jnp.int32),
      q, k_pages, v_pages, pos_pages)
