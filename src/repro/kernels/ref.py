"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bgmv_shrink_ref(x, a_pool, idx):
    """y[b] = x[b] @ A[idx[b]].  x: (B, d_in); a_pool: (S, d_in, r) -> (B, r).
    idx<0 -> zero row (no adapter)."""
    safe = jnp.where(idx >= 0, idx, 0)
    y = jnp.einsum("bd,bdr->br", x, a_pool[safe])
    return y * (idx >= 0)[:, None].astype(y.dtype)


def bgmv_expand_ref(y, b_pool, idx):
    """out[b] = y[b] @ B[idx[b]].  y: (B, r); b_pool: (S, r, d_out)."""
    safe = jnp.where(idx >= 0, idx, 0)
    out = jnp.einsum("br,bro->bo", y, b_pool[safe])
    return out * (idx >= 0)[:, None].astype(out.dtype)


def bgmv_ref(x, a_pool, b_pool, idx):
    """Full BGMV delta (pad-to-max semantics): x (B,d_in) -> (B,d_out)."""
    return bgmv_expand_ref(bgmv_shrink_ref(x, a_pool, idx), b_pool, idx)


def mbgmv_shrink_ref(x, a_pool, idx, ranks, rank_block=16):
    """Rank-block-skip shrink: bgmv_shrink_ref with rank columns past each
    adapter's ceil(rank/rank_block) live blocks forced to zero, f32 output
    (the kernel's accumulator dtype)."""
    safe = jnp.where(idx >= 0, idx, 0)
    nblk = (ranks[safe] + rank_block - 1) // rank_block * rank_block
    y = bgmv_shrink_ref(x, a_pool, idx).astype(jnp.float32)
    return y * (jnp.arange(y.shape[-1])[None] < nblk[:, None]).astype(y.dtype)


def mbgmv_expand_ref(y, b_pool, idx, ranks, rank_block=16):
    """Rank-block-skip expand: dead rank blocks contribute exactly zero."""
    safe = jnp.where(idx >= 0, idx, 0)
    nblk = (ranks[safe] + rank_block - 1) // rank_block * rank_block
    y = y * (jnp.arange(y.shape[-1])[None] < nblk[:, None]).astype(y.dtype)
    return bgmv_expand_ref(y, b_pool, idx)


def mbgmv_ref(x, a_pool, b_pool, idx, ranks, rank_block=16):
    """Rank-block-skip semantics (sum-rank law). Numerically identical to
    bgmv_ref when the pool is zero-padded beyond each adapter's rank; the mask
    additionally guards against junk in unused rank columns."""
    safe = jnp.where(idx >= 0, idx, 0)
    nblk = (ranks[safe] + rank_block - 1) // rank_block * rank_block
    y = bgmv_shrink_ref(x, a_pool, idx)
    y = y * (jnp.arange(y.shape[-1])[None] < nblk[:, None]).astype(y.dtype)
    return bgmv_expand_ref(y, b_pool, idx)


def paged_attention_ref(q, k_pages, v_pages, pos_pages, block_table, pos):
    """Paged decode attention oracle (one layer, one token per row).

    q: (B, H, hd); k_pages/v_pages: (P, KV, ps, hd); pos_pages: (P, ps)
    absolute positions (-1 = empty slot); block_table: (B, W) physical page
    per logical page (-1 = unclaimed); pos: (B,) current position.
    Returns (B, H, hd). Gathers each row's pages into a dense (W*ps)-deep
    view and runs masked GQA attention — slots of unclaimed pages and empty
    slots of claimed pages are masked out, so garbage behind them (pages of
    other rows) contributes exactly zero."""
    b, h, hd = q.shape
    kv = k_pages.shape[1]
    safe = jnp.maximum(block_table, 0)
    k = k_pages[safe].transpose(0, 2, 1, 3, 4).reshape(b, kv, -1, hd)
    v = v_pages[safe].transpose(0, 2, 1, 3, 4).reshape(b, kv, -1, hd)
    kpos = jnp.where(block_table[:, :, None] >= 0,
                     pos_pages[safe], -1).reshape(b, -1)
    qg = q.reshape(b, kv, h // kv, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k).astype(jnp.float32) / hd ** 0.5
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v)
    # rows with no attendable slot at all (all pages unclaimed, or every
    # claimed slot empty) are defined to return zeros — softmax over an
    # all-masked row would otherwise average garbage uniformly; the Pallas
    # kernel's mask-aware p gives the same zeros
    any_valid = valid.any(axis=-1)
    out = out * any_valid[:, None, None, None].astype(out.dtype)
    return out.reshape(b, h, hd)


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,H,Lq,hd); k/v: (B,KV,Lk,hd). GQA by head grouping."""
    b, h, lq, hd = q.shape
    kv, lk = k.shape[1], k.shape[2]
    qg = q.reshape(b, kv, h // kv, lq, hd)
    s = jnp.einsum("bkglh,bksh->bkgls", qg, k).astype(jnp.float32) / hd ** 0.5
    qpos = jnp.arange(lq)[:, None] + (lk - lq)   # decode-style alignment
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgls,bksh->bkglh", p.astype(v.dtype), v)
    return out.reshape(b, h, lq, hd)
