"""Flash attention (prefill) Pallas TPU kernel — the compute hot-spot of the
32k-prefill serving path.

Online-softmax over KV blocks with VMEM scratch accumulators; GQA via
index_map head folding (q head h reads kv head h // group). Causal and
sliding-window masks skip whole KV blocks at grid level (pl.when), so windowed
prefill is O(L·W) not O(L²). Block shapes are (8,128)-tile aligned:
BQ=BK=256, hd in lanes.

Validated against kernels.ref.flash_attention_ref in interpret mode; the
pure-jnp chunked path (models.layers.attn_chunked) is the portable fallback
used by the dry-run (Pallas TPU kernels do not lower on the CPU backend).

Statically verified by `analysis.kernel_verify` (lint rules `kernel-*`,
CLI `tools/kverify.py`): contiguous revisits of the output block over
the KV grid dim (the TPU revisit rule), m/l/acc scratch
init/flush/carry discipline, f32 accumulators with
`preferred_element_type` on every dot, and the per-step VMEM footprint
at every `configs/` shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq, bk, lk_real, causal, window, scale):
    i, j = pl.program_id(2), pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_end = (i + 1) * bq - 1
    k_start = j * bk
    needed = k_start <= q_end if causal else True
    if window is not None:
        needed = jnp.logical_and(needed,
                                 (j + 1) * bk - 1 >= i * bq - window) \
            if causal else ((j + 1) * bk - 1 >= i * bq - window)

    @pl.when(needed)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < lk_real
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # mask-aware p: `needed` is a block-granular overapproximation, so a
        # grid step can run with every element masked (s == m_new == NEG_INF,
        # exp -> 1); zeroing by the mask keeps such blocks contributing
        # exactly nothing instead of summing garbage V rows
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, bq=256, bk=256,
                    interpret=None):
    """q: (B,H,Lq,hd); k/v: (B,KV,Lk,hd) -> (B,H,Lq,hd)."""
    B, H, Lq, hd = q.shape
    KV, Lk = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"flash_attention: H ({H}) not divisible by KV "
                         f"({KV}) — q {q.shape} vs k {k.shape}")
    if k.shape != v.shape:
        raise ValueError(f"flash_attention: k {k.shape} != v {v.shape}")
    group = H // KV
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bq = min(bq, Lq)
    bk = min(bk, Lk)
    pad_q = (-Lq) % bq
    pad_k = (-Lk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v
    grid = (B, H, qp.shape[2] // bq, kp.shape[2] // bk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, lk_real=Lk, causal=causal,
        window=window, scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Lq]
