"""BGMV — Batched Gather Matrix-Vector multiply (Punica, adapted to TPU).

Per decode step each request multiplies its hidden vector by its own
adapter's low-rank factors, gathered from the device slot pool:

    shrink:  y[b]   = x[b] @ A[idx[b]]        (B, d_in) -> (B, r_max)
    expand:  out[b] = y[b] @ B[idx[b]]        (B, r_max) -> (B, d_out)

TPU adaptation (DESIGN.md sec 2): the CUDA kernel's warp-level gather becomes
scalar-prefetched BlockSpec index_maps — the adapter index idx[b] is read
before the grid step, so the DMA engine pulls A[idx[b]] HBM->VMEM tiles
directly; no gather materialization. d_in is tiled (D_BLOCK) with VMEM
accumulation over the grid's minor axis; pad-to-max-rank semantics (the
whole r_max extent is computed regardless of the adapter's true rank) gives
BGMV its max-rank cost law (paper Fig 4-left).

Grid sizes are MXU/VPU aligned: D_BLOCK, O_BLOCK multiples of 128 lanes;
r_max (64) sits in the sublane dim of the (8,128) fp32 tile.

Statically verified by `analysis.kernel_verify` (lint rules `kernel-*`,
CLI `tools/kverify.py`): output-block coverage and revisit contiguity
over the (B, d-blocks) grid, index-map bounds with the clamped
`idx[b]` gather paired to its `pl.when(idx_ref[b] >= 0)` guard, the
shrink accumulator's init-at-step-0, and the per-step VMEM footprint at
every `configs/` shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

D_BLOCK = 512
O_BLOCK = 512


def _fit_block(dim: int, want: int) -> int:
    """Largest divisor of dim that is <= want (keeps tiles grid-aligned for
    non-power-of-two model dims, e.g. whisper's 384)."""
    b = min(want, dim)
    while dim % b:
        b -= 1
    return b


def _shrink_kernel(idx_ref, x_ref, a_ref, y_ref):
    b, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    # the index_map clamps idx[b] to slot 0 for adapterless rows (idx < 0);
    # this guard skips the whole grid step so the clamped (stale) gather
    # never contributes — the invariant kernel-bounds proves statically
    @pl.when(idx_ref[b] >= 0)
    def _():
        x = x_ref[...]                  # (1, D_BLOCK)
        a = a_ref[0]                    # (D_BLOCK, r)
        y_ref[...] += jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32),
                              preferred_element_type=jnp.float32
                              ).astype(y_ref.dtype)


def bgmv_shrink(x, a_pool, idx, *, d_block=D_BLOCK, interpret=None):
    """x: (B, d_in); a_pool: (slots, d_in, r); idx: (B,) -> (B, r) fp32."""
    B, d_in = x.shape
    slots, a_d_in, r = a_pool.shape
    if a_d_in != d_in:
        raise ValueError(f"bgmv_shrink: x {x.shape} and a_pool "
                         f"{a_pool.shape} disagree on d_in "
                         f"({d_in} vs {a_d_in})")
    if idx.shape != (B,):
        raise ValueError(f"bgmv_shrink: idx {idx.shape} must be ({B},) "
                         f"to match x {x.shape}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    d_block = _fit_block(d_in, d_block)
    if d_in % d_block:
        raise ValueError(f"bgmv_shrink: d_in ({d_in}) not divisible by "
                         f"d_block ({d_block})")
    grid = (B, d_in // d_block)
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d_block), lambda b, i, idx: (b, i)),
                pl.BlockSpec((1, d_block, r),
                             lambda b, i, idx: (jnp.maximum(idx[b], 0), i, 0)),
            ],
            out_specs=pl.BlockSpec((1, r), lambda b, i, idx: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, r), jnp.float32),
        interpret=interpret,
    )(idx, x, a_pool)


def _expand_kernel(idx_ref, y_ref, b_ref, o_ref):
    b = pl.program_id(0)
    o_ref[...] = jnp.zeros_like(o_ref)

    # clamp-paired guard: adapterless rows keep the zero block (see shrink)
    @pl.when(idx_ref[b] >= 0)
    def _():
        y = y_ref[...]                  # (1, r)
        w = b_ref[0]                    # (r, O_BLOCK)
        o_ref[...] = jnp.dot(y.astype(jnp.float32), w.astype(jnp.float32),
                             preferred_element_type=jnp.float32
                             ).astype(o_ref.dtype)


def bgmv_expand(y, b_pool, idx, *, o_block=O_BLOCK, out_dtype=None,
                interpret=None):
    """y: (B, r); b_pool: (slots, r, d_out); idx: (B,) -> (B, d_out)."""
    B, r = y.shape
    slots, b_r, d_out = b_pool.shape
    if b_r != r:
        raise ValueError(f"bgmv_expand: y {y.shape} and b_pool "
                         f"{b_pool.shape} disagree on rank ({r} vs {b_r})")
    if idx.shape != (B,):
        raise ValueError(f"bgmv_expand: idx {idx.shape} must be ({B},) "
                         f"to match y {y.shape}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    o_block = _fit_block(d_out, o_block)
    if d_out % o_block:
        raise ValueError(f"bgmv_expand: d_out ({d_out}) not divisible by "
                         f"o_block ({o_block})")
    out_dtype = out_dtype or y.dtype
    grid = (B, d_out // o_block)
    return pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, r), lambda b, o, idx: (b, 0)),
                pl.BlockSpec((1, r, o_block),
                             lambda b, o, idx: (jnp.maximum(idx[b], 0), 0, o)),
            ],
            out_specs=pl.BlockSpec((1, o_block), lambda b, o, idx: (b, o)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, d_out), out_dtype),
        interpret=interpret,
    )(idx, y, b_pool)


def bgmv(x, a_pool, b_pool, idx, **kw):
    """Full LoRA delta, pad-to-max (max-rank cost law)."""
    y = bgmv_shrink(x, a_pool, idx, interpret=kw.get("interpret"))
    return bgmv_expand(y.astype(x.dtype), b_pool, idx,
                       out_dtype=x.dtype, interpret=kw.get("interpret"))
