"""MBGMV — padding-free multi-size BGMV (S-LoRA), adapted to TPU via
rank-block skipping.

TPU has no efficient ragged matrix-vector op (the CUDA kernel indexes rows
at warp granularity). The TPU-native equivalent quantizes ranks to RB-lane
blocks and *skips whole grid steps* for rank blocks beyond the adapter's
rank with pl.when: compute ∝ Σ_b ceil(rank_b / RB)·RB ≈ Σ_b rank_b, which
preserves S-LoRA's sum-rank cost law (paper Fig 4-right / sec 5) up to RB
quantization. Numerics are identical to BGMV because the pool is
zero-padded beyond each adapter's rank.

Statically verified by `analysis.kernel_verify` (lint rules `kernel-*`,
CLI `tools/kverify.py`): the expand path's f32 VMEM accumulator is
proved init-under-`pl.when(j == 0)` / flush-under-`pl.when(j == nj-1)`
with carry on every overwrite — the revisited output block discipline
interpret mode cannot exercise — plus bounds, revisit contiguity, and
the VMEM budget at every `configs/` shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RANK_BLOCK = 16
O_BLOCK = 512


def _shrink_kernel(idx_ref, nblk_ref, x_ref, a_ref, y_ref):
    b, j = pl.program_id(0), pl.program_id(1)
    live = jnp.logical_and(idx_ref[b] >= 0, j < nblk_ref[b])

    y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(live)
    def _():
        x = x_ref[...].astype(jnp.float32)          # (1, d_in)
        a = a_ref[0].astype(jnp.float32)            # (d_in, RB)
        y_ref[...] = jnp.dot(x, a,
                             preferred_element_type=jnp.float32
                             ).astype(y_ref.dtype)


def mbgmv_shrink(x, a_pool, idx, ranks, *, rank_block=RANK_BLOCK,
                 interpret=None):
    """x: (B, d_in); a_pool: (S, d_in, r_max); ranks: (S,) -> (B, r_max)."""
    B, d_in = x.shape
    slots, a_d_in, r_max = a_pool.shape
    if a_d_in != d_in:
        raise ValueError(f"mbgmv_shrink: x {x.shape} and a_pool "
                         f"{a_pool.shape} disagree on d_in "
                         f"({d_in} vs {a_d_in})")
    if ranks.shape != (slots,):
        raise ValueError(f"mbgmv_shrink: ranks {ranks.shape} must be "
                         f"({slots},) to match a_pool {a_pool.shape}")
    if idx.shape != (B,):
        raise ValueError(f"mbgmv_shrink: idx {idx.shape} must be ({B},) "
                         f"to match x {x.shape}")
    if r_max % rank_block:
        raise ValueError(
            f"r_max ({r_max}) must be a multiple of rank_block "
            f"({rank_block})")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nrb = r_max // rank_block
    safe = jnp.maximum(idx, 0)
    nblk = (ranks[safe] + rank_block - 1) // rank_block   # (B,) live blocks
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nrb),
            in_specs=[
                pl.BlockSpec((1, d_in), lambda b, j, idx, nb: (b, 0)),
                pl.BlockSpec((1, d_in, rank_block),
                             lambda b, j, idx, nb: (jnp.maximum(idx[b], 0),
                                                    0, j)),
            ],
            out_specs=pl.BlockSpec((1, rank_block),
                                   lambda b, j, idx, nb: (b, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, r_max), jnp.float32),
        interpret=interpret,
    )(idx, nblk.astype(jnp.int32), x, a_pool)


def _expand_kernel(idx_ref, nblk_ref, y_ref, b_ref, o_ref, acc_ref):
    b, j = pl.program_id(0), pl.program_id(2)
    nj = pl.num_programs(2)
    live = jnp.logical_and(idx_ref[b] >= 0, j < nblk_ref[b])

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _():
        y = y_ref[...].astype(jnp.float32)           # (1, RB)
        w = b_ref[0].astype(jnp.float32)             # (RB, O_BLOCK)
        acc_ref[...] += jnp.dot(y, w,
                                preferred_element_type=jnp.float32)

    # f32 accumulation across rank blocks; the output dtype cast happens
    # exactly once at the flush (kernel-scratch / kernel-dtype invariants)
    @pl.when(j == nj - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def mbgmv_expand(y, b_pool, idx, ranks, *, rank_block=RANK_BLOCK,
                 o_block=O_BLOCK, out_dtype=None, interpret=None):
    """y: (B, r_max); b_pool: (S, r_max, d_out) -> (B, d_out)."""
    B, r_max = y.shape
    slots, b_r_max, d_out = b_pool.shape
    if b_r_max != r_max:
        raise ValueError(f"mbgmv_expand: y {y.shape} and b_pool "
                         f"{b_pool.shape} disagree on r_max "
                         f"({r_max} vs {b_r_max})")
    if ranks.shape != (slots,):
        raise ValueError(f"mbgmv_expand: ranks {ranks.shape} must be "
                         f"({slots},) to match b_pool {b_pool.shape}")
    if idx.shape != (B,):
        raise ValueError(f"mbgmv_expand: idx {idx.shape} must be ({B},) "
                         f"to match y {y.shape}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    from repro.kernels.bgmv import _fit_block
    o_block = _fit_block(d_out, o_block)
    if d_out % o_block:
        raise ValueError(f"mbgmv_expand: d_out ({d_out}) not divisible by "
                         f"o_block ({o_block})")
    if r_max % rank_block:
        raise ValueError(
            f"r_max ({r_max}) must be a multiple of rank_block "
            f"({rank_block})")
    nrb = r_max // rank_block
    safe = jnp.maximum(idx, 0)
    nblk = (ranks[safe] + rank_block - 1) // rank_block
    out_dtype = out_dtype or y.dtype
    return pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, d_out // o_block, nrb),
            in_specs=[
                pl.BlockSpec((1, rank_block),
                             lambda b, o, j, idx, nb: (b, j)),
                pl.BlockSpec((1, rank_block, o_block),
                             lambda b, o, j, idx, nb: (jnp.maximum(idx[b], 0),
                                                       j, o)),
            ],
            out_specs=pl.BlockSpec((1, o_block),
                                   lambda b, o, j, idx, nb: (b, o)),
            scratch_shapes=[
                pltpu.VMEM((1, o_block), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, d_out), out_dtype),
        interpret=interpret,
    )(idx, nblk.astype(jnp.int32), y, b_pool)


def mbgmv(x, a_pool, b_pool, idx, ranks, *, rank_block=RANK_BLOCK, **kw):
    y = mbgmv_shrink(x, a_pool, idx, ranks, rank_block=rank_block,
                     interpret=kw.get("interpret"))
    return mbgmv_expand(y.astype(x.dtype), b_pool, idx, ranks,
                        rank_block=rank_block, out_dtype=x.dtype,
                        interpret=kw.get("interpret"))
