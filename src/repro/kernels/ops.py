"""Jit'd public wrappers for the Pallas kernels, with automatic fallback to
the pure-jnp oracle where Pallas cannot lower (CPU backend uses
interpret=True; the oracle itself is exported for the dry-run path)."""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.bgmv import bgmv, bgmv_expand, bgmv_shrink
from repro.kernels.flash import flash_attention
from repro.kernels.mbgmv import mbgmv, mbgmv_expand, mbgmv_shrink
from repro.kernels.paged import paged_attention as _paged_attention

lora_delta_bgmv = jax.jit(bgmv, static_argnames=("interpret",))
lora_delta_mbgmv = jax.jit(mbgmv, static_argnames=("rank_block", "interpret"))
lora_delta_ref = jax.jit(ref.bgmv_ref, static_argnums=())

paged_attention = jax.jit(_paged_attention, static_argnames=("interpret",))


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention(q, k, v, causal=True, window=None):
    return flash_attention(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("mode", "rank_block"))
def lora_delta(x, a_pool, b_pool, idx, ranks=None, mode="bgmv",
               rank_block=16):
    """Dispatch by kernel mode (the scheduler's two performance laws)."""
    if mode == "bgmv":
        return bgmv(x, a_pool, b_pool, idx)
    if mode == "mbgmv":
        return mbgmv(x, a_pool, b_pool, idx, ranks, rank_block=rank_block)
    if mode == "ref":
        return ref.bgmv_ref(x, a_pool, b_pool, idx)
    raise ValueError(mode)
