"""Static verification of Pallas TPU kernels over ``kernel_model`` models.

Interpret mode runs grid steps sequentially and hides the hardware bug
class; these checks prove the TPU invariants statically, per kernel:

* ``kernel-race``    — (1) coverage/race: every output block coordinate is
  written by >= 1 grid step, and revisits of the same output block are
  *contiguous* in the sequential grid order (the TPU revisit rule —
  non-contiguous revisits are nondeterministic on hardware but pass
  interpret mode).
* ``kernel-bounds``  — (2) bounds: ``index_map(...) * block_shape`` stays
  inside the operand for every enumerated grid point (with representative
  scalar-prefetch operands including ``-1`` sentinels), and a clamped
  gather in an index map (``jnp.maximum(bt[b, j], 0)``) must be paired
  with a ``pl.when`` guard on the same scalar in the kernel body — else
  the clamped (stale/foreign) block is read *and used*.
* ``kernel-scratch`` — (3) VMEM scratch accumulators must be initialized
  under ``pl.when(inner == 0)`` and flushed to an output under
  ``pl.when(inner == n_inner - 1)`` of the revisiting grid dimension;
  accumulating writes must carry the previous value; outputs must not be
  written only under data-dependent guards (unselected blocks would keep
  garbage VMEM).
* ``kernel-dtype``   — (4) dtype discipline: ``preferred_element_type``
  on every in-kernel ``jnp.dot``, f32 scratch accumulators, and no
  cross-step accumulation into a sub-f32 output block.
* ``kernel-vmem``    — (5) per-grid-step VMEM footprint (double-buffered
  blocks + scratch) against the per-core budget.

The checks run over a :class:`~repro.analysis.kernel_model.KernelModel`,
so the same code verifies the shipped kernels *and* programmatically
perturbed mutants (see ``tests/test_kernel_verify.py``): the model's
index maps can be wrapped, its grid permuted, and its kernel AST edited.
"""
from __future__ import annotations

import ast
import dataclasses
import inspect
import textwrap
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Sequence, \
    Set, Tuple

import numpy as np

from repro.analysis.kernel_model import KernelModel, SpecModel

# ~16 MB of VMEM per TPU core (v4/v5 generations); the budget the footprint
# table reports against. Override with tools/kverify.py --budget.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

KERNEL_RULES = ("kernel-race", "kernel-bounds", "kernel-scratch",
                "kernel-dtype", "kernel-vmem")


@dataclasses.dataclass(frozen=True)
class KernelFinding:
    rule: str
    path: str
    line: int
    message: str
    kernel: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] " \
               f"{self.kernel}: {self.message}"


# ----------------------------------------------------------- lambda source --

_FILE_AST: Dict[str, Optional[ast.Module]] = {}


def _file_ast(path: str) -> Optional[ast.Module]:
    if path not in _FILE_AST:
        try:
            with open(path) as f:
                _FILE_AST[path] = ast.parse(f.read())
        except (OSError, SyntaxError):
            _FILE_AST[path] = None
    return _FILE_AST[path]


def _callable_node(fn: Callable) -> Optional[ast.AST]:
    """AST (Lambda or FunctionDef) of a callable, located by source line."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return None
    if fn.__name__ != "<lambda>":
        try:
            node = ast.parse(textwrap.dedent(inspect.getsource(fn))).body[0]
            return node if isinstance(node, ast.FunctionDef) else None
        except (OSError, SyntaxError, IndexError):
            return None
    tree = _file_ast(code.co_filename)
    if tree is None:
        return None
    cands = [n for n in ast.walk(tree) if isinstance(n, ast.Lambda)
             and n.lineno == code.co_firstlineno]
    if len(cands) > 1:
        cands = [n for n in cands
                 if len(n.args.args) == code.co_argcount] or cands
    return cands[0] if cands else None


def _fn_params(node: ast.AST) -> List[str]:
    return [a.arg for a in node.args.args]


def _resolve_name(fn: Callable, name: str):
    """Resolve `name` in fn's closure, then globals."""
    code = getattr(fn, "__code__", None)
    if code is not None and fn.__closure__ and name in code.co_freevars:
        try:
            return fn.__closure__[
                code.co_freevars.index(name)].cell_contents
        except ValueError:
            return None
    return getattr(fn, "__globals__", {}).get(name)


def _clamp_names(fn: Callable, depth: int = 2) -> Set[str]:
    """Parameter names of `fn` whose subscripted value flows through a
    clamp-to-zero (``jnp.maximum(x[...], 0)`` / ``jnp.clip(x[...], 0,
    ...)``) inside `fn` or a callee resolved from its closure/globals."""
    node = _callable_node(fn)
    if node is None:
        return set()
    params = set(_fn_params(node))
    clamped: Set[str] = set()

    def names_in(expr: ast.AST) -> Set[str]:
        return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}

    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        f = sub.func
        if isinstance(f, ast.Attribute) and f.attr in ("maximum", "clip") \
                and len(sub.args) >= 2 \
                and isinstance(sub.args[1], ast.Constant) \
                and sub.args[1].value == 0:
            clamped |= names_in(sub.args[0]) & params
        elif isinstance(f, ast.Name) and depth > 0:
            callee = _resolve_name(fn, f.id)
            cnode = _callable_node(callee) if callable(callee) else None
            if cnode is None:
                continue
            inner = _clamp_names(callee, depth - 1)
            cparams = _fn_params(cnode)
            for nm in inner:
                if nm in cparams:
                    pos = cparams.index(nm)
                    if pos < len(sub.args):
                        clamped |= names_in(sub.args[pos]) & params
    return clamped


def clamped_scalar_operands(model: KernelModel,
                            spec: SpecModel) -> Set[int]:
    """Scalar-prefetch operand indices that `spec`'s index_map clamps."""
    node = _callable_node(spec.index_map)
    if node is None:
        return set()
    params = _fn_params(node)
    n_grid = len(model.grid)
    out: Set[int] = set()
    for nm in _clamp_names(spec.index_map):
        if nm in params:
            i = params.index(nm)
            if i >= n_grid:
                out.add(i - n_grid)
    return out


# ------------------------------------------------------- kernel body model --

@dataclasses.dataclass
class _Write:
    ref: str
    node: ast.AST
    guards: Tuple[Tuple[str, Any], ...]   # stack of classified pl.when preds
    aug: bool
    rhs: Optional[ast.AST]


class KernelBody:
    """Guard-aware read/write model of a kernel function's AST."""

    def __init__(self, model: KernelModel):
        self.model = model
        self.fn = model.kernel_ast
        self.roles = model.param_roles() or {}
        self.env: Dict[str, ast.AST] = {}
        self.writes: List[_Write] = []
        self.guard_preds: List[ast.AST] = []   # every pl.when predicate
        if self.fn is None:
            return
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t, v = node.targets[0], node.value
                if isinstance(t, ast.Name):
                    self.env.setdefault(t.id, v)
                elif isinstance(t, ast.Tuple) and isinstance(v, ast.Tuple) \
                        and len(t.elts) == len(v.elts):
                    for te, ve in zip(t.elts, v.elts):
                        if isinstance(te, ast.Name):
                            self.env.setdefault(te.id, ve)
        self._walk(self.fn.body, ())

    # -------------------------------------------------------------- walk --
    def _when_pred(self, node: ast.AST) -> Optional[ast.AST]:
        """Predicate of a ``@pl.when(pred)`` decorator node."""
        if isinstance(node, ast.Call) and node.args \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "when":
            return node.args[0]
        return None

    def _walk(self, body: Sequence[ast.stmt],
              guards: Tuple[Tuple[str, Any], ...]):
        for stmt in body:
            if isinstance(stmt, ast.FunctionDef):
                preds = [p for p in map(self._when_pred,
                                        stmt.decorator_list)
                         if p is not None]
                g = guards
                for p in preds:
                    self.guard_preds.append(p)
                    g = g + (self.classify_guard(p),)
                self._walk(stmt.body, g)
                continue
            for node in ast.walk(stmt):
                tgt = rhs = None
                aug = False
                if isinstance(node, ast.Assign):
                    tgt, rhs = node.targets, node.value
                elif isinstance(node, ast.AugAssign):
                    tgt, rhs, aug = [node.target], node.value, True
                if tgt is None:
                    continue
                for t in tgt:
                    if isinstance(t, ast.Subscript) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id in self.roles:
                        self.writes.append(_Write(
                            ref=t.value.id, node=node, guards=guards,
                            aug=aug, rhs=rhs))

    # ------------------------------------------------------------ expand --
    def expanded(self, expr: ast.AST, depth: int = 4):
        """All AST nodes of expr, expanding Name loads via local assigns."""
        stack = [(expr, depth)]
        while stack:
            node, d = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                stack.append((child, d))
            if isinstance(node, ast.Name) and d > 0 and node.id in self.env:
                stack.append((self.env[node.id], d - 1))

    def _deref(self, expr: ast.AST, depth: int = 4) -> ast.AST:
        while isinstance(expr, ast.Name) and expr.id in self.env \
                and depth > 0:
            expr = self.env[expr.id]
            depth -= 1
        return expr

    def _pid_dim(self, expr: ast.AST) -> Optional[int]:
        e = self._deref(expr)
        if isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute) \
                and e.func.attr == "program_id" and e.args \
                and isinstance(e.args[0], ast.Constant):
            return int(e.args[0].value)
        return None

    def _mentions_num_programs(self, expr: ast.AST, dim: int) -> bool:
        for n in self.expanded(expr):
            if isinstance(n, ast.Call) \
                    and isinstance(n.func, ast.Attribute) \
                    and n.func.attr == "num_programs" and n.args \
                    and isinstance(n.args[0], ast.Constant) \
                    and int(n.args[0].value) == dim:
                return True
        return False

    # ---------------------------------------------------------- classify --
    def classify_guard(self, pred: ast.AST) -> Tuple[str, Any]:
        """('init', dim) for ``pid(dim) == 0``; ('flush', dim) for
        ``pid(dim) == <expr using num_programs(dim)>``; ('data', refs)
        otherwise, with the ref params the predicate tests."""
        e = self._deref(pred)
        if isinstance(e, ast.Compare) and len(e.ops) == 1 \
                and isinstance(e.ops[0], ast.Eq):
            for a, b in ((e.left, e.comparators[0]),
                         (e.comparators[0], e.left)):
                d = self._pid_dim(a)
                if d is None:
                    continue
                bb = self._deref(b)
                if isinstance(bb, ast.Constant) and bb.value == 0:
                    return ("init", d)
                if self._mentions_num_programs(b, d):
                    return ("flush", d)
        refs = frozenset(n.id for n in self.expanded(pred)
                         if isinstance(n, ast.Name) and n.id in self.roles)
        return ("data", refs)

    # ------------------------------------------------------------ helpers --
    def refs_any(self, expr: Optional[ast.AST],
                 names: Set[str]) -> bool:
        """`expr` (expanded) *loads* one of `names` via subscript
        (``ref[...]``). A bare attribute mention like ``o_ref.dtype``
        does not count — it reads metadata, not VMEM."""
        if expr is None:
            return False
        return any(isinstance(n, ast.Subscript)
                   and isinstance(n.value, ast.Name) and n.value.id in names
                   for n in self.expanded(expr))

    def writes_to(self, ref: str) -> List[_Write]:
        return [w for w in self.writes if w.ref == ref]

    def has_guard_on_scalar(self, param: str) -> bool:
        """Some pl.when predicate compares `param[...]` against 0."""
        for pred in self.guard_preds:
            for n in self.expanded(pred):
                if isinstance(n, ast.Compare) and len(n.ops) == 1:
                    sides = [n.left] + list(n.comparators)
                    if any(isinstance(s, ast.Subscript)
                           and isinstance(s.value, ast.Name)
                           and s.value.id == param for s in sides) \
                            and any(isinstance(s, ast.Constant)
                                    and s.value == 0 for s in sides):
                        return True
        return False

    def dot_calls(self) -> List[ast.Call]:
        if self.fn is None:
            return []
        return [n for n in ast.walk(self.fn)
                if isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr in ("dot", "dot_general")]


# ------------------------------------------------------------ verification --

class Verifier:
    def __init__(self, model: KernelModel,
                 vmem_budget: int = VMEM_BUDGET_BYTES):
        self.m = model
        self.budget = vmem_budget
        self.findings: List[KernelFinding] = []
        self._out_coords: List[List[Tuple[int, ...]]] = []

    def _emit(self, rule: str, line: int, message: str):
        f = KernelFinding(rule=rule, path=self.m.path, line=line,
                          message=message, kernel=self.m.name)
        if f not in self.findings:
            self.findings.append(f)

    # ------------------------------------------------------------- run --
    def run(self) -> List[KernelFinding]:
        self._eval_out_coords()
        self.check_race()
        self.check_bounds()
        body = KernelBody(self.m)
        if self.m.kernel_ast is not None and self.m.param_roles():
            self.check_scratch(body)
            self.check_dtype(body)
        self.check_vmem()
        return self.findings

    # -------------------------------------------------- coverage / race --
    def _eval_out_coords(self):
        self._out_coords = []
        points = list(self.m.grid_points())
        for spec in self.m.out_specs:
            self._out_coords.append(
                [self.m.eval_index(spec, p) for p in points])

    def revisit_dims(self, oi: int = 0) -> Set[int]:
        """Grid dims along which output `oi`'s block coordinate repeats."""
        points = list(self.m.grid_points())
        coords = self._out_coords[oi]
        dims: Set[int] = set()
        for d in range(len(self.m.grid)):
            seen: Dict[Tuple, Dict[Tuple, int]] = {}
            for p, c in zip(points, coords):
                rest = p[:d] + p[d + 1:]
                vals = seen.setdefault(rest, {})
                vals[c] = vals.get(c, 0) + 1
                if vals[c] > 1:
                    dims.add(d)
                    break
            if d in dims:
                continue
        return dims

    def inner_dim(self) -> Optional[int]:
        """Innermost revisiting grid dimension over all outputs."""
        dims: Set[int] = set()
        for oi in range(len(self.m.out_specs)):
            dims |= self.revisit_dims(oi)
        return max(dims) if dims else None

    def check_race(self):
        for oi, spec in enumerate(self.m.out_specs):
            coords = self._out_coords[oi]
            nblocks = tuple(-(-dim // bs) for dim, bs
                            in zip(spec.shape, spec.block_shape))
            visits: Dict[Tuple[int, ...], List[int]] = {}
            for step, c in enumerate(coords):
                visits.setdefault(c, []).append(step)
            missing = [c for c in np.ndindex(*nblocks) if c not in visits]
            if missing:
                self._emit(
                    "kernel-race", spec.line or self.m.line,
                    f"{len(missing)} output block(s) of `{spec.name}` "
                    f"never written by any grid step (first missing: "
                    f"{missing[0]}, grid {self.m.grid})")
            for c, steps in sorted(visits.items()):
                if steps[-1] - steps[0] + 1 != len(steps):
                    self._emit(
                        "kernel-race", spec.line or self.m.line,
                        f"output block {c} of `{spec.name}` is revisited "
                        f"non-contiguously (grid steps {steps[:4]}...): "
                        "revisits must be consecutive in the sequential "
                        "grid order — nondeterministic on TPU, invisible "
                        "in interpret mode")
                    break

    # ------------------------------------------------------------ bounds --
    def check_bounds(self):
        points = list(self.m.grid_points())
        for spec in self.m.in_specs + self.m.out_specs:
            bad = None
            nbad = 0
            for p in points:
                c = self.m.eval_index(spec, p)
                for d, (ci, bs, dim) in enumerate(
                        zip(c, spec.block_shape, spec.shape)):
                    hi = -(-dim // bs) - 1
                    if ci < 0 or ci > hi:
                        nbad += 1
                        if bad is None:
                            bad = (p, c, d, hi)
                        break
            if bad is not None:
                p, c, d, hi = bad
                self._emit(
                    "kernel-bounds", spec.line or self.m.line,
                    f"index_map of `{spec.name}` out of bounds at grid "
                    f"point {p}: block coord {c} dim {d} outside [0, {hi}] "
                    f"for operand shape {spec.shape} x block "
                    f"{spec.block_shape} ({nbad} grid point(s) affected)")
        # clamp / guard pairing
        body = KernelBody(self.m)
        if self.m.kernel_ast is None or not self.m.param_roles():
            return
        for spec in self.m.in_specs:
            for k in clamped_scalar_operands(self.m, spec):
                param = self.m.scalar_param(k)
                if param is None:
                    continue
                if not body.has_guard_on_scalar(param):
                    self._emit(
                        "kernel-bounds", spec.line or self.m.line,
                        f"index_map of `{spec.name}` clamps scalar operand "
                        f"`{param}` (jnp.maximum(..., 0)) but the kernel "
                        f"body has no pl.when guard comparing `{param}` "
                        "against 0 — the clamped gather reads a "
                        "stale/foreign block that is then *used* "
                        "(tenant-isolation hazard)")

    # ----------------------------------------------------------- scratch --
    def check_scratch(self, body: KernelBody):
        roles = body.roles
        scratch_names = {p for p, r in roles.items() if r == "scratch"}
        out_names = [p for p, r in roles.items() if r == "output"]
        inner = self.inner_dim()
        kline = self.m.line

        def is_init(w: _Write) -> bool:
            return any(g[0] == "init" and (inner is None or g[1] == inner)
                       for g in w.guards)

        def is_flush(w: _Write) -> bool:
            return any(g[0] == "flush" and (inner is None or g[1] == inner)
                       for g in w.guards)

        def pid_only(w: _Write) -> bool:
            return all(g[0] in ("init", "flush") for g in w.guards)

        for s in scratch_names:
            writes = body.writes_to(s)
            if not writes:
                self._emit("kernel-scratch", kline,
                           f"VMEM scratch `{s}` is never written — "
                           "uninitialized VMEM if read")
                continue
            accumulating = any(
                w.aug or body.refs_any(w.rhs, scratch_names)
                for w in writes if not is_init(w))
            unconditional = any(not w.guards for w in writes)
            if accumulating and not unconditional \
                    and not any(is_init(w) for w in writes):
                self._emit(
                    "kernel-scratch", kline,
                    f"scratch accumulator `{s}` has no initialization "
                    f"under pl.when(<inner grid dim {inner}> == 0) — "
                    "stale VMEM from the previous output block leaks "
                    "into the accumulation (interpret mode zero-fills, "
                    "hardware does not)")
            for w in writes:
                if is_init(w) or w.aug:
                    continue
                if not body.refs_any(w.rhs, scratch_names):
                    self._emit(
                        "kernel-scratch", self.m.abs_line(w.node),
                        f"scratch `{s}` overwritten without carrying any "
                        "accumulator state — prior grid steps' "
                        "contribution is dropped")
        if scratch_names:
            flushes = [w for w in self.writes_to_outputs(body, out_names)
                       if body.refs_any(w.rhs, scratch_names)]
            if not flushes:
                self._emit(
                    "kernel-scratch", kline,
                    "VMEM scratch accumulator is never flushed to an "
                    "output ref — results stay in scratch")
            elif not any(not w.guards or is_flush(w) for w in flushes):
                self._emit(
                    "kernel-scratch", kline,
                    f"scratch is flushed to an output only under a guard "
                    f"that is not pl.when(<inner grid dim {inner}> == "
                    "n-1) — the final accumulated value never reaches "
                    "the output block")

        # output refs: default writes + revisit accumulation discipline
        for oi, spec in enumerate(self.m.out_specs):
            name = out_names[oi] if oi < len(out_names) else spec.name
            writes = body.writes_to(name)
            if not writes:
                self._emit("kernel-scratch", kline,
                           f"output ref `{name}` is never written in the "
                           "kernel body — the output block is garbage "
                           "VMEM")
                continue
            if not any(not w.guards or pid_only(w) for w in writes):
                self._emit(
                    "kernel-scratch", kline,
                    f"output ref `{name}` is written only under "
                    "data-dependent pl.when guards — blocks whose guard "
                    "is false keep garbage VMEM (interpret mode "
                    "zero-fills, hardware does not)")
            revisited = bool(self.revisit_dims(oi))
            if revisited:
                accumulating = any(
                    w.aug or body.refs_any(w.rhs, {name})
                    for w in writes if not is_init(w))
                if accumulating and not any(is_init(w) for w in writes):
                    self._emit(
                        "kernel-scratch", kline,
                        f"revisited output `{name}` accumulates without "
                        f"initialization under pl.when(<inner grid dim "
                        f"{inner}> == 0)")
                for w in writes:
                    if is_init(w) or is_flush(w) or w.aug:
                        continue
                    if not body.refs_any(w.rhs, scratch_names | {name}):
                        self._emit(
                            "kernel-scratch", self.m.abs_line(w.node),
                            f"revisited output `{name}` overwritten "
                            "without carrying the previous value — "
                            "prior grid steps' contribution is dropped")

    def writes_to_outputs(self, body: KernelBody,
                          out_names: List[str]) -> List[_Write]:
        return [w for w in body.writes if w.ref in out_names]

    # ------------------------------------------------------------- dtype --
    def check_dtype(self, body: KernelBody):
        for call in body.dot_calls():
            kws = {kw.arg for kw in call.keywords}
            if "preferred_element_type" not in kws:
                self._emit(
                    "kernel-dtype", self.m.abs_line(call),
                    "in-kernel jnp.dot without preferred_element_type — "
                    "the MXU accumulates bf16 inputs at reduced "
                    "precision unless f32 is requested explicitly")
        for shape, dtype in self.m.scratch:
            if np.dtype(dtype) != np.float32:
                self._emit(
                    "kernel-dtype", self.m.line,
                    f"VMEM scratch accumulator dtype {np.dtype(dtype)} — "
                    "accumulators must be float32")
        out_names = [p for p, r in (body.roles or {}).items()
                     if r == "output"]
        for oi, spec in enumerate(self.m.out_specs):
            if not self.revisit_dims(oi):
                continue
            name = out_names[oi] if oi < len(out_names) else spec.name
            writes = body.writes_to(name)
            accumulating = any(
                w.aug or body.refs_any(w.rhs, {name}) for w in writes
                if not any(g[0] == "init" for g in w.guards))
            if accumulating and np.dtype(spec.dtype) != np.float32:
                self._emit(
                    "kernel-dtype", self.m.line,
                    f"revisited output `{name}` is accumulated across "
                    f"grid steps in {np.dtype(spec.dtype)} — accumulate "
                    "in an f32 VMEM scratch and cast once at the flush")

    # -------------------------------------------------------------- vmem --
    def check_vmem(self):
        fp = self.m.vmem_footprint()
        if fp["total_bytes"] > self.budget:
            self._emit(
                "kernel-vmem", self.m.line,
                f"per-grid-step VMEM footprint {fp['total_bytes']} B "
                f"(2x({fp['in_bytes']} in + {fp['out_bytes']} out) + "
                f"{fp['scratch_bytes']} scratch) exceeds the per-core "
                f"budget {self.budget} B for case `{self.m.case}`")


def verify_model(model: KernelModel,
                 vmem_budget: int = VMEM_BUDGET_BYTES
                 ) -> List[KernelFinding]:
    return Verifier(model, vmem_budget).run()


def verify_models(models: Sequence[KernelModel],
                  vmem_budget: int = VMEM_BUDGET_BYTES
                  ) -> List[KernelFinding]:
    """Verify many models (e.g. one per shape case), deduplicating
    identical findings that recur across cases."""
    seen: Set[Tuple] = set()
    out: List[KernelFinding] = []
    for m in models:
        for f in verify_model(m, vmem_budget):
            key = (f.rule, f.path, f.line, f.message)
            if key not in seen:
                seen.add(key)
                out.append(f)
    return out


# ------------------------------------------------------- mutation helpers --
# Used by the negative suite: perturb a captured model the way a buggy
# kernel edit would, then assert the matching rule catches it.

def shift_index_map(model: KernelModel, spec_idx: int, dim: int,
                    delta: int = 1) -> KernelModel:
    """Return a model whose `spec_idx`-th in_spec index map is shifted by
    `delta` blocks along `dim` (an off-by-one gather: OOB)."""
    m = dataclasses.replace(model)
    m.in_specs = list(model.in_specs)
    spec = model.in_specs[spec_idx]
    orig = spec.index_map

    def shifted(*args):
        c = orig(*args)
        c = (c,) if not isinstance(c, tuple) else c
        return tuple(ci + delta if d == dim else ci
                     for d, ci in enumerate(c))

    m.in_specs[spec_idx] = dataclasses.replace(spec, index_map=shifted)
    return m


def swap_grid_order(model: KernelModel) -> KernelModel:
    """Return a model with the grid dimensions reversed (index maps see
    the original coordinate order): output revisits that were contiguous
    in the innermost dim become strided — the TPU revisit race."""
    n = len(model.grid)
    perm = tuple(reversed(range(n)))
    m = dataclasses.replace(model)
    m.grid = tuple(model.grid[p] for p in perm)

    def rewire(spec: SpecModel) -> SpecModel:
        orig = spec.index_map

        def remapped(*args):
            g, rest = args[:n], args[n:]
            back = tuple(g[perm.index(d)] for d in range(n))
            return orig(*back, *rest)

        return dataclasses.replace(spec, index_map=remapped)

    m.in_specs = [rewire(s) for s in model.in_specs]
    m.out_specs = [rewire(s) for s in model.out_specs]
    return m


class _DropWhenBlock(ast.NodeTransformer):
    """Remove inner defs decorated with pl.when(pred) matching `match`."""

    def __init__(self, match: Callable[[ast.AST], bool]):
        self.match = match
        self.dropped = 0

    def visit_FunctionDef(self, node: ast.FunctionDef):
        kept = []
        for stmt in node.body:
            if isinstance(stmt, ast.FunctionDef):
                preds = [d.args[0] for d in stmt.decorator_list
                         if isinstance(d, ast.Call)
                         and isinstance(d.func, ast.Attribute)
                         and d.func.attr == "when" and d.args]
                if preds and any(self.match(p) for p in preds):
                    self.dropped += 1
                    continue
            kept.append(stmt)
        node.body = kept
        self.generic_visit(node)
        return node


def mutate_kernel_ast(model: KernelModel,
                      transform: ast.NodeTransformer) -> KernelModel:
    """Return a model whose kernel AST went through `transform` (deep
    copy; the original model is untouched)."""
    import copy
    m = dataclasses.replace(model)
    tree = copy.deepcopy(model.kernel_ast)
    tree = transform.visit(tree)
    ast.fix_missing_locations(tree)
    m.kernel_ast = tree
    return m


def drop_when_block(model: KernelModel, kind: str,
                    dim: Optional[int] = None) -> KernelModel:
    """Drop the pl.when(<pid(dim)> == 0) init block (kind='init') or the
    pl.when(<pid> == n-1) flush block (kind='flush') or every
    data-dependent guard block (kind='data') from the kernel AST."""
    probe = KernelBody(model)

    def match(pred: ast.AST) -> bool:
        g = probe.classify_guard(pred)
        if g[0] != kind:
            return False
        return dim is None or g[1] == dim

    t = _DropWhenBlock(match)
    mutated = mutate_kernel_ast(model, t)
    if t.dropped == 0:
        raise ValueError(f"no pl.when block of kind {kind!r} to drop in "
                         f"{model.kernel_name}")
    return mutated


class _StripDotKwarg(ast.NodeTransformer):
    def visit_Call(self, node: ast.Call):
        self.generic_visit(node)
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("dot", "dot_general"):
            node.keywords = [k for k in node.keywords
                             if k.arg != "preferred_element_type"]
        return node


def strip_preferred_element_type(model: KernelModel) -> KernelModel:
    return mutate_kernel_ast(model, _StripDotKwarg())


class _BreakCarry(ast.NodeTransformer):
    """Rewrite `ref[...] = <rhs>` / `ref[...] += <rhs>` into a plain
    overwrite that drops the accumulator state."""

    def __init__(self, ref: str, replacement: ast.AST):
        self.ref = ref
        self.replacement = replacement

    def _hit(self, t) -> bool:
        return isinstance(t, ast.Subscript) \
            and isinstance(t.value, ast.Name) and t.value.id == self.ref

    def visit_AugAssign(self, node: ast.AugAssign):
        if self._hit(node.target):
            return ast.copy_location(
                ast.Assign(targets=[node.target], value=self.replacement),
                node)
        return node

    def visit_Assign(self, node: ast.Assign):
        if any(self._hit(t) for t in node.targets):
            return ast.copy_location(
                ast.Assign(targets=node.targets, value=self.replacement),
                node)
        return node


def break_carry(model: KernelModel, ref: str) -> KernelModel:
    """Every write to `ref` becomes `ref[...] = <fresh zeros-like rhs not
    referencing any scratch>` — the carry-correction mutation."""
    repl = ast.parse("__fresh__", mode="eval").body
    probe = KernelBody(model)

    class _T(_BreakCarry):
        def visit_FunctionDef(self, node):
            self.generic_visit(node)
            return node

    t = _T(ref, repl)
    mutated = mutate_kernel_ast(model, t)
    # only non-init writes should lose their carry: re-add an init write
    # is unnecessary for the negative test (the carry rule fires first)
    del probe
    return mutated
