"""JAX-aware lint rules over the `callgraph` analysis.

Rule catalog (waive a finding with ``# lint: allow-<rule>`` on the finding
line or the line above, with a reason):

* ``host-sync``     — host-synchronizing primitives (``.item()``,
  ``.tolist()``, ``.block_until_ready()``, ``np.asarray``/``np.array``,
  ``jax.device_get``, ``float()``/``int()`` on a tracer) inside functions
  reachable from ``jax.jit``/``lax.scan``/``pallas_call``; the same
  primitives anywhere in the hot-path driver modules (``core/backend.py``,
  ``core/engine.py``, ``serving/cache.py``, ``kernels/``) need an explicit
  waiver — every un-waived device->host sync there is a latency bug.
* ``jit-spec``      — a ``jax.jit`` in a hot-path module that declares
  neither ``static_argnums``/``static_argnames`` nor ``donate_argnums``;
  the spec must be explicit (an empty tuple is an explicit "none").
* ``donated-reuse`` — a buffer passed in a donated argument position of a
  jit'd callable is read again in the caller before being rebound.
* ``bare-assert``   — ``assert`` in library code (stripped under
  ``python -O``; invariants must raise).
* ``pallas-oracle`` — a ``pl.pallas_call`` wrapper without a matching
  ``<name>_ref`` oracle in ``kernels/ref.py``, with a positional signature
  drifted from its oracle, missing ``out_shape``, or with an out dtype that
  is neither input-derived nor the f32 accumulator convention.
* ``tracer-if``     — Python ``if``/``while`` on a traced value inside
  traced code (silent concretization error or retrace trap). Static
  extractors (``x.shape``, ``len()``, ``is None``, config keys) are
  exempt.
* ``kernel-race`` / ``kernel-bounds`` / ``kernel-scratch`` /
  ``kernel-dtype`` / ``kernel-vmem`` — static Pallas kernel verification
  (grid/BlockSpec coverage & revisit contiguity, index_map bounds and
  clamp/guard pairing, scratch init/flush/carry discipline, accumulator
  dtypes, per-step VMEM budget). Implemented in
  ``repro.analysis.kernel_verify`` over the symbolic models extracted by
  ``repro.analysis.kernel_model``; same waiver syntax as every other
  rule. ``tools/kverify.py`` runs the same checks standalone and prints
  the per-config VMEM footprint table.

The linter also audits waivers themselves: a ``# lint: allow-<rule>``
comment that matched no finding in this run is reported by
``Linter.unused_waivers()`` (CLI: ``tools/lint.py --strict-waivers``) —
stale waivers hide regressions.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import callgraph as cg

HOT_MODULES = ("repro.core.backend", "repro.core.engine",
               "repro.serving.cache")
HOT_PREFIXES = ("repro.kernels.",)
JIT_SPEC_PREFIXES = ("repro.core.", "repro.kernels.")
SYNC_ATTRS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}
SYNC_FQS = {"numpy.asarray", "numpy.array", "jax.device_get"}
JIT_SPEC_KWARGS = {"static_argnums", "static_argnames", "donate_argnums",
                   "donate_argnames"}
WAIVER_RE = re.compile(r"#\s*lint:\s*allow-([a-z-]+)")


@dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.message}")


def _is_hot(fq: str) -> bool:
    return fq in HOT_MODULES or fq.startswith(HOT_PREFIXES)


def _own_nodes(root: ast.AST):
    """Walk `root` without descending into nested function/class defs."""
    stack = list(ast.iter_child_nodes(root))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _norm(expr: ast.AST) -> Optional[str]:
    """Normalize a Name/Attribute/Subscript chain to a comparable string
    (subscript keys collapse to ``[*]``)."""
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        base = _norm(expr.value)
        return f"{base}.{expr.attr}" if base else None
    if isinstance(expr, ast.Subscript):
        base = _norm(expr.value)
        return f"{base}[*]" if base else None
    return None


class Linter:
    def __init__(self, src_root: str, package: str = "repro"):
        self.project = cg.Project.load(src_root, package)
        self.analysis = cg.analyze(self.project)
        self.findings: List[Finding] = []
        self.waived: List[Finding] = []
        # (path, 1-based line) of every waiver comment that matched a
        # finding — the complement is reported by unused_waivers()
        self.used_waiver_lines: Set[Tuple[str, int]] = set()

    # ------------------------------------------------------------ helpers --
    def _emit(self, mod: cg.ModuleInfo, node: ast.AST, rule: str,
              message: str):
        f = Finding(path=mod.path, line=getattr(node, "lineno", 0),
                    col=getattr(node, "col_offset", 0), rule=rule,
                    message=message)
        # a waiver covers its own line, and a finding is waived by a marker
        # anywhere in the contiguous comment block immediately above it
        ln = f.line - 1
        if 0 <= ln < len(mod.lines):
            m = WAIVER_RE.search(mod.lines[ln])
            if m and m.group(1) == rule:
                self.used_waiver_lines.add((mod.path, ln + 1))
                self.waived.append(f)
                return
        ln -= 1
        while 0 <= ln < len(mod.lines) \
                and mod.lines[ln].lstrip().startswith("#"):
            m = WAIVER_RE.search(mod.lines[ln])
            if m and m.group(1) == rule:
                self.used_waiver_lines.add((mod.path, ln + 1))
                self.waived.append(f)
                return
            ln -= 1
        self.findings.append(f)

    def _tr(self, f: cg.FuncInfo) -> cg.Tracedness:
        return cg.Tracedness(self.project, f.module, f,
                             self.analysis.summaries)

    def _func_of_node(self, mod: cg.ModuleInfo,
                      node: ast.AST) -> Optional[cg.FuncInfo]:
        for fi in mod.funcs.values():
            if fi.node is node:
                return fi
        return None

    # -------------------------------------------------------------- rules --
    def run(self) -> List[Finding]:
        self.rule_bare_assert()
        self.rule_host_sync()
        self.rule_jit_spec()
        self.rule_donated_reuse()
        self.rule_pallas_oracle()
        self.rule_tracer_if()
        self.rule_kernel_static()
        self.findings.sort(key=lambda f: (f.path, f.line, f.col))
        return self.findings

    def rule_kernel_static(self):
        """Static Pallas kernel verification (kernel-* rules): extract the
        symbolic model of every pallas_call under kernels/ at a
        representative config shape and run the race/bounds/scratch/dtype/
        vmem checks. Imported lazily — model extraction traces the kernel
        wrappers, which needs jax."""
        import os
        from repro.analysis import kernel_model, kernel_verify
        by_path = {os.path.abspath(m.path): m
                   for m in self.project.modules.values()}
        models = kernel_model.lint_models()
        for kf in kernel_verify.verify_models(models):
            mod = by_path.get(os.path.abspath(kf.path))
            if mod is None:
                continue
            node = ast.Pass(lineno=kf.line, col_offset=0)
            self._emit(mod, node, kf.rule, f"{kf.kernel}: {kf.message}")

    def unused_waivers(self) -> List[Finding]:
        """Waiver comments that matched no finding in this run. Only real
        COMMENT tokens count (the rule-catalog docstring above mentions the
        marker syntax without being a waiver). Call after run()."""
        import io
        import tokenize
        out: List[Finding] = []
        for mod in self.project.modules.values():
            src = "\n".join(mod.lines)
            try:
                toks = list(tokenize.generate_tokens(
                    io.StringIO(src).readline))
            except tokenize.TokenizeError:
                continue
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                m = WAIVER_RE.search(tok.string)
                if m is None:
                    continue
                line = tok.start[0]
                if (mod.path, line) in self.used_waiver_lines:
                    continue
                out.append(Finding(
                    path=mod.path, line=line, col=tok.start[1],
                    rule="unused-waiver",
                    message=f"waiver `allow-{m.group(1)}` matched no "
                            "finding in this run — stale waivers hide "
                            "regressions; remove it or fix the marker"))
        out.sort(key=lambda f: (f.path, f.line, f.col))
        return out

    def rule_bare_assert(self):
        for mod in self.project.modules.values():
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Assert):
                    self._emit(mod, node, "bare-assert",
                               "bare assert in library code (stripped "
                               "under python -O) — raise ValueError/"
                               "RuntimeError instead")

    def _sync_call_kind(self, mod: cg.ModuleInfo,
                        node: ast.Call) -> Optional[str]:
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in SYNC_ATTRS:
            return f".{node.func.attr}()"
        fq = self.project.external_fq(mod, node.func)
        if fq in SYNC_FQS:
            return fq
        return None

    def rule_host_sync(self):
        flagged: Set[Tuple[str, int, int]] = set()
        # tier a: inside traced code
        for f, fa in self.analysis.info.items():
            mod = f.module
            if not mod.fq.startswith("repro."):
                continue
            tr = self._tr(f)
            for node in _own_nodes(f.node):
                if not isinstance(node, ast.Call):
                    continue
                kind = self._sync_call_kind(mod, node)
                if kind is None and isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool") and \
                        any(tr.expr(a, fa.traced_names) for a in node.args):
                    kind = f"{node.func.id}() on a traced value"
                if kind is not None:
                    key = (mod.path, node.lineno, node.col_offset)
                    flagged.add(key)
                    self._emit(mod, node, "host-sync",
                               f"{kind} inside jit-traced code "
                               f"(in {f.qname.rsplit('.', 1)[-1]}, "
                               "reachable from a jit/scan/pallas entry)")
        # tier b: anywhere in hot-path driver modules
        for mod in self.project.modules.values():
            if not _is_hot(mod.fq):
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                key = (mod.path, node.lineno, node.col_offset)
                if key in flagged:
                    continue
                kind = self._sync_call_kind(mod, node)
                if kind is not None:
                    self._emit(mod, node, "host-sync",
                               f"{kind} in hot-path module {mod.fq} — "
                               "device->host sync; waive with a reason if "
                               "this transfer is intentional")

    def rule_jit_spec(self):
        for mod in self.project.modules.values():
            if not mod.fq.startswith(JIT_SPEC_PREFIXES):
                continue
            for node in ast.walk(mod.tree):
                jit_call = None
                if isinstance(node, ast.Call) and \
                        self.project.external_fq(mod, node.func) == \
                        "jax.jit":
                    jit_call = node
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        if self.project.external_fq(mod, dec) == "jax.jit":
                            self._emit(mod, dec, "jit-spec",
                                       "bare @jax.jit in hot-path module — "
                                       "declare static_argnames/"
                                       "donate_argnums explicitly")
                if jit_call is None:
                    continue
                if not any(kw.arg in JIT_SPEC_KWARGS
                           for kw in jit_call.keywords):
                    self._emit(mod, jit_call, "jit-spec",
                               "jax.jit without an explicit static/donate "
                               "spec in hot-path module — declare "
                               "static_argnames/static_argnums/"
                               "donate_argnums (an explicit empty tuple "
                               "documents 'none')")

    # -- donated-reuse ------------------------------------------------------
    def _donated_bindings(self, mod: cg.ModuleInfo) -> Dict[str, List[int]]:
        """Map normalized assign-target -> donate_argnums of the jit bound
        to it (conditional bindings take the union of both branches)."""
        out: Dict[str, List[int]] = {}

        def jit_donates(expr: ast.AST) -> List[int]:
            donates: List[int] = []
            for n in ast.walk(expr):
                if isinstance(n, ast.Call) and \
                        self.project.external_fq(mod, n.func) == "jax.jit":
                    for kw in n.keywords:
                        if kw.arg == "donate_argnums":
                            vals = cg._const_tuple(kw.value) or []
                            donates += [v for v in vals
                                        if isinstance(v, int)]
            return donates

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign) or node.value is None:
                continue
            donates = jit_donates(node.value)
            if not donates:
                continue
            for t in node.targets:
                key = _norm(t)
                if key:
                    out.setdefault(key, [])
                    out[key] = sorted(set(out[key]) | set(donates))
        return out

    def rule_donated_reuse(self):
        for mod in self.project.modules.values():
            if not mod.fq.startswith("repro."):
                continue
            bindings = self._donated_bindings(mod)
            if not bindings:
                continue
            for f in mod.funcs.values():
                self._donated_reuse_in(mod, f, bindings)

    def _donated_reuse_in(self, mod: cg.ModuleInfo, f: cg.FuncInfo,
                          bindings: Dict[str, List[int]]):
        # local tuple literals, for `fn(*args)` expansion
        tuple_lits: Dict[str, List[ast.expr]] = {}
        for node in _own_nodes(f.node):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Tuple) and \
                    len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                tuple_lits[node.targets[0].id] = list(node.value.elts)

        parent: Dict[ast.AST, ast.AST] = {}
        for p in ast.walk(f.node):
            for c in ast.iter_child_nodes(p):
                parent[c] = p

        calls: List[Tuple[ast.Call, List[ast.expr]]] = []
        for node in _own_nodes(f.node):
            if not isinstance(node, ast.Call):
                continue
            key = _norm(node.func)
            if key is None or key not in bindings:
                continue
            args: List[ast.expr] = []
            ok = True
            for a in node.args:
                if isinstance(a, ast.Starred):
                    if isinstance(a.value, ast.Name) and \
                            a.value.id in tuple_lits:
                        args.extend(tuple_lits[a.value.id])
                    else:
                        ok = False
                        break
                else:
                    args.append(a)
            if not ok:
                continue
            donated = [args[i] for i in bindings[key] if i < len(args)]
            calls.append((node, donated))

        if not calls:
            return

        # events: (line, col, kind, normalized name, node)
        events: List[Tuple[int, int, int, str, ast.AST]] = []
        for node in _own_nodes(f.node):
            if isinstance(node, (ast.Name, ast.Attribute)) and \
                    isinstance(getattr(node, "ctx", None), ast.Load):
                nm = _norm(node)
                if nm:
                    events.append((node.lineno, node.col_offset, 0, nm,
                                   node))
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, (ast.Name, ast.Attribute)):
                            nm = _norm(sub)
                            if nm:
                                events.append((node.lineno,
                                               node.col_offset, 1, nm,
                                               node))
        events.sort(key=lambda e: (e[0], e[1], e[2]))

        for call, donated in calls:
            end = (getattr(call, "end_lineno", call.lineno),
                   getattr(call, "end_col_offset", call.col_offset))
            # rebinding by the assignment the call itself feeds
            stmt = parent.get(call)
            while stmt is not None and not isinstance(stmt, ast.stmt):
                stmt = parent.get(stmt)
            rebound_now: Set[str] = set()
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    for sub in ast.walk(t):
                        nm = _norm(sub)
                        if nm:
                            rebound_now.add(nm)
            for d in donated:
                nm = _norm(d)
                if nm is None or nm in rebound_now:
                    continue
                for line, col, kind, name, node in events:
                    if (line, col) <= end:
                        continue
                    if name != nm:
                        continue
                    if kind == 1:       # rebound before any read
                        break
                    self._emit(mod, node, "donated-reuse",
                               f"`{nm}` is read after being donated to "
                               f"`{_norm(call.func)}` (donate_argnums) at "
                               f"line {call.lineno} — donated buffers are "
                               "invalidated by XLA")
                    break

    # -- pallas-oracle ------------------------------------------------------
    def rule_pallas_oracle(self):
        ref_mod = self.project.modules.get("repro.kernels.ref")
        for mod in self.project.modules.values():
            if not mod.fq.startswith("repro.kernels.") or \
                    mod.fq == "repro.kernels.ref":
                continue
            for f in mod.funcs.values():
                if f.parent is not None or f.cls_name is not None:
                    continue
                pcalls = [n for n in _own_nodes(f.node)
                          if isinstance(n, ast.Call)
                          and self.project.is_entry(mod, n.func) ==
                          "jax.experimental.pallas.pallas_call"]
                if not pcalls:
                    continue
                self._check_oracle(mod, f, pcalls, ref_mod)

    def _check_oracle(self, mod: cg.ModuleInfo, f: cg.FuncInfo,
                      pcalls: List[ast.Call],
                      ref_mod: Optional[cg.ModuleInfo]):
        oracle_name = f"{f.node.name}_ref"
        oracle = ref_mod.funcs.get(oracle_name) if ref_mod else None
        if oracle is None:
            self._emit(mod, f.node, "pallas-oracle",
                       f"pallas_call wrapper `{f.node.name}` has no "
                       f"`{oracle_name}` oracle in kernels/ref.py")
        else:
            want = [p for p in f.required_pos_params if p != "self"]
            got = [p for p in oracle.required_pos_params]
            if want != got:
                self._emit(mod, f.node, "pallas-oracle",
                           f"`{f.node.name}` positional signature {want} "
                           f"drifted from oracle `{oracle_name}` {got}")
        for call in pcalls:
            out_shape = next((kw.value for kw in call.keywords
                              if kw.arg == "out_shape"), None)
            if out_shape is None:
                self._emit(mod, call, "pallas-oracle",
                           f"pallas_call in `{f.node.name}` passes no "
                           "explicit out_shape=")
                continue
            self._check_out_dtype(mod, f, call, out_shape)

    def _check_out_dtype(self, mod: cg.ModuleInfo, f: cg.FuncInfo,
                         call: ast.Call, out_shape: ast.AST):
        # names assigned from a `.dtype`-derived expression in this wrapper
        derived: Set[str] = set(f.all_params)
        for node in _own_nodes(f.node):
            if isinstance(node, ast.Assign):
                src_ok = any(
                    isinstance(s, ast.Attribute) and s.attr == "dtype"
                    for s in ast.walk(node.value)) or any(
                    isinstance(s, ast.Name) and s.id in derived
                    for s in ast.walk(node.value))
                if src_ok:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            derived.add(t.id)

        for n in ast.walk(out_shape):
            if not (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "ShapeDtypeStruct"):
                continue
            dtype_arg = None
            if len(n.args) >= 2:
                dtype_arg = n.args[1]
            for kw in n.keywords:
                if kw.arg == "dtype":
                    dtype_arg = kw.value
            if dtype_arg is None:
                continue
            ok = False
            if any(isinstance(s, ast.Attribute) and s.attr == "dtype"
                   for s in ast.walk(dtype_arg)):
                ok = True
            elif isinstance(dtype_arg, ast.Name) and \
                    dtype_arg.id in derived:
                ok = True
            else:
                fq = self.project.external_fq(mod, dtype_arg)
                # f32 accumulator convention matches the jnp oracles
                if fq is not None and fq.endswith(".float32"):
                    ok = True
            if not ok:
                self._emit(mod, dtype_arg, "pallas-oracle",
                           f"out_shape dtype in `{f.node.name}` is neither "
                           "derived from an input (`x.dtype`) nor the f32 "
                           "accumulator convention — oracle agreement "
                           "cannot hold across input dtypes")

    def rule_tracer_if(self):
        for f, fa in self.analysis.info.items():
            mod = f.module
            if not mod.fq.startswith("repro."):
                continue
            tr = self._tr(f)
            for node in _own_nodes(f.node):
                if isinstance(node, (ast.If, ast.While)) and \
                        tr.expr(node.test, fa.traced_names):
                    kind = "while" if isinstance(node, ast.While) else "if"
                    self._emit(mod, node, "tracer-if",
                               f"python `{kind}` on a traced value in "
                               f"`{f.qname.rsplit('.', 1)[-1]}` — inside "
                               "jit this concretizes (error) or forces a "
                               "retrace; use jnp.where/lax.cond or mark "
                               "the argument static")


@dataclass
class LintReport:
    findings: List[Finding]
    waived: List[Finding]
    unused_waivers: List[Finding]

    def to_dict(self) -> dict:
        def rows(fs: List[Finding]) -> List[dict]:
            return [{"path": f.path, "line": f.line, "col": f.col,
                     "rule": f.rule, "message": f.message} for f in fs]

        return {"findings": rows(self.findings),
                "waived": rows(self.waived),
                "unused_waivers": rows(self.unused_waivers)}


def run_lint_report(src_root: str,
                    targets: Optional[Sequence[str]] = None) -> LintReport:
    """Lint the package rooted at `src_root`; restrict *reporting* to files
    under `targets` (analysis is always whole-package)."""
    linter = Linter(src_root)
    findings = linter.run()
    waived = linter.waived
    unused = linter.unused_waivers()
    if targets:
        import os
        roots = [os.path.abspath(t) for t in targets]

        def keep(f: Finding) -> bool:
            p = os.path.abspath(f.path)
            return any(p == r or p.startswith(r + os.sep) for r in roots)

        findings = [f for f in findings if keep(f)]
        waived = [f for f in waived if keep(f)]
        unused = [f for f in unused if keep(f)]
    return LintReport(findings=findings, waived=waived,
                      unused_waivers=unused)


def run_lint(src_root: str,
             targets: Optional[Sequence[str]] = None
             ) -> Tuple[List[Finding], List[Finding]]:
    """Back-compat wrapper over :func:`run_lint_report`: returns
    (findings, waived)."""
    report = run_lint_report(src_root, targets)
    return report.findings, report.waived
