"""Env-gated runtime sanitizers for the serving hot paths.

Enable with ``REPRO_SANITIZE=1`` (or `force(True)` in tests). All hooks are
installed at construction time of the instrumented objects — when disabled,
the production code carries a ``None`` attribute and a falsy branch, nothing
else.

* **PageSan** — shadow ownership map over ``serving.cache.PageAllocator``.
  Detects double-claim (a page handed out while the shadow map says it is
  live), double-free (freeing a page the shadow map says is dead — even if
  the allocator's own book-keeping was corrupted back to "owned"),
  use-after-free (touching a freed page before re-claim; freed pages are
  *quarantined* — kept out of the free list until capacity pressure — so
  stale block-table entries keep pointing at dead pages long enough to be
  caught), and KV/adapter aliasing (a page reached through a KV block table
  while owned by an adapter, or vice versa). Quarantine is capacity-neutral:
  ``free_pages`` counts quarantined pages and ``claim`` recycles them
  (oldest first) under pressure, so allocator-visible accounting is
  identical with and without the sanitizer.

* **LinkSan** — happens-before checker over ``core.cold_start.LoadTracker``.
  Asserts the scheduled link's invariants after every mutation: queued
  uploads carry a self-consistent provisional schedule, started uploads are
  frozen (start/finish never move once a lane took them), retired finish
  times are monotone non-decreasing (globally, hence per class), and under
  the ``preempt`` policy a manager-mediated demand upload is never delayed
  behind queued speculative prefetch (the ``demand_delayed_by_prefetch``
  counter must not move, and no queued prefetch may survive the begin).
  The failure plane (``core/faults.py``) adds two retry-aware
  happens-before rules: a retried upload must be *requested* after — and
  retire strictly past — the failed attempt's finish, and an upload
  canceled by a crash (or failed outright) must never retire.

`retrace.RetraceSan` (jit retrace detector) lives in its own module to stay
importable without the allocator/link vocabulary.
"""
from __future__ import annotations

import contextlib
import os
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

_EPS = 1e-6

_FORCED: Optional[bool] = None


def enabled() -> bool:
    """True when the sanitizers should be active (REPRO_SANITIZE=1, or a
    `force(...)` override in tests)."""
    if _FORCED is not None:
        return _FORCED
    return os.environ.get("REPRO_SANITIZE", "") == "1"


@contextlib.contextmanager
def force(on: bool):
    """Override the env gate for the duration of a test block."""
    global _FORCED
    prev = _FORCED
    _FORCED = on
    try:
        yield
    finally:
        _FORCED = prev


class SanitizerError(RuntimeError):
    """Base class for every sanitizer violation."""


class PageSanError(SanitizerError, ValueError):
    """Also a ValueError: the allocator's own double-free check raises
    ValueError, and enabling the sanitizer must sharpen the diagnostic
    without changing the exception contract callers rely on."""


class LinkSanError(SanitizerError):
    pass


# ------------------------------------------------------------- PageSan ----

class PageSan:
    """Shadow ownership map + quarantine for one `PageAllocator`."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.owner: Dict[int, str] = {}
        self.freed_by: Dict[int, str] = {}      # tombstones: page -> owner
        self.quarantine: Deque[int] = deque()
        self.claims = 0
        self.frees = 0
        self.access_checks = 0

    # -- allocator hooks ----------------------------------------------------
    def on_claim(self, ids: Iterable[int], owner: str) -> None:
        for i in ids:
            if i in self.owner:
                raise PageSanError(
                    f"PageSan: double-claim of page {i} for '{owner}' — "
                    f"shadow map says it is live under "
                    f"'{self.owner[i]}'")
            self.owner[i] = owner
            self.freed_by.pop(i, None)
        self.claims += 1

    def pre_free(self, ids: Iterable[int]) -> None:
        for i in ids:
            if i not in self.owner:
                was = self.freed_by.get(i)
                detail = (f" (already freed by '{was}')" if was is not None
                          else " (never claimed)")
                raise PageSanError(
                    f"PageSan: double-free of page {i}{detail}")

    def on_free(self, ids: Iterable[int]) -> None:
        for i in ids:
            self.freed_by[i] = self.owner.pop(i)
            self.quarantine.append(i)
        self.frees += 1

    def take_quarantined(self, n: int) -> List[int]:
        """Recycle up to `n` quarantined pages, oldest first (capacity
        pressure — the allocator's free list ran short)."""
        out = []
        while self.quarantine and len(out) < n:
            out.append(self.quarantine.popleft())
        return out

    # -- access checks ------------------------------------------------------
    def check_access(self, ids: Iterable[int], expect_prefix: Optional[str],
                     op: str) -> None:
        """Validate that every (non-negative) page id touched by `op` is
        live, and owned under `expect_prefix` (``"kv:"`` / ``"adapter:"``)
        when given."""
        self.access_checks += 1
        for i in ids:
            i = int(i)
            if i < 0:
                continue
            o = self.owner.get(i)
            if o is None:
                was = self.freed_by.get(i)
                if was is not None:
                    raise PageSanError(
                        f"PageSan: use-after-free — {op} touched page {i}, "
                        f"freed while owned by '{was}'")
                raise PageSanError(
                    f"PageSan: {op} touched unclaimed page {i}")
            if expect_prefix is not None and not o.startswith(expect_prefix):
                raise PageSanError(
                    f"PageSan: aliasing — {op} expected a "
                    f"'{expect_prefix}' page but page {i} is owned by "
                    f"'{o}'")


# ------------------------------------------------------------- LinkSan ----

class LinkSan:
    """Happens-before checker over one `LoadTracker`."""

    def __init__(self):
        self._frozen: Dict[int, Tuple[float, float]] = {}   # seq -> (s, f)
        self._last_retired: float = float("-inf")
        self._last_retired_cls: Dict[int, float] = {}
        # failure plane: seqs that must never retire, and per-retry floors
        # (the failed attempt's finish the retry must move strictly past)
        self._dead: set = set()
        self._retry_floor: Dict[int, float] = {}
        self.checks = 0

    def on_start(self, ev) -> None:
        """A lane took `ev`: its schedule is final from here on."""
        self._frozen[ev.seq] = (ev.start_ms, ev.finish_ms)

    def check_schedule(self, tracker) -> None:
        """Queued/running split and provisional schedules are consistent."""
        self.checks += 1
        for ev in tracker._queued:
            if ev.started:
                raise LinkSanError(
                    f"LinkSan: started upload '{ev.uid}' (seq {ev.seq}) "
                    "still sits in the queue")
            if ev.start_ms < ev.request_ms - _EPS:
                raise LinkSanError(
                    f"LinkSan: upload '{ev.uid}' scheduled to start at "
                    f"{ev.start_ms:.3f}ms, before its request at "
                    f"{ev.request_ms:.3f}ms")
            want = ev.start_ms + tracker._xfer_ms(ev.nbytes, ev.start_ms)
            if abs(ev.finish_ms - want) > 1e-3:
                raise LinkSanError(
                    f"LinkSan: upload '{ev.uid}' finish {ev.finish_ms:.3f}"
                    f"ms inconsistent with start + transfer "
                    f"({want:.3f}ms)")
        for ev in tracker._running:
            if not ev.started:
                raise LinkSanError(
                    f"LinkSan: un-started upload '{ev.uid}' in the "
                    "running set")
            frozen = self._frozen.get(ev.seq)
            if frozen is not None and (
                    abs(ev.start_ms - frozen[0]) > _EPS
                    or abs(ev.finish_ms - frozen[1]) > _EPS):
                raise LinkSanError(
                    f"LinkSan: started upload '{ev.uid}' moved from "
                    f"{frozen} to ({ev.start_ms}, {ev.finish_ms}) — "
                    "started uploads must never be rescheduled")

    def on_retire(self, ev) -> None:
        """Retired finish times are monotone non-decreasing — globally and
        per priority class — and match the frozen schedule. An upload the
        failure plane killed (crash-canceled or failed) must never come
        back through here, and a retry must retire strictly after the
        attempt it replaces."""
        if ev.canceled or ev.seq in self._dead:
            raise LinkSanError(
                f"LinkSan: canceled/failed upload '{ev.uid}' (seq "
                f"{ev.seq}) retired at {ev.finish_ms:.3f}ms — a killed "
                "upload must never retire")
        floor = self._retry_floor.pop(ev.seq, None)
        if floor is not None and ev.finish_ms <= floor + _EPS:
            raise LinkSanError(
                f"LinkSan: retry '{ev.uid}' (attempt {ev.attempt}) "
                f"retired at {ev.finish_ms:.3f}ms, not strictly after its "
                f"failed attempt's finish at {floor:.3f}ms")
        frozen = self._frozen.pop(ev.seq, None)
        if frozen is not None and abs(ev.finish_ms - frozen[1]) > _EPS:
            raise LinkSanError(
                f"LinkSan: upload '{ev.uid}' retired at {ev.finish_ms:.3f}"
                f"ms but was frozen to finish at {frozen[1]:.3f}ms")
        if ev.finish_ms < self._last_retired - _EPS:
            raise LinkSanError(
                f"LinkSan: upload '{ev.uid}' (class {ev.cls}) retired at "
                f"{ev.finish_ms:.3f}ms after a retirement at "
                f"{self._last_retired:.3f}ms — finish times must be "
                "monotone")
        prev_cls = self._last_retired_cls.get(ev.cls, float("-inf"))
        if ev.finish_ms < prev_cls - _EPS:
            raise LinkSanError(
                f"LinkSan: class-{ev.cls} finish times not monotone "
                f"({ev.finish_ms:.3f}ms after {prev_cls:.3f}ms)")
        self._last_retired = max(self._last_retired, ev.finish_ms)
        self._last_retired_cls[ev.cls] = max(prev_cls, ev.finish_ms)

    def on_fail(self, ev) -> None:
        """A finishing transfer failed: it will never retire (the tracker
        either requeues a *fresh* event or drops it), so its frozen
        schedule is dead and its seq joins the never-retire set."""
        self._frozen.pop(ev.seq, None)
        self._dead.add(ev.seq)

    def on_retry(self, failed, retry) -> None:
        """Happens-before between a failed attempt and its retry: the
        retry must be requested after the failure (backoff > 0), and —
        recorded as a floor checked at retirement — must finish strictly
        past it."""
        if retry.request_ms <= failed.finish_ms + _EPS:
            raise LinkSanError(
                f"LinkSan: retry of '{failed.uid}' requested at "
                f"{retry.request_ms:.3f}ms, not after the failed "
                f"attempt's finish at {failed.finish_ms:.3f}ms")
        if retry.attempt != failed.attempt + 1:
            raise LinkSanError(
                f"LinkSan: retry of '{failed.uid}' carries attempt "
                f"{retry.attempt}, expected {failed.attempt + 1}")
        self._retry_floor[retry.seq] = failed.finish_ms

    def on_cancel(self, events) -> None:
        """A crash aborted these uploads: drop their frozen schedules and
        remember the seqs — a canceled upload must never retire."""
        for ev in events:
            self._frozen.pop(ev.seq, None)
            self._retry_floor.pop(ev.seq, None)
            self._dead.add(ev.seq)

    def on_demand_begin(self, tracker, ev, delayed_before: int) -> None:
        """Manager-mediated demand begin under the `preempt` policy: the
        demand upload must not have been delayed by queued prefetch, and no
        queued prefetch may have survived the preemption."""
        if tracker.policy != "preempt":
            return
        delayed = tracker.stats["demand_delayed_by_prefetch"]
        if delayed > delayed_before:
            raise LinkSanError(
                f"LinkSan: demand upload '{ev.uid}' was delayed behind "
                "queued prefetch under the preempt policy "
                "(demand_delayed_by_prefetch moved "
                f"{delayed_before} -> {delayed})")
        from repro.core.cold_start import CLS_PREFETCH
        survivors = [e.uid for e in tracker._queued
                     if e.cls == CLS_PREFETCH]
        if survivors:
            raise LinkSanError(
                f"LinkSan: queued prefetch {survivors} survived a "
                f"preempt-policy demand begin of '{ev.uid}'")
