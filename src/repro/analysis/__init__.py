"""Correctness tooling plane: JAX-aware static lints and runtime sanitizers.

Two halves (ISSUE 7):

* **Static** — `callgraph` builds a cross-module reachability graph from the
  package ASTs (which functions are traced under `jax.jit` / `lax.scan` /
  `pl.pallas_call`, and which of their parameters are tracers vs static);
  `lint` runs JAX-specific rules over it (host-sync inside traced code,
  undeclared jit static/donate specs, donated-buffer reuse, bare asserts in
  library code, Pallas wrappers without a matching `ref.py` oracle, Python
  `if` on tracer values). CLI: ``python tools/lint.py src/``.

* **Runtime** — `sanitizers` (env-gated, ``REPRO_SANITIZE=1``) wraps the
  serving hot paths with shadow-state checkers: PageSan (page ownership /
  quarantine over `PageAllocator`), LinkSan (happens-before on the cold-start
  link scheduler), and `retrace.RetraceSan` (steady-state jit retrace
  detector). Zero overhead when disabled: production code guards every hook
  on ``sanitizers.enabled()`` at construction time.
"""
from repro.analysis.sanitizers import (  # noqa: F401
    LinkSan,
    LinkSanError,
    PageSan,
    PageSanError,
    SanitizerError,
    enabled,
    force,
)
from repro.analysis.retrace import RetraceError, RetraceSan  # noqa: F401
