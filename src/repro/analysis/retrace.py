"""RetraceSan — steady-state jit retrace detector.

A jitted callable retraces when it sees a new (shape, dtype, static-arg)
signature; in steady-state decode that means an avoidable compile on the
hot path. `RetraceSan.observe(name, fn)` samples ``fn._cache_size()`` after
each dispatch; once `mark_steady()` is called, any growth of a previously
observed callable's cache is recorded as a violation and `assert_clean()`
raises. Warmup retraces (before `mark_steady`) are expected and ignored —
the engine's megastep pipeline traces once per (K, batch-signature) bucket
and must then stay trace-stable.

Hooked into `core.backend.NumericsBackend` behind `sanitizers.enabled()`;
tests drive `mark_steady`/`assert_clean` directly.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.analysis.sanitizers import SanitizerError


class RetraceError(SanitizerError):
    pass


def _cache_size(fn) -> Optional[int]:
    try:
        return int(fn._cache_size())
    except Exception:
        return None


class RetraceSan:
    def __init__(self):
        self._sizes: Dict[str, int] = {}
        self._steady = False
        self.violations: List[str] = []

    def observe(self, name: str, fn) -> None:
        """Record the trace-cache size of `fn` after a dispatch under
        `name`. Growth after `mark_steady()` is a violation."""
        size = _cache_size(fn)
        if size is None:
            return
        prev = self._sizes.get(name)
        if prev is not None and size > prev and self._steady:
            self.violations.append(
                f"{name}: trace cache grew {prev} -> {size} after "
                "steady state")
        self._sizes[name] = size

    def mark_steady(self) -> None:
        """Declare warmup over: every observed callable must now be
        trace-stable."""
        self._steady = True

    def reset(self) -> None:
        self._sizes.clear()
        self._steady = False
        self.violations.clear()

    def assert_clean(self) -> None:
        if self.violations:
            raise RetraceError(
                "RetraceSan: steady-state retrace detected — "
                + "; ".join(self.violations))
