"""AST call graph + tracedness analysis for the JAX-aware lint.

Builds a whole-package view of `src/repro`:

* which functions are *reachable from a trace* — i.e. called (transitively)
  from a function handed to `jax.jit`, `jax.lax.scan`, `pl.pallas_call`,
  `jax.checkpoint`, or passed as a callback inside already-traced code
  (`jax.tree.map`, `lax.cond`, ...);
* which of each reachable function's *parameters are tracers* vs static
  python values (`static_argnums`/`static_argnames`, `functools.partial`
  pre-bound arguments, scalar config objects), propagated through call
  sites to a fixpoint, including per-element tracedness of tuple returns
  (so `mode = lora.get("mode", "bgmv")` unpacked through a helper stays
  static);
* which *local names* inside each reachable function hold tracers, with
  static extractors (`x.shape`, `x.ndim`, `x.dtype`, `len(...)`,
  `isinstance(...)`, `is None` tests) excluded.

The lint rules in `analysis.lint` are thin walks over this structure.
Everything here is plain `ast` — no imports of the linted code.
"""
from __future__ import annotations

import ast
import os
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

# Attribute reads that yield static python values even on a tracer
# ("key"/"idx"/"name" are pytree KeyPath entries — static structure).
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "sharding",
                "key", "idx", "name"}
# Builtin calls whose result is static regardless of argument tracedness.
STATIC_CALLS = {"len", "isinstance", "hasattr", "type", "id", "repr", "str"}
# Builtins whose function-valued arguments are introspected, not called —
# excluded from the passed-as-callback reachability heuristic.
CALLBACK_EXEMPT = STATIC_CALLS | {"getattr", "setattr", "print", "format"}
# Dict keys that carry static configuration through traced containers
# (e.g. the lora pack: `lora["pool"]` is a tracer, `lora["mode"]` is not).
STATIC_KEYS = {"mode", "rank_block", "family", "impl"}

# External callables that put their function-argument under trace.
TRACING_ENTRY_FQS = {
    "jax.jit",
    "jax.lax.scan",
    "jax.lax.cond",
    "jax.lax.switch",
    "jax.lax.while_loop",
    "jax.lax.fori_loop",
    "jax.lax.map",
    "jax.checkpoint",
    "jax.remat",
    "jax.vmap",
    "jax.grad",
    "jax.value_and_grad",
    "jax.eval_shape",
    "jax.experimental.pallas.pallas_call",
}


def _dotted(expr: ast.AST) -> Optional[str]:
    """Flatten `a.b.c` Name/Attribute chains to a dotted string."""
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if not isinstance(expr, ast.Name):
        return None
    parts.append(expr.id)
    return ".".join(reversed(parts))


@dataclass
class FuncInfo:
    module: "ModuleInfo"
    qname: str                       # "repro.core.backend.Cls.meth[.inner]"
    node: Union[ast.FunctionDef, ast.AsyncFunctionDef]
    cls_name: Optional[str] = None
    parent: Optional["FuncInfo"] = None

    def __hash__(self):
        return hash(self.qname)

    def __eq__(self, other):
        return isinstance(other, FuncInfo) and self.qname == other.qname

    def __repr__(self):
        return f"<fn {self.qname}>"

    @property
    def pos_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in list(a.posonlyargs) + list(a.args)]

    @property
    def kwonly_params(self) -> List[str]:
        return [p.arg for p in self.node.args.kwonlyargs]

    @property
    def all_params(self) -> List[str]:
        out = self.pos_params + self.kwonly_params
        if self.node.args.vararg:
            out.append(self.node.args.vararg.arg)
        if self.node.args.kwarg:
            out.append(self.node.args.kwarg.arg)
        return out

    @property
    def required_pos_params(self) -> List[str]:
        """Positional parameter names that have no default."""
        a = self.node.args
        pos = list(a.posonlyargs) + list(a.args)
        n_def = len(a.defaults)
        return [p.arg for p in (pos[:-n_def] if n_def else pos)]

    def is_method(self) -> bool:
        return self.cls_name is not None and self.parent is None


@dataclass
class ModuleInfo:
    fq: str                          # "repro.serving.cache"
    path: str
    tree: ast.Module
    lines: List[str]
    import_alias: Dict[str, str] = field(default_factory=dict)
    from_symbols: Dict[str, Tuple[str, str]] = field(default_factory=dict)
    funcs: Dict[str, FuncInfo] = field(default_factory=dict)
    classes: Dict[str, Dict[str, FuncInfo]] = field(default_factory=dict)


class Project:
    """All modules under a package root, with name resolution helpers."""

    def __init__(self):
        self.modules: Dict[str, ModuleInfo] = {}
        self.functions: Dict[str, FuncInfo] = {}

    # ------------------------------------------------------------ loading --
    @classmethod
    def load(cls, src_root: str, package: str = "repro") -> "Project":
        proj = cls()
        pkg_dir = os.path.join(src_root, package)
        for dirpath, _dirs, files in os.walk(pkg_dir):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, src_root)
                fq = rel[:-3].replace(os.sep, ".")
                if fq.endswith(".__init__"):
                    fq = fq[: -len(".__init__")]
                proj._load_module(fq, path)
        return proj

    def _load_module(self, fq: str, path: str) -> None:
        with open(path, "r") as f:
            src = f.read()
        tree = ast.parse(src, filename=path)
        mod = ModuleInfo(fq=fq, path=path, tree=tree,
                         lines=src.splitlines())
        self.modules[fq] = mod
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    mod.import_alias[alias.asname or
                                     alias.name.split(".")[0]] = (
                        alias.name if alias.asname else
                        alias.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                base = node.module
                if node.level:  # relative import — anchor at this package
                    parts = fq.split(".")[: -node.level]
                    base = ".".join(parts + [node.module])
                for alias in node.names:
                    mod.from_symbols[alias.asname or alias.name] = (
                        base, alias.name)

        def collect(body, cls_name, parent, prefix):
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qname = f"{fq}.{prefix}{node.name}"
                    fi = FuncInfo(module=mod, qname=qname, node=node,
                                  cls_name=cls_name, parent=parent)
                    local = f"{prefix}{node.name}"
                    mod.funcs[local] = fi
                    self.functions[qname] = fi
                    if cls_name and parent is None:
                        mod.classes.setdefault(cls_name, {})[node.name] = fi
                    collect(node.body, cls_name, fi, f"{prefix}{node.name}.")
                elif isinstance(node, ast.ClassDef):
                    mod.classes.setdefault(node.name, {})
                    collect(node.body, node.name, None, f"{node.name}.")

        collect(tree.body, None, None, "")

    # --------------------------------------------------------- resolution --
    def external_fq(self, mod: ModuleInfo, expr: ast.AST) -> Optional[str]:
        """Fully-qualified dotted name of `expr` through import aliases,
        e.g. `pl.pallas_call` -> "jax.experimental.pallas.pallas_call"."""
        dotted = _dotted(expr)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        if head in mod.import_alias:
            base = mod.import_alias[head]
            return f"{base}.{rest}" if rest else base
        if head in mod.from_symbols:
            src_mod, orig = mod.from_symbols[head]
            tail = f"{src_mod}.{orig}"
            return f"{tail}.{rest}" if rest else tail
        return dotted

    def is_entry(self, mod: ModuleInfo, expr: ast.AST) -> Optional[str]:
        """Return the canonical tracing-entry name if `expr` names one."""
        fq = self.external_fq(mod, expr)
        if fq is None:
            return None
        if fq in TRACING_ENTRY_FQS:
            return fq
        # tolerate deep import paths (jax.experimental.pallas.* re-exports)
        if fq.endswith(".pallas_call"):
            return "jax.experimental.pallas.pallas_call"
        if fq in ("jax.numpy.jit",):
            return None
        return None

    def resolve(self, caller_mod: ModuleInfo, expr: ast.AST,
                caller: Optional[FuncInfo] = None) -> Optional[FuncInfo]:
        """Resolve a call/reference expression to a project FuncInfo."""
        # self.method() inside a class
        if (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id in ("self", "cls") and caller is not None):
            cur: Optional[FuncInfo] = caller
            while cur is not None and cur.cls_name is None:
                cur = cur.parent
            if cur is not None and cur.cls_name in caller_mod.classes:
                return caller_mod.classes[cur.cls_name].get(expr.attr)
            return None
        dotted = _dotted(expr)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        # nested function in an enclosing scope
        if not rest and caller is not None:
            scope = caller
            while scope is not None:
                cand = caller_mod.funcs.get(
                    f"{scope.qname[len(caller_mod.fq) + 1:]}.{head}")
                if cand is not None:
                    return cand
                scope = scope.parent
        # module-local function / method via ClassName.method
        if dotted in caller_mod.funcs:
            return caller_mod.funcs[dotted]
        # from-imported symbol
        if head in caller_mod.from_symbols:
            src_mod, orig = caller_mod.from_symbols[head]
            target = f"{orig}.{rest}" if rest else orig
            m = self.modules.get(src_mod)
            if m is not None and target in m.funcs:
                return m.funcs[target]
            # from-import of a module: `from repro.kernels import ref`
            m2 = self.modules.get(f"{src_mod}.{orig}")
            if m2 is not None and rest and rest in m2.funcs:
                return m2.funcs[rest]
            return None
        # import-aliased module: `cache_lib.scatter_pages`
        if head in caller_mod.import_alias and rest:
            m = self.modules.get(caller_mod.import_alias[head])
            if m is not None and rest in m.funcs:
                return m.funcs[rest]
        return None


# ---------------------------------------------------------------- seeds ----

@dataclass
class Seed:
    func: FuncInfo
    traced: Set[str]
    kind: str                        # "jit" | "scan" | "pallas" | ...


def _const_tuple(node: ast.AST) -> Optional[List[object]]:
    if isinstance(node, ast.Constant):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not isinstance(e, ast.Constant):
                return None
            out.append(e.value)
        return out
    return None


def _unwrap_partial(proj: Project, mod: ModuleInfo, expr: ast.AST,
                    caller: Optional[FuncInfo]
                    ) -> Tuple[ast.AST, int, Set[str]]:
    """Peel `functools.partial(f, a, b, kw=...)`: returns (inner expr,
    number of pre-bound positional args, pre-bound kwarg names)."""
    n_pos, kw_names = 0, set()
    while isinstance(expr, ast.Call):
        fq = proj.external_fq(mod, expr.func)
        if fq in ("functools.partial", "partial"):
            if not expr.args:
                break
            n_pos += len(expr.args)
            kw_names |= {k.arg for k in expr.keywords if k.arg}
            expr = expr.args[0]
            if isinstance(expr, ast.Call):
                continue
            break
        if fq in ("jax.checkpoint", "jax.remat"):
            if expr.args:
                expr = expr.args[0]
                continue
        break
    return expr, max(n_pos - 1, 0) if n_pos else 0, kw_names


def _jit_statics(func: FuncInfo, call: ast.Call, n_partial_pos: int,
                 partial_kws: Set[str]) -> Set[str]:
    """Parameter names of `func` that are static under this jit call."""
    statics: Set[str] = set(partial_kws)
    pos = func.pos_params
    statics |= set(pos[:n_partial_pos])
    remaining = pos[n_partial_pos:]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = _const_tuple(kw.value) or []
            statics |= {v for v in vals if isinstance(v, str)}
        elif kw.arg == "static_argnums":
            vals = _const_tuple(kw.value) or []
            for v in vals:
                if isinstance(v, int) and 0 <= v < len(remaining):
                    statics.add(remaining[v])
    return statics


def discover_seeds(proj: Project) -> List[Seed]:
    """Find every function handed to a tracing entry point anywhere in the
    project (module level or inside another function)."""
    seeds: List[Seed] = []

    def enclosing(mod: ModuleInfo, node: ast.AST,
                  parents: Dict[ast.AST, ast.AST]) -> Optional[FuncInfo]:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in mod.funcs.values():
                    if fi.node is cur:
                        return fi
            cur = parents.get(cur)
        return None

    for mod in proj.modules.values():
        parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(mod.tree):
            for child in ast.iter_child_nodes(parent):
                parents[child] = parent

        def seed_target(expr, entry, caller, mod=mod):
            target, n_pos, kws = _unwrap_partial(proj, mod, expr, caller)
            fi = proj.resolve(mod, target, caller)
            if fi is None:
                return None
            return fi, n_pos, kws

        for node in ast.walk(mod.tree):
            # decorator form: @jax.jit / @functools.partial(jax.jit, ...)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fi = next((f for f in mod.funcs.values() if f.node is node),
                          None)
                if fi is None:
                    continue
                for dec in node.decorator_list:
                    entry = None
                    statics: Set[str] = set()
                    if proj.is_entry(mod, dec):
                        entry = proj.is_entry(mod, dec)
                    elif isinstance(dec, ast.Call):
                        dfq = proj.external_fq(mod, dec.func)
                        if dfq in ("functools.partial", "partial") and \
                                dec.args and proj.is_entry(mod, dec.args[0]):
                            entry = proj.is_entry(mod, dec.args[0])
                            statics = _jit_statics(fi, dec, 0, set())
                        elif proj.is_entry(mod, dec.func):
                            entry = proj.is_entry(mod, dec.func)
                            statics = _jit_statics(fi, dec, 0, set())
                    if entry:
                        traced = ({p for p in fi.all_params if p != "self"}
                                  - statics)
                        seeds.append(Seed(fi, traced, entry))
                continue
            if not isinstance(node, ast.Call):
                continue
            entry = proj.is_entry(mod, node.func)
            if entry is None or not node.args:
                continue
            caller = enclosing(mod, node, parents)
            hit = seed_target(node.args[0], entry, caller)
            if hit is None:
                continue
            fi, n_pos, kws = hit
            if entry == "jax.jit":
                statics = _jit_statics(fi, node, n_pos, kws)
                traced = ({p for p in fi.all_params if p != "self"}
                          - statics)
            else:
                pos = fi.pos_params
                traced = (set(pos[n_pos:]) | set(fi.kwonly_params)) - kws
            seeds.append(Seed(fi, traced, entry))
    return seeds


# ----------------------------------------------------------- tracedness ----

@dataclass
class FuncAnalysis:
    traced_names: Set[str] = field(default_factory=set)
    summary: Union[bool, List[bool]] = True
    calls: List[Tuple[FuncInfo, Set[str]]] = field(default_factory=list)
    callbacks: List[FuncInfo] = field(default_factory=list)


class Tracedness:
    """Expression tracedness under a set of traced local names."""

    def __init__(self, proj: Project, mod: ModuleInfo,
                 caller: Optional[FuncInfo],
                 summaries: Dict[FuncInfo, Union[bool, List[bool]]]):
        self.proj = proj
        self.mod = mod
        self.caller = caller
        self.summaries = summaries

    def expr(self, node: ast.AST, traced: Set[str]) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in traced
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            d = _dotted(node)
            if d is not None and d in traced:
                return True
            return self.expr(node.value, traced)
        if isinstance(node, ast.Compare):
            # identity tests are static; membership tests probe pytree
            # *structure* (dict keys), which is static under trace
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in node.ops):
                return False
            return (self.expr(node.left, traced)
                    or any(self.expr(c, traced) for c in node.comparators))
        if isinstance(node, ast.Call):
            return self._call(node, traced)
        if isinstance(node, ast.Subscript):
            if (isinstance(node.slice, ast.Constant)
                    and node.slice.value in STATIC_KEYS):
                return False
            return (self.expr(node.value, traced)
                    or self.expr(node.slice, traced))
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            inner = set(traced)
            for gen in node.generators:
                # the comprehension target always shadows the outer scope;
                # it is traced iff the iterable is
                it = self.expr(gen.iter, inner)
                for n in ast.walk(gen.target):
                    if isinstance(n, ast.Name):
                        (inner.add if it else inner.discard)(n.id)
            parts = [getattr(node, "elt", None), getattr(node, "key", None),
                     getattr(node, "value", None)]
            return any(self.expr(p, inner) for p in parts if p is not None)
        if isinstance(node, ast.Lambda):
            return False
        return any(self.expr(c, traced)
                   for c in ast.iter_child_nodes(node)
                   if not isinstance(c, (ast.operator, ast.cmpop,
                                         ast.boolop, ast.unaryop,
                                         ast.expr_context)))

    def _call(self, node: ast.Call, traced: Set[str]) -> bool:
        fname = node.func.id if isinstance(node.func, ast.Name) else None
        if fname in STATIC_CALLS:
            return False
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "get" and node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value in STATIC_KEYS):
            return False
        target = self.proj.resolve(self.mod, node.func, self.caller)
        if target is not None and target in self.summaries:
            summ = self.summaries[target]
            if isinstance(summ, list):
                return any(summ)
            return bool(summ)
        args_traced = (any(self.expr(a, traced) for a in node.args)
                       or any(self.expr(k.value, traced)
                              for k in node.keywords))
        return args_traced or self.expr(node.func, traced)


def _assign_targets(node: ast.AST) -> List[str]:
    """Flatten assignment targets to name / dotted-attr strings."""
    out: List[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            d = _dotted(n)
            if d:
                out.append(d)
    return out


def analyze_function(proj: Project, f: FuncInfo, traced_in: Set[str],
                     summaries: Dict[FuncInfo, Union[bool, List[bool]]],
                     ambient: Set[str]) -> FuncAnalysis:
    res = FuncAnalysis()
    tr = Tracedness(proj, f.module, f, summaries)
    traced: Set[str] = set(traced_in) | set(ambient)
    returns: List[Union[bool, List[bool]]] = []

    def visit_stmts(body: Sequence[ast.stmt]):
        for st in body:
            visit(st)

    def record_call(node: ast.Call):
        # callback arguments: a project function passed by value
        # (introspection builtins like getattr() do not call their args)
        if not (isinstance(node.func, ast.Name)
                and node.func.id in CALLBACK_EXEMPT):
            _record_callbacks(node)
        target = proj.resolve(f.module, node.func, f)
        if target is None:
            return
        pos = target.pos_params
        skip_self = 1 if (target.is_method() and pos and
                          pos[0] in ("self", "cls")) else 0
        pos = pos[skip_self:]
        gtraced: Set[str] = set()
        i = 0
        for arg in node.args:
            if isinstance(arg, ast.Starred):
                if tr.expr(arg.value, traced):
                    gtraced |= set(pos[i:])
                i = len(pos)
                continue
            if i < len(pos) and tr.expr(arg, traced):
                gtraced.add(pos[i])
            i += 1
        for kw in node.keywords:
            if kw.arg is None:
                continue
            if kw.arg in target.all_params and tr.expr(kw.value, traced):
                gtraced.add(kw.arg)
        res.calls.append((target, gtraced))

    def _record_callbacks(node: ast.Call):
        for arg in list(node.args) + [k.value for k in node.keywords]:
            if isinstance(arg, (ast.Name, ast.Attribute)):
                cb = proj.resolve(f.module, arg, f)
                if cb is not None and not (
                        isinstance(node.func, (ast.Name, ast.Attribute))
                        and proj.resolve(f.module, node.func, f) is cb):
                    res.callbacks.append(cb)

    def assign(targets: List[ast.expr], value: Optional[ast.AST]):
        if value is None:
            return
        # per-element tracedness for tuple unpack of a summarized call
        if (len(targets) == 1 and isinstance(targets[0], ast.Tuple)
                and isinstance(value, ast.Call)):
            g = proj.resolve(f.module, value.func, f)
            summ = summaries.get(g) if g is not None else None
            elts = targets[0].elts
            if (isinstance(summ, list) and len(summ) == len(elts)
                    and all(isinstance(e, ast.Name) for e in elts)):
                for e, t in zip(elts, summ):
                    if t:
                        traced.add(e.id)
                    else:
                        traced.discard(e.id)
                return
        # direct tuple-literal unpack: elementwise
        if (len(targets) == 1 and isinstance(targets[0], ast.Tuple)
                and isinstance(value, ast.Tuple)
                and len(targets[0].elts) == len(value.elts)
                and all(isinstance(e, ast.Name) for e in targets[0].elts)):
            for e, v in zip(targets[0].elts, value.elts):
                if tr.expr(v, traced):
                    traced.add(e.id)
                else:
                    traced.discard(e.id)
            return
        is_traced = tr.expr(value, traced)
        for t in targets:
            for name in _assign_targets(t):
                if is_traced:
                    traced.add(name)
                else:
                    traced.discard(name)

    def for_target(target: ast.expr, it: ast.AST):
        """Loop-target tracedness, destructuring `enumerate`/`zip` so a
        static list zipped against traced params doesn't poison every
        target name."""
        if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id == "enumerate" and it.args
                and isinstance(target, ast.Tuple)
                and len(target.elts) == 2):
            if isinstance(target.elts[0], ast.Name):
                traced.discard(target.elts[0].id)
            for_target(target.elts[1], it.args[0])
            return
        if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id == "zip"
                and isinstance(target, ast.Tuple)
                and len(target.elts) == len(it.args)):
            for t, a in zip(target.elts, it.args):
                for_target(t, a)
            return
        is_traced = tr.expr(it, traced)
        for name in _assign_targets(target):
            if is_traced:
                traced.add(name)
            else:
                traced.discard(name)

    def visit(st: ast.stmt):
        for node in ast.walk(st):
            if isinstance(node, ast.Call):
                record_call(node)
        if isinstance(st, ast.Assign):
            assign(st.targets, st.value)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                assign([st.target], st.value)
        elif isinstance(st, ast.AugAssign):
            if tr.expr(st.value, traced):
                for name in _assign_targets(st.target):
                    traced.add(name)
        elif isinstance(st, ast.For):
            for_target(st.target, st.iter)
            visit_stmts(st.body)
            visit_stmts(st.orelse)
        elif isinstance(st, (ast.If, ast.While)):
            visit_stmts(st.body)
            visit_stmts(st.orelse)
        elif isinstance(st, ast.With):
            for item in st.items:
                if item.optional_vars is not None and \
                        tr.expr(item.context_expr, traced):
                    for name in _assign_targets(item.optional_vars):
                        traced.add(name)
            visit_stmts(st.body)
        elif isinstance(st, ast.Try):
            visit_stmts(st.body)
            for h in st.handlers:
                visit_stmts(h.body)
            visit_stmts(st.orelse)
            visit_stmts(st.finalbody)
        elif isinstance(st, ast.Return):
            if st.value is None:
                returns.append(False)
            elif isinstance(st.value, ast.Tuple):
                returns.append([tr.expr(e, traced) for e in st.value.elts])
            else:
                returns.append(tr.expr(st.value, traced))
        elif isinstance(st, ast.Expr):
            pass  # calls already recorded

    # two passes: second pass sees loop-carried tracedness
    for _ in range(2):
        res.calls.clear()
        res.callbacks.clear()
        returns.clear()
        visit_stmts(f.node.body)

    res.traced_names = traced
    if not returns:
        res.summary = False
    else:
        tuples = [r for r in returns if isinstance(r, list)]
        if tuples and all(isinstance(r, list) and len(r) == len(tuples[0])
                          for r in returns):
            res.summary = [any(col) for col in zip(*returns)]
        else:
            res.summary = any(
                any(r) if isinstance(r, list) else r for r in returns)
    return res


@dataclass
class Analysis:
    project: Project
    reachable: Dict[FuncInfo, Set[str]]          # func -> traced param names
    info: Dict[FuncInfo, FuncAnalysis]
    seeds: List[Seed]
    summaries: Dict[FuncInfo, Union[bool, List[bool]]] = field(
        default_factory=dict)

    def tracer(self, f: FuncInfo) -> Optional[Set[str]]:
        """Traced local-name set for a reachable function (None if not)."""
        fa = self.info.get(f)
        return fa.traced_names if fa is not None else None


def analyze(proj: Project) -> Analysis:
    """Tracedness fixpoint. Within a round, traced-param sets only grow
    (worklist until stable). Return summaries refined during a round can
    prove a parameter *static* that an earlier over-approximation (summary
    not yet known -> assume traced) had poisoned — growth-only sets cannot
    retract that, so the whole round is re-run from the seeds with the
    refined summaries carried over, until two rounds agree."""
    seeds = discover_seeds(proj)
    summaries: Dict[FuncInfo, Union[bool, List[bool]]] = {}
    traced_params: Dict[FuncInfo, Set[str]] = {}
    info: Dict[FuncInfo, FuncAnalysis] = {}
    prev_snapshot = None

    for _round in range(4):
        traced_params = {}
        ambient: Dict[FuncInfo, Set[str]] = {}
        callers: Dict[FuncInfo, Set[FuncInfo]] = {}
        info = {}
        work: deque = deque()

        def enqueue(f: FuncInfo, new_traced: Set[str]):
            cur = traced_params.get(f)
            if cur is None:
                traced_params[f] = set(new_traced)
                work.append(f)
            elif new_traced - cur:
                cur |= new_traced
                work.append(f)

        for s in seeds:
            enqueue(s.func, s.traced)

        budget = 20000
        while work and budget > 0:
            budget -= 1
            f = work.popleft()
            res = analyze_function(proj, f, traced_params[f], summaries,
                                   ambient.get(f, set()))
            info[f] = res
            if summaries.get(f) != res.summary:
                summaries[f] = res.summary
                for c in callers.get(f, ()):
                    work.append(c)
            for g, gtraced in res.calls:
                callers.setdefault(g, set()).add(f)
                enqueue(g, gtraced)
            for cb in res.callbacks:
                callers.setdefault(cb, set()).add(f)
                ambient.setdefault(cb, set())
                enqueue(cb, {p for p in cb.all_params if p != "self"})
            # decorated nested defs execute at trace time (@pl.when(...))
            for child in proj.functions.values():
                if child.parent is f and child.node.decorator_list:
                    amb = ambient.setdefault(child, set())
                    if res.traced_names - amb:
                        amb |= res.traced_names
                        work.append(child)
                    enqueue(child, set(child.all_params))

        snapshot = {f.qname: frozenset(tp)
                    for f, tp in traced_params.items()}
        if snapshot == prev_snapshot:
            break
        prev_snapshot = snapshot

    return Analysis(project=proj, reachable=traced_params, info=info,
                    seeds=seeds, summaries=summaries)
