"""Symbolic models of every ``pl.pallas_call`` in ``repro.kernels``.

Interpret mode executes grid steps sequentially in Python and therefore
hides exactly the bug class that kills Pallas kernels on real TPUs:
output-block revisit races, out-of-bounds index maps, and uninitialized or
unflushed VMEM scratch accumulators. This module extracts a *static* model
of each kernel — grid, BlockSpec block shapes, index-map callables
(evaluated over enumerated grid coordinates and representative
scalar-prefetch operands), scratch shapes, and the kernel body's AST — so
``repro.analysis.kernel_verify`` can prove the hardware invariants without
any TPU.

Extraction works by interception: :func:`capture` monkeypatches
``pl.pallas_call`` while the ordinary kernel *wrapper* runs, records the
grid spec and the concrete operands the wrapper passes, and returns zeros
of ``out_shape`` instead of executing anything. The wrappers' own shape
logic (``_fit_block``, padding, GQA folding) is therefore modeled exactly
as shipped — there is no second copy of the launch math to drift.

Shape cases come from ``repro.configs``: :func:`config_cases` yields one
case per registered architecture with the *real* model dims (d_model,
head_dim, max_rank, rank block) so block shapes — and hence the VMEM
footprint table — match production, while batch/head/page counts are kept
small so exhaustive grid enumeration stays cheap (the index maps are
per-coordinate, so small grids exercise the same arithmetic).
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import inspect
import textwrap
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

KERNEL_WRAPPERS = ("bgmv_shrink", "bgmv_expand", "mbgmv_shrink",
                   "mbgmv_expand", "flash_attention", "paged_attention")


@dataclasses.dataclass
class SpecModel:
    """One BlockSpec bound to its concrete operand."""
    block_shape: Tuple[int, ...]
    index_map: Callable
    shape: Tuple[int, ...]          # operand (or output) array shape
    dtype: Any                      # numpy dtype
    name: str                       # kernel ref param bound to this spec
    line: int                       # index_map lambda source line

    def nbytes(self) -> int:
        n = 1
        for d in self.block_shape:
            n *= int(d)
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass
class KernelModel:
    """Everything kernel_verify needs about one pallas_call site."""
    name: str                       # wrapper name (bgmv_shrink, ...)
    case: str                       # shape-case label (config name, ...)
    kernel_name: str                # kernel function name (_shrink_kernel)
    path: str                       # source file of the kernel function
    line: int                       # kernel def line (1-based)
    grid: Tuple[int, ...]
    num_scalar_prefetch: int
    scalars: List[np.ndarray]       # concrete scalar-prefetch operands
    in_specs: List[SpecModel]
    out_specs: List[SpecModel]
    scratch: List[Tuple[Tuple[int, ...], Any]]   # (shape, dtype)
    kernel_params: List[str]        # positional ref params of the kernel
    kernel_ast: Optional[ast.FunctionDef]
    ast_line_base: int              # kernel_ast lineno 1 == this file line

    # ---------------------------------------------------------------- ast --
    def abs_line(self, node: ast.AST) -> int:
        """Map a kernel_ast node line to an absolute file line."""
        return self.ast_line_base + getattr(node, "lineno", 1) - 1

    # ------------------------------------------------------------- params --
    def param_roles(self) -> Optional[Dict[str, str]]:
        """Map kernel param name -> scalar|input|output|scratch, or None if
        the signature does not line up with the captured specs."""
        nsp, ni = self.num_scalar_prefetch, len(self.in_specs)
        no, ns = len(self.out_specs), len(self.scratch)
        if len(self.kernel_params) != nsp + ni + no + ns:
            return None
        roles: Dict[str, str] = {}
        for i, p in enumerate(self.kernel_params):
            if i < nsp:
                roles[p] = "scalar"
            elif i < nsp + ni:
                roles[p] = "input"
            elif i < nsp + ni + no:
                roles[p] = "output"
            else:
                roles[p] = "scratch"
        return roles

    def scalar_param(self, k: int) -> Optional[str]:
        """Kernel ref param name of scalar-prefetch operand k."""
        if k < self.num_scalar_prefetch and k < len(self.kernel_params):
            return self.kernel_params[k]
        return None

    # --------------------------------------------------------- index maps --
    def eval_index(self, spec: SpecModel,
                   point: Tuple[int, ...]) -> Tuple[int, ...]:
        """Evaluate one index_map at a grid point with the representative
        scalar operands; returns concrete block coordinates."""
        out = spec.index_map(*point, *self.scalars)
        if not isinstance(out, tuple):
            out = (out,)
        return tuple(int(c) for c in out)

    def grid_points(self) -> Iterator[Tuple[int, ...]]:
        """Row-major (last dim fastest) — the TPU sequential grid order."""
        return np.ndindex(*self.grid)

    # --------------------------------------------------------------- vmem --
    def vmem_footprint(self) -> Dict[str, int]:
        """Per-grid-step VMEM bytes. ``total`` doubles the in/out windows
        for Pallas' pipeline double buffering; scratch is single-buffered
        (it persists across grid steps)."""
        in_b = sum(s.nbytes() for s in self.in_specs)
        out_b = sum(s.nbytes() for s in self.out_specs)
        sc_b = 0
        for shape, dtype in self.scratch:
            n = 1
            for d in shape:
                n *= int(d)
            sc_b += n * np.dtype(dtype).itemsize
        return {"in_bytes": in_b, "out_bytes": out_b,
                "scratch_bytes": sc_b,
                "total_bytes": 2 * (in_b + out_b) + sc_b}


# ------------------------------------------------------------------ capture --

def _unwrap(kernel) -> Callable:
    while isinstance(kernel, functools.partial):
        kernel = kernel.func
    return kernel


def _positional_params(fn: Callable) -> List[str]:
    out = []
    for p in inspect.signature(fn).parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            out.append(p.name)
    return out


_AST_CACHE: Dict[Tuple[str, int, str], Optional[ast.FunctionDef]] = {}


def _kernel_ast(fn: Callable) -> Tuple[Optional[ast.FunctionDef], str, int]:
    """(AST of fn's def, source path, first line). Best-effort: returns a
    None AST for callables without retrievable source (the numeric checks
    still run on such models)."""
    try:
        path = inspect.getsourcefile(fn) or "<unknown>"
        line = fn.__code__.co_firstlineno
    except (TypeError, AttributeError):
        return None, "<unknown>", 0
    key = (path, line, fn.__name__)
    if key not in _AST_CACHE:
        try:
            src = textwrap.dedent(inspect.getsource(fn))
            node = ast.parse(src).body[0]
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                node = None
        except (OSError, SyntaxError, IndexError):
            node = None
        _AST_CACHE[key] = node
    return _AST_CACHE[key], path, line


def lambda_line(fn: Callable) -> int:
    try:
        return fn.__code__.co_firstlineno
    except AttributeError:
        return 0


def _flat_specs(specs) -> List[pl.BlockSpec]:
    return list(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, pl.BlockSpec)))


@contextmanager
def capture(into: List[KernelModel], *, name: str = "", case: str = ""):
    """Patch ``pl.pallas_call`` so wrapper invocations append a
    :class:`KernelModel` to `into` and return zeros instead of running."""
    real = pl.pallas_call

    def fake(kernel, out_shape, *, grid_spec=None, grid=(),
             in_specs=None, out_specs=None, scratch_shapes=(), **kw):
        if grid_spec is not None:
            g = tuple(grid_spec.grid)
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
            ins = _flat_specs(grid_spec.in_specs)
            outs = _flat_specs(grid_spec.out_specs)
            scratch = list(getattr(grid_spec, "scratch_shapes", ()) or ())
        else:
            g = tuple(grid)
            nsp = 0
            ins = _flat_specs(in_specs)
            outs = _flat_specs(out_specs)
            scratch = list(scratch_shapes or ())
        kfn = _unwrap(kernel)
        kast, kpath, kline = _kernel_ast(kfn)
        out_structs = jax.tree_util.tree_leaves(out_shape)

        def runner(*operands):
            scalars = [np.asarray(o) for o in operands[:nsp]]
            tensors = operands[nsp:]
            in_models = []
            for spec, op in zip(ins, tensors):
                in_models.append(SpecModel(
                    block_shape=tuple(int(d) for d in spec.block_shape),
                    index_map=spec.index_map,
                    shape=tuple(op.shape),
                    dtype=np.dtype(op.dtype),
                    name="", line=lambda_line(spec.index_map)))
            out_models = []
            for spec, st in zip(outs, out_structs):
                out_models.append(SpecModel(
                    block_shape=tuple(int(d) for d in spec.block_shape),
                    index_map=spec.index_map,
                    shape=tuple(st.shape),
                    dtype=np.dtype(st.dtype),
                    name="", line=lambda_line(spec.index_map)))
            params = _positional_params(kfn)
            model = KernelModel(
                name=name or kfn.__name__.lstrip("_"),
                case=case,
                kernel_name=kfn.__name__, path=kpath, line=kline,
                grid=g, num_scalar_prefetch=nsp, scalars=scalars,
                in_specs=in_models, out_specs=out_models,
                scratch=[(tuple(int(d) for d in s.shape),
                          np.dtype(s.dtype)) for s in scratch],
                kernel_params=params, kernel_ast=kast, ast_line_base=kline)
            # bind ref param names to specs (for messages)
            roles = model.param_roles()
            if roles is not None:
                for i, sm in enumerate(in_models):
                    sm.name = params[nsp + i]
                for i, sm in enumerate(out_models):
                    sm.name = params[nsp + len(in_models) + i]
            into.append(model)
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(out_shape),
                [jnp.zeros(s.shape, s.dtype) for s in out_structs])

        return runner

    pl.pallas_call = fake
    try:
        yield into
    finally:
        pl.pallas_call = real


# -------------------------------------------------------------- shape cases --

@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """Representative dims for one extraction sweep. Block shapes (and the
    VMEM table) use the real model dims; batch/head/page counts are the
    minimum that still exercises GQA folding, block-table gathers, and
    no-adapter sentinels."""
    label: str
    d_model: int
    hd: int
    group: int                      # GQA group (H // KV) to model
    r_max: int
    rank_block: int
    ps: int = 32                    # KV page size (serving default sweep mid)
    dtype: Any = jnp.bfloat16
    has_attn: bool = True
    batch: int = 3
    pages: int = 6
    width: int = 3                  # block-table W
    seq: int = 512                  # flash prefill length (2 KV blocks)


def case_from_config(cfg) -> ShapeCase:
    group = 1
    has_attn = cfg.n_heads > 0 and cfg.n_kv_heads > 0
    if has_attn:
        group = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    # enumerate with 4 query heads, preserving whether GQA folds (group>1)
    group_e = group if group in (1, 2, 4) else 2
    return ShapeCase(
        label=cfg.name, d_model=cfg.d_model, hd=(cfg.hd if has_attn else 64),
        group=group_e, r_max=cfg.lora.max_rank,
        rank_block=cfg.lora.rank_block, dtype=cfg.jdtype,
        has_attn=has_attn)


def build_models(sc: ShapeCase) -> List[KernelModel]:
    """Run every kernel wrapper once under capture with `sc`'s shapes.
    Scalar operands include the full sentinel vocabulary: no-adapter rows
    (idx == -1), unclaimed pages (block_table == -1), an all-unclaimed row,
    maximal slot/page ids, and empty page slots (pos_pages == -1)."""
    from repro.kernels import bgmv, flash, mbgmv, paged

    models: List[KernelModel] = []
    slots, B = 3, sc.batch
    idx = jnp.asarray([0, slots - 1, -1][:B], jnp.int32)
    ranks = jnp.asarray([sc.r_max, min(sc.rank_block, sc.r_max), 1][:slots],
                        jnp.int32)
    x = jnp.zeros((B, sc.d_model), sc.dtype)
    a_pool = jnp.zeros((slots, sc.d_model, sc.r_max), sc.dtype)
    b_pool = jnp.zeros((slots, sc.r_max, sc.d_model), sc.dtype)
    y32 = jnp.zeros((B, sc.r_max), jnp.float32)

    with capture(models, name="bgmv_shrink", case=sc.label):
        bgmv.bgmv_shrink(x, a_pool, idx)
    with capture(models, name="bgmv_expand", case=sc.label):
        bgmv.bgmv_expand(y32.astype(sc.dtype), b_pool, idx)
    with capture(models, name="mbgmv_shrink", case=sc.label):
        mbgmv.mbgmv_shrink(x, a_pool, idx, ranks,
                           rank_block=sc.rank_block)
    with capture(models, name="mbgmv_expand", case=sc.label):
        mbgmv.mbgmv_expand(y32.astype(sc.dtype), b_pool, idx, ranks,
                           rank_block=sc.rank_block)

    if sc.has_attn:
        H = 4
        KV = max(1, H // sc.group)
        q = jnp.zeros((1, H, sc.seq, sc.hd), sc.dtype)
        k = jnp.zeros((1, KV, sc.seq, sc.hd), sc.dtype)
        with capture(models, name="flash_attention", case=sc.label):
            flash.flash_attention(q, k, k)

        P, W, ps = sc.pages, sc.width, sc.ps
        qd = jnp.zeros((B, H, sc.hd), sc.dtype)
        kp = jnp.zeros((P, KV, ps, sc.hd), sc.dtype)
        # pos_pages: page 0 fully empty (lazily grown), others part-filled
        pp = np.zeros((P, ps), np.int32)
        pp[0] = -1
        pp[1:, ps // 2:] = -1
        # block tables: max page id used, unclaimed tails, one row fully
        # unclaimed (the all-masked conformance edge)
        bt = np.full((B, W), -1, np.int32)
        order = [P - 1] + list(range(1, P - 1))
        it = iter(order)
        for b in range(B - 1):
            for j in range(min(W, 2)):
                try:
                    bt[b, j] = next(it)
                except StopIteration:
                    break
        pos = np.maximum(pp.max(axis=1).max(), 0) * np.ones(B, np.int32)
        with capture(models, name="paged_attention", case=sc.label):
            paged.paged_attention(qd, kp, kp, jnp.asarray(pp),
                                  jnp.asarray(bt), jnp.asarray(pos))
    return models


def lint_models() -> List[KernelModel]:
    """The representative sweep the lint's kernel-* rules run on: one dense
    GQA config (llama2-7b dims) — every kernel, every rule, small grids."""
    from repro.configs.base import get_config
    return build_models(case_from_config(get_config("llama2-7b")))


def config_cases() -> Iterator[ShapeCase]:
    """One ShapeCase per registered architecture (real dims)."""
    from repro.configs.base import all_arch_ids, get_config
    for name in all_arch_ids():
        yield case_from_config(get_config(name))


def config_models() -> Iterator[Tuple[str, List[KernelModel]]]:
    for sc in config_cases():
        yield sc.label, build_models(sc)
