"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:
    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes is
parsed from the optimized HLO text: for every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op we sum the bytes moved
(all-reduce counted 2x for the reduce+broadcast phases; others at op size).
Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.11 = bf16[8,512,1024]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^)]*?\s*(" +
    "|".join(_COLLECTIVES) + r")\(")
_TUPLE_RE = re.compile(r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")\(")
_ELEM_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind, from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        kind = next((k for k in _COLLECTIVES if f" {k}(" in line or
                     line.startswith(k)), None)
        if kind is None:
            continue
        # output shape(s) appear between '=' and the op name
        head = line.split(f" {kind}(")[0]
        elems = _ELEM_RE.findall(head.split("=", 1)[-1])
        size = sum(_shape_bytes(dt, dims) for dt, dims in elems)
        factor = 2 if kind == "all-reduce" else 1
        out[kind] += size * factor
    return out


def roofline_terms(flops: float, bytes_hbm: float, coll_bytes: float,
                   chips: int, *, per_device: bool = True,
                   peak=PEAK_FLOPS, bw=HBM_BW, link=LINK_BW):
    """XLA's cost_analysis()/HLO text describe the per-device SPMD program,
    so per-device quantities divide by one chip's peak — numerically equal to
    the spec formula total/(chips*peak) since total = per_device*chips."""
    div = 1 if per_device else chips
    t_c = flops / (div * peak)
    t_m = bytes_hbm / (div * bw)
    t_x = coll_bytes / (div * link)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/request
