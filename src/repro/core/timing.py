"""Analytic timing model — the system's "profiler" on a CPU-only container.

The paper profiles an A10 GPU to obtain prefill/decode latencies and feeds
them to both the serving engine's continuous-batching timeline and the
scheduler's performance models (sec 5, sec 7.5 "we obtain the prefill and
decoding latency of the simulator by profiling"). We reproduce that
methodology with a first-principles roofline cost model of the TPU v5e
target: iteration latency = max(compute term, HBM term) + fixed overheads,
and LoRA kernel cost follows the BGMV max-rank / MBGMV sum-rank laws by
construction of the kernels in repro.kernels.

Every constant is either a v5e datasheet number or calibrated to the paper's
figures (adapter upload ~tens of ms for rank 64, Fig 3; <1 ms invocation via
shared memory, Fig 17; single-CPU token ceiling, Fig 18).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # B/s per chip
    ici_bw: float = 50e9              # B/s per link
    hbm_bytes: float = 16 * 2 ** 30
    chips: int = 1                    # chips per serving instance (TP group)
    # host <-> device adapter upload (effective, pageable host memory);
    # calibrated so a rank-64 q/k/v adapter of a 7B model (~100 MiB) costs
    # ~25 ms, matching paper Fig 3-Right.
    load_bw: float = 4e9
    load_base_ms: float = 1.0
    # parallel upload lanes on the host link; 1 = a single PCIe/DMA stream,
    # so concurrent cold starts serialize on the link (LoadTracker)
    load_concurrency: int = 1
    # host-assist constants; core GEMM rate calibrated to paper Fig 18
    # (128-token rank-64 q/k/v prefill of a 7B model on 8 cores ~ 13 ms)
    cpu_core_flops: float = 120e9     # sustained AVX-512 GEMM FLOP/s per core
    cpu_cores: int = 112              # TPU VM host cores (DESIGN.md sec 6)
    cpu_max_tokens_per_core: int = 16 # profiling-guided parallelization knob
    invoke_overhead_ms: float = 0.8   # shared-memory IPC per prefill (Fig 17)
    sync_per_layer_ms: float = 0.02   # async memcpy+signal operator (Fig 8)
    step_overhead_ms: float = 1.5     # scheduling/launch overhead per iter


V5E = Hardware()
# The paper's testbed GPU, for apples-to-apples reproduction of its figures.
A10 = Hardware(name="a10", peak_flops=125e12, hbm_bw=600e9,
               hbm_bytes=24 * 2 ** 30, load_bw=4e9)


def model_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    return cfg.param_count() * dtype_bytes


def active_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    return cfg.active_param_count() * dtype_bytes


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    if cfg.family == "ssm":
        return 0
    n_blocks = cfg.n_layers + cfg.n_enc_layers
    return 2 * cfg.n_kv_heads * cfg.hd * n_blocks * dtype_bytes


class TimingModel:
    """Latency oracle for one serving instance of `cfg` on `hw`."""

    def __init__(self, cfg: ModelConfig, hw: Hardware = V5E):
        self.cfg = cfg
        self.hw = hw
        # config-derived constants, hoisted out of the per-iteration path
        # (the engine calls these oracles once per simulated iteration)
        self._active_params = cfg.active_param_count()
        self._active_bytes = active_bytes(cfg)
        self._kv_bpt = kv_bytes_per_token(cfg)
        self._lora_unit: Optional[float] = None

    # ----------------------------------------------------- base model ----
    def _attn_flops(self, new_tokens: int, ctx_start: int = 0) -> float:
        """FLOPs of causal attention for `new_tokens` query positions whose
        context already holds `ctx_start` cached keys: query i attends to
        ctx_start + i + 1 keys, and each (query, key) pair costs
        4 * n_heads * hd flops per block (QK^T + PV)."""
        if new_tokens <= 0 or self._kv_bpt == 0:
            return 0.0
        n_blocks = self.cfg.n_layers + self.cfg.n_enc_layers
        keys = new_tokens * ctx_start + new_tokens * (new_tokens + 1) / 2.0
        return 4.0 * n_blocks * self.cfg.n_heads * self.cfg.hd * keys

    def base_prefill_ms(self, total_tokens: int) -> float:
        """Monolithic prefill of `total_tokens` prompt tokens.

        Compute term = linear GEMM flops plus the quadratic causal-attention
        term (without it the model under-bills 2k+ token prompts); short
        prompts stay HBM-bound, so their cost is bitwise unchanged by the
        attention term.
        """
        flops = 2 * self._active_params * total_tokens \
            + self._attn_flops(total_tokens)
        t_c = flops / (self.hw.peak_flops * self.hw.chips)
        t_m = self._active_bytes / (self.hw.hbm_bw * self.hw.chips)
        return max(t_c, t_m) * 1e3 + self.hw.step_overhead_ms

    def chunk_prefill_ms(self, chunk_tokens: int, ctx_start: int = 0) -> float:
        """One prefill chunk of `chunk_tokens` on top of `ctx_start` cached
        tokens, run as its own iteration (no decode rows riding along)."""
        return self.mixed_step_ms(0, 0, chunk_tokens, ctx_start)

    def mixed_step_ms(self, batch: int, avg_ctx: int,
                      chunk_tokens: int, chunk_ctx: int = 0) -> float:
        """One iteration serving `batch` decode rows plus a piggybacked
        prefill chunk of `chunk_tokens` (context depth `chunk_ctx`).

        The chunk shares the iteration's weight pass and fixed step
        overhead with the decode batch — that sharing is the piggyback
        win — but pays its own GEMM/attention flops and re-reads the
        chunk row's prefix KV from HBM.
        """
        if chunk_tokens <= 0:
            return self.base_decode_ms(batch, avg_ctx)
        flops = 2 * self._active_params * (batch + chunk_tokens) \
            + self._attn_flops(chunk_tokens, chunk_ctx)
        par_b = self._active_bytes
        kv_b = self._kv_bpt * (avg_ctx * batch + chunk_ctx + chunk_tokens)
        t_c = flops / (self.hw.peak_flops * self.hw.chips)
        t_m = (par_b + kv_b) / (self.hw.hbm_bw * self.hw.chips)
        return max(t_c, t_m) * 1e3 + self.hw.step_overhead_ms

    def base_decode_ms(self, batch: int, avg_ctx: int = 512) -> float:
        """One decode iteration for `batch` sequences (HBM-bound)."""
        par_b = self._active_bytes
        kv_b = self._kv_bpt * avg_ctx * batch
        t_m = (par_b + kv_b) / (self.hw.hbm_bw * self.hw.chips)
        flops = 2 * self._active_params * batch
        t_c = flops / (self.hw.peak_flops * self.hw.chips)
        return max(t_c, t_m) * 1e3 + self.hw.step_overhead_ms

    # ------------------------------------------------------ LoRA kernels ----
    def _lora_bytes_per_token_rank(self) -> float:
        if self._lora_unit is not None:
            return self._lora_unit
        total = 0
        from repro.core.lora import lora_target_dims
        for tgt in self.cfg.lora.targets:
            d_in, d_out = lora_target_dims(self.cfg, tgt)
            total += (d_in + d_out)
        n_blocks = self.cfg.n_layers + self.cfg.n_enc_layers
        self._lora_unit = total * n_blocks * 2  # bytes per unit rank (bf16)
        return self._lora_unit

    def lora_decode_ms(self, ranks: Sequence[int], kernel: str = "bgmv",
                       rank_block: int = 16) -> float:
        """Per-iteration LoRA kernel cost (HBM-bound, paper sec 5: >70% of
        memory bandwidth). BGMV: |S|*max(rank); MBGMV: sum(ceil(rank/RB)*RB)."""
        if not ranks:
            return 0.0
        unit = self._lora_bytes_per_token_rank()
        if kernel == "bgmv":
            work = len(ranks) * max(ranks)
        else:
            work = sum((r + rank_block - 1) // rank_block * rank_block
                       for r in ranks)
        return work * unit / (self.hw.hbm_bw * self.hw.chips) * 1e3

    def lora_prefill_gpu_ms(self, tokens: int, rank: int) -> float:
        unit = self._lora_bytes_per_token_rank()
        flops = tokens * rank * unit  # 2 flops per 2 bytes -> ~1:1
        return max(flops / (self.hw.peak_flops * self.hw.chips),
                   rank * unit / (self.hw.hbm_bw * self.hw.chips)) * 1e3

    # ------------------------------------------------------- cold start ----
    def load_ms(self, adapter_bytes: int) -> float:
        """Host->device adapter upload (the paper's cold-start, Fig 3)."""
        return self.hw.load_base_ms + adapter_bytes / self.hw.load_bw * 1e3

    def cpu_cores_for(self, tokens: int) -> int:
        """Profiling-guided parallelization (paper sec 4.2, Fig 18)."""
        want = -(-tokens // self.hw.cpu_max_tokens_per_core)
        return max(1, min(want, self.hw.cpu_cores))

    def cpu_lora_prefill_ms(self, tokens: int, rank: int) -> float:
        """Host CPUs computing x·A·B for the prefill (paper sec 4.1)."""
        unit = self._lora_bytes_per_token_rank()   # = flops per token-rank
        flops = tokens * rank * unit
        cores = self.cpu_cores_for(tokens)
        t = flops / (cores * self.hw.cpu_core_flops) * 1e3
        n_blocks = self.cfg.n_layers + self.cfg.n_enc_layers
        return t + self.hw.invoke_overhead_ms \
            + n_blocks * self.hw.sync_per_layer_ms

    def cpu_lora_decode_ms(self, ranks: Sequence[int]) -> float:
        """Host CPUs computing the per-token x·A·B for decode rows riding
        the CPU-assist path as a *fault shield* — their adapter upload is
        mid-retry (core/faults.py), so the LoRA delta comes from the host
        copy instead of stalling the row. One token per row per iteration:
        a single token cannot be split across cores
        (cpu_max_tokens_per_core >= 1), rows run on distinct cores in
        parallel — the iteration is bounded by the largest rank — and pays
        the shared-memory invocation plus per-layer sync overheads once
        (paper Figs 8, 17). The host work overlaps the device pass; the
        engine charges max(device_ms, cpu_lora_decode_ms)."""
        if not ranks:
            return 0.0
        unit = self._lora_bytes_per_token_rank()
        t = max(ranks) * unit / self.hw.cpu_core_flops * 1e3
        n_blocks = self.cfg.n_layers + self.cfg.n_enc_layers
        return t + self.hw.invoke_overhead_ms \
            + n_blocks * self.hw.sync_per_layer_ms
