"""Continuous-batching LoRA serving engine (one inference server, paper Fig 6).

Iteration-level batching (Orca-style, paper sec 2.2): each `step()` admits
queued requests (prefill, possibly cold-starting their adapter per the
engine mode), then runs ONE decode iteration for every running request.
Completed requests leave the batch immediately.

Two coupled planes:
  * numerics — real JAX computation: per-request prefill, batched decode over
    the KV-cache pool, heterogeneous LoRA via the slot pool (can be disabled
    for timing-only simulations at cluster scale).
  * timeline — a virtual clock advanced by the TimingModel, reproducing the
    paper's profiling-driven methodology (sec 7.5); cold-start/CPU-assist
    overlap comes from ColdStartManager.

Modes: cached | ondemand | slora | caraserve.  Kernels: bgmv | mbgmv.
"""
from __future__ import annotations

import collections
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cold_start import ColdStartManager
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import Hardware, TimingModel, V5E
from repro.models import model as model_lib
from repro.models.param import split
from repro.serving import cache as cache_lib
from repro.serving.request import Request, RequestState, summarize
from repro.serving.sampling import sample


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class InferenceServer:
    def __init__(self, cfg: ModelConfig, *, mode: str = "caraserve",
                 kernel: str = "bgmv", max_batch: int = 8,
                 cache_slots: int = 256, hw: Hardware = V5E,
                 numerics: bool = True, params=None, seed: int = 0,
                 avg_ctx: int = 512, pool_slots: Optional[int] = None,
                 prefetch: bool = False):
        self.cfg = cfg
        self.mode = mode
        self.kernel = kernel
        self.max_batch = max_batch
        self.cache_slots = cache_slots
        self.numerics = numerics
        self.tm = TimingModel(cfg, hw)
        self.store = HostLoRAStore(cfg)
        self.pool = DevicePool(cfg, n_slots=pool_slots or
                               max(cfg.lora.n_slots, max_batch),
                               materialize=numerics)
        self.cold = ColdStartManager(self.tm, self.store, self.pool, mode)
        self.clock = 0.0
        self.queue: collections.deque = collections.deque()
        self.rows: List[Optional[RequestState]] = [None] * max_batch
        self.states: List[RequestState] = []
        self.avg_ctx = avg_ctx
        self._row_idx = np.full(max_batch, -1, np.int64)   # adapter slot/row
        self._row_pos = np.zeros(max_batch, np.int64)
        # beyond-paper: popularity-EWMA adapter prefetching into idle slots
        # (the paper critiques S-LoRA's unspecified prefetching, sec 2.3 —
        # here it is concrete and composable with CPU-assist)
        self.prefetch = prefetch
        self._popularity: Dict[str, float] = {}
        if numerics:
            if params is None:
                params, _ = split(model_lib.init_params(
                    cfg, jax.random.PRNGKey(seed)))
            self.params = params
            row_cache = model_lib.cache_abstract(cfg, 1, cache_slots)
            self.cache = cache_lib.zeros_like_batched(row_cache, max_batch)
            self._decode_jit = jax.jit(functools.partial(
                self._decode_fn, cfg, self._mode_str()), donate_argnums=(1,))
            self._prefill_jit = {}

    # ----------------------------------------------------------- public ----
    def register_adapter(self, spec: AdapterSpec):
        self.store.register(spec, materialize=self.numerics)

    def submit(self, req: Request) -> RequestState:
        st = RequestState(req)
        self.states.append(st)
        self.queue.append(st)
        if self.prefetch:   # EWMA popularity update
            for k in self._popularity:
                self._popularity[k] *= 0.98
            self._popularity[req.adapter_uid] = \
                self._popularity.get(req.adapter_uid, 0.0) + 1.0
        return st

    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.rows)

    def running_ranks(self) -> List[int]:
        return [self.store.specs[r.req.adapter_uid].rank
                for r in self.rows if r is not None]

    # ------------------------------------------------------ one iteration ----
    def step(self):
        """One continuous-batching iteration; advances the virtual clock."""
        iter_ms = 0.0
        # 1. admission: new arrivals preempt decoding (paper Fig 2)
        admitted = []
        while self.queue and self._free_row() is not None \
                and self.queue[0].req.arrival_ms <= self.clock:
            st = self.queue.popleft()
            row = self._free_row()
            st.row = row
            self.rows[row] = st
            pinned = [int(s) for s in self._row_idx if s >= 0]
            plan = self.cold.admit(st.req.adapter_uid,
                                   self.clock + iter_ms,
                                   st.req.prompt_len, pinned=pinned)
            if plan is None:     # every device slot pinned: requeue, stop
                self.rows[row] = None
                st.row = -1
                self.queue.appendleft(st)
                break
            st.cold_start = st.cold_start or plan.cold
            st.assist_used = st.assist_used or plan.assist
            iter_ms += plan.blocking_ms + plan.prefill_ms
            st.first_token_ms = self.clock + iter_ms
            st.phase = "decode"
            st._ready_ms = plan.ready_decode_ms
            self._row_idx[row] = plan.slot
            self._row_pos[row] = st.req.prompt_len
            admitted.append((st, plan))
            if self.numerics:
                self._prefill_numerics(st, plan)
            else:
                st.generated.append(0)
                st.token_times_ms.append(st.first_token_ms)

        # 2. one decode iteration over ready rows
        ready = [r for r in self.rows
                 if r is not None and r._ready_ms <= self.clock + iter_ms
                 and not r.done]
        if ready:
            ranks = [self.store.specs[r.req.adapter_uid].rank for r in ready]
            dec_ms = self.tm.base_decode_ms(len(ready), self.avg_ctx) \
                + self.tm.lora_decode_ms(ranks, self.kernel)
            iter_ms += dec_ms
            if self.numerics:
                self._decode_numerics(ready)
            else:
                for r in ready:
                    r.generated.append(0)
            for r in ready:
                r.token_times_ms.append(self.clock + iter_ms)

        # 2b. prefetch: pull the hottest non-resident adapters into free,
        # unpinned slots (upload rides the otherwise-idle host link; it
        # never blocks the iteration)
        if self.prefetch and self._popularity:
            pinned = {int(s) for s in self._row_idx if s >= 0}
            pop = lambda u: self._popularity.get(u, 0.0)
            hot = sorted((u for u in self._popularity
                          if self.pool.lookup(u) is None),
                         key=pop, reverse=True)
            for uid in hot[:4]:           # a few uploads per iteration
                # victim: unpinned slot with the least-popular resident,
                # replaced only on a clear popularity win (hysteresis 1.5x)
                cands = [s for s in range(self.pool.n_slots)
                         if s not in pinned]
                if not cands:
                    break
                victim = min(cands, key=lambda s: pop(self.pool.slot_uid[s])
                             if self.pool.slot_uid[s] else -1.0)
                vu = self.pool.slot_uid[victim]
                if vu is not None and pop(uid) < 1.5 * pop(vu):
                    continue
                w = self.store.weights(uid) if self.numerics else None
                spec = self.store.specs[uid]
                self.pool.slot_uid[victim] = None   # claim the slot
                self.pool.insert(uid, w,
                                 min(spec.rank, self.cfg.lora.max_rank),
                                 pinned=tuple(pinned))

        self.clock += iter_ms if iter_ms > 0 else 0.1   # idle tick
        # 3. retire finished requests
        for row, st in enumerate(self.rows):
            if st is not None and st.done:
                st.finish_ms = st.token_times_ms[-1] if st.token_times_ms \
                    else self.clock
                st.phase = "done"
                self.rows[row] = None
                self._row_idx[row] = -1

    def run(self, requests: List[Request], max_iters: int = 100000):
        """Drive the engine over a trace; returns summary metrics."""
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        i = 0
        iters = 0
        while (i < len(pending) or self.busy()) and iters < max_iters:
            while i < len(pending) and pending[i].arrival_ms <= self.clock:
                self.submit(pending[i])
                i += 1
            if not self.busy() and i < len(pending):
                self.clock = pending[i].arrival_ms   # jump to next arrival
                continue
            self.step()
            iters += 1
        return summarize(self.states)

    # --------------------------------------------------------- numerics ----
    def _free_row(self) -> Optional[int]:
        for i, r in enumerate(self.rows):
            if r is None:
                return i
        return None

    def _mode_str(self):
        return "bgmv" if self.kernel == "bgmv" else "mbgmv"

    def _lora_arg_single(self, uid):
        """Batch-1 lora arg from host weights (CPU-assist path numerics)."""
        w = self.store.weights(uid)
        spec = self.store.specs[uid]
        pool = {t: {"a": jnp.asarray(w[t]["a"])[:, None],
                    "b": jnp.asarray(w[t]["b"])[:, None]} for t in w}
        pool["ranks"] = jnp.full((1,), min(spec.rank, self.cfg.lora.max_rank),
                                 jnp.int32)
        return {"pool": pool, "idx": jnp.zeros((1,), jnp.int32)}

    def _prefill_numerics(self, st: RequestState, plan):
        cfg = self.cfg
        L = st.req.prompt_len
        Lp = min(_bucket(L), self.cache_slots)
        toks = np.zeros((1, Lp), np.int32)
        toks[0, :L] = st.req.prompt
        key = Lp
        if key not in self._prefill_jit:
            self._prefill_jit[key] = jax.jit(functools.partial(
                self._prefill_fn, cfg, self._mode_str(), self.cache_slots))
        lora = self._lora_arg_single(st.req.adapter_uid)
        logits, row_cache = self._prefill_jit[key](
            self.params, jnp.asarray(toks), lora)
        tok = int(sample(logits[:, L - 1])[0])
        row_cache = self._mask_pad_slots(row_cache, L)
        self.cache = cache_lib.scatter_row(self.cache, row_cache, st.row)
        st.generated.append(tok)
        st.token_times_ms.append(st.first_token_ms)
        st._last_token = tok

    @staticmethod
    def _prefill_fn(cfg, mode, cache_slots, params, toks, lora):
        lora = dict(lora, mode=mode)
        return model_lib.prefill(cfg, params, {"tokens": toks}, lora=lora,
                                 cache_slots=cache_slots)

    def _mask_pad_slots(self, row_cache, true_len):
        def fix(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "pos":
                slots = x.shape[-1]
                live = jnp.arange(slots) < true_len
                return jnp.where(live[None], x, -1)
            return x
        return jax.tree_util.tree_map_with_path(fix, row_cache)

    def _decode_numerics(self, ready):
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        live = np.zeros((self.max_batch,), bool)
        idx = self._row_idx.copy()
        for st in ready:
            toks[st.row, 0] = getattr(st, "_last_token", 0)
            pos[st.row] = self._row_pos[st.row]
            live[st.row] = True
        idx[~live] = -1
        lora = {"pool": self.pool.pool, "idx": jnp.asarray(idx, jnp.int32)}
        logits, self.cache = self._decode_jit(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            lora)
        new = np.asarray(sample(logits[:, -1]))
        for st in ready:
            tok = int(new[st.row])
            st.generated.append(tok)
            st._last_token = tok
            self._row_pos[st.row] += 1

    @staticmethod
    def _decode_fn(cfg, mode, params, cache, toks, pos, lora):
        lora = dict(lora, mode=mode)
        return model_lib.decode(cfg, params, cache, toks, pos, lora=lora)
