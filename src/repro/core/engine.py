"""Continuous-batching LoRA serving engine (one inference server, paper
Fig 6), decomposed into three planes:

  * admission — repro.core.admission.AdmissionPlane: row assignment,
    admission policy (arrivals preempt decoding, Fig 2), popularity-EWMA
    prefetch.
  * numerics — repro.core.backend.NumericsBackend: real JAX computation,
    batched multi-request prefill + batched decode over the KV-cache pool
    and the heterogeneous LoRA slot pool (absent for timing-only
    simulations at cluster scale).
  * timeline — this module: the virtual clock advanced by the TimingModel,
    reproducing the paper's profiling-driven methodology (sec 7.5), with
    cold-start/CPU-assist overlap from the asynchronous ColdStartManager /
    LoadTracker (uploads occupy the shared host link over simulated time; a
    load-complete event flips a request from CPU-assist LoRA to the device
    pool mid-flight).

Iteration-level batching (Orca-style, paper sec 2.2): each `step()` admits
queued requests (prefill, possibly cold-starting their adapter per the
engine mode), then runs ONE decode iteration for every ready running
request. Completed requests leave the batch immediately.

Modes: cached | ondemand | slora | caraserve.  Kernels: bgmv | mbgmv.
"""
from __future__ import annotations

import collections
from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core.admission import AdmissionPlane
from repro.core.backend import NumericsBackend, bucket as _bucket
from repro.core.cold_start import ColdStartManager
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.scheduler import select_victim
from repro.core.timing import Hardware, TimingModel, V5E
from repro.models.model import supports_chunked_prefill, supports_paged
from repro.serving.cache import (PageAllocator, boundary_steps,
                                 kv_page_nbytes, pages_for_tokens)
from repro.serving.request import (Request, RequestState, itl_percentiles,
                                   summarize)

IDLE_TICK_MS = 0.1
# window for the preemption-pressure rate routing steers by (simulated ms)
PREEMPT_WINDOW_MS = 2000.0


class InferenceServer:
    def __init__(self, cfg: ModelConfig, *, mode: str = "caraserve",
                 kernel: str = "bgmv", max_batch: int = 8,
                 cache_slots: int = 256, hw: Hardware = V5E,
                 numerics: bool = True, params=None, seed: int = 0,
                 avg_ctx: int = 512, pool_slots: Optional[int] = None,
                 prefetch: bool = False, link_policy: str = "fifo",
                 pipeline: str = "fused", megastep: int = 8,
                 temperature: float = 0.0, staging_slots: int = 16,
                 memory: str = "auto", page_size: int = 32,
                 total_pages: Optional[int] = None,
                 admit_footprint: str = "prompt",
                 preempt: str = "recompute", chunk_budget: int = 0,
                 shed_late_slo: float = 0.0):
        self.cfg = cfg
        self.mode = mode
        self.kernel = kernel
        self.max_batch = max_batch
        self.cache_slots = cache_slots
        self.numerics = numerics
        self.link_policy = link_policy
        self.tm = TimingModel(cfg, hw)
        self.store = HostLoRAStore(cfg)
        n_slots = pool_slots or max(cfg.lora.n_slots, max_batch)
        # memory plane: "paged" = block-table KV + unified KV/LoRA page
        # allocator (fused numerics on families with the uniform layered
        # cache); "dense" = the per-row slab. "auto" picks paged wherever
        # it is supported, dense elsewhere (recurrent/hybrid/enc-dec state,
        # int8 KV, the legacy per-step pipeline, timing-only servers).
        if memory not in ("auto", "paged", "dense"):
            raise ValueError(f"unknown memory plane {memory!r}")
        if memory == "auto":
            memory = "paged" if (numerics and pipeline == "fused"
                                 and supports_paged(cfg)
                                 and cache_slots % page_size == 0) \
                else "dense"
        self.memory = memory
        self.page_size = page_size
        if memory == "paged":
            self.page_bytes = kv_page_nbytes(cfg, page_size)
            # default budget: what the dense layout statically reserved —
            # every row at full depth plus every adapter slot at max rank —
            # so the paged plane admits a superset of the dense workloads;
            # benchmarks shrink `total_pages` to show demand-gated admission
            sizing = AdapterSpec("_sizing", cfg.lora.max_rank, cfg.name)
            ad_pages = max(1, -(-sizing.nbytes(cfg) // self.page_bytes))
            self.allocator = PageAllocator(
                total_pages or max_batch * (cache_slots // page_size)
                + n_slots * ad_pages)
        else:
            self.page_bytes = 0
            self.allocator = None
        self.pool = DevicePool(cfg, n_slots=n_slots, materialize=numerics,
                               allocator=self.allocator,
                               page_bytes=self.page_bytes)
        self.cold = ColdStartManager(self.tm, self.store, self.pool, mode,
                                     link_policy=link_policy)
        # KV over-subscription: admission claims prompt pages only
        # (admit_footprint="prompt"; "full" = PR-5 up-front baseline) and
        # block tables grow lazily; `preempt` picks the victim resolution
        # when the allocator runs dry mid-decode — "swap" saves the KV
        # pages to host and re-uploads through the link scheduler,
        # "recompute" drops them and re-prefills on resume
        if preempt not in ("swap", "recompute"):
            raise ValueError(f"unknown preempt policy {preempt!r}")
        self.preempt_policy = preempt
        # chunked prefill (prefill/decode interference control): prompts
        # longer than `chunk_budget` tokens are fed to the model at most
        # one chunk per decode iteration, piggybacking on the resident
        # batch's step instead of stalling it for a monolithic prefill.
        # 0 disables. The numerics path scatters each chunk's KV into the
        # row's claimed pages, so it needs the paged memory plane.
        if chunk_budget < 0:
            raise ValueError(f"chunk_budget must be >= 0, got {chunk_budget}")
        if chunk_budget and numerics:
            if self.memory != "paged":
                raise ValueError(
                    "chunked prefill needs the paged memory plane "
                    "(memory='paged'): chunks scatter KV into claimed "
                    "pages")
            if not supports_chunked_prefill(cfg):
                raise ValueError(
                    f"model family {cfg.name!r} does not support chunked "
                    "prefill (needs the uniform layered cache, no MoE)")
        self.chunk_budget = chunk_budget
        self.admission = AdmissionPlane(self.cold, self.store, self.pool,
                                        max_batch, prefetch=prefetch,
                                        allocator=self.allocator,
                                        page_size=page_size,
                                        cache_slots=cache_slots,
                                        admit_footprint=admit_footprint,
                                        kv_page_bytes=self.page_bytes,
                                        chunk_budget=chunk_budget,
                                        shed_late_slo=shed_late_slo)
        self.backend = NumericsBackend(
            cfg, kernel=kernel, max_batch=max_batch, cache_slots=cache_slots,
            store=self.store, pool=self.pool, params=params, seed=seed,
            pipeline=pipeline, megastep=megastep, temperature=temperature,
            staging_slots=staging_slots, memory=memory, page_size=page_size,
            allocator=self.allocator) if numerics else None
        self.clock = 0.0
        self.states: List[RequestState] = []
        self.avg_ctx = avg_ctx
        self.prefetch = prefetch
        # preemption / over-subscription telemetry (ServerStats + benches)
        self.preempt_stats = {"preemptions": 0, "swap_preemptions": 0,
                              "recompute_preemptions": 0, "swapped_pages": 0,
                              "recompute_tokens": 0, "grown_pages": 0}
        self._preempt_times: collections.deque = collections.deque()
        self.peak_oversub = 0.0
        # failure-plane telemetry (core/faults.py): crash/drain/adoption
        # counts plus the CPU-assist fault shield's engagement (rows that
        # decoded on the host path while their adapter upload was retrying)
        self.fault_stats = {"crashes": 0, "restarts": 0,
                            "drained_requests": 0, "adopted_requests": 0,
                            "assist_shield_rows": 0,
                            "assist_shield_tokens": 0}

    # ----------------------------------------------------------- views ----
    @property
    def queue(self):
        return self.admission.queue

    @property
    def rows(self):
        return self.admission.rows

    @property
    def params(self):
        return self.backend.params if self.backend else None

    # ----------------------------------------------------------- public ----
    def register_adapter(self, spec: AdapterSpec):
        self.store.register(spec, materialize=self.numerics,
                            now_ms=self.clock)

    def install_adapter(self, spec: AdapterSpec,
                        now_ms: Optional[float] = None):
        """Late registration on a live server (the cluster's
        register-on-miss / rebalance paths): the adapter joins the host
        store mid-run, stamped with the event time (`store.registered_ms`;
        the server's own clock can lag the cluster event that triggered
        the install). Its device upload happens on first admission through
        the normal cold-start machinery. Idempotent."""
        if spec.uid not in self.store:
            self.store.register(spec, materialize=self.numerics,
                                now_ms=max(self.clock, now_ms or 0.0))

    def submit(self, req: Request) -> RequestState:
        if self.memory == "paged":
            # page-gated admission: reject demands the pool can never meet
            # (temporary exhaustion merely defers the admission instead)
            width = self.cache_slots // self.page_size
            need_prompt = -(-req.prompt_len // self.page_size)
            if need_prompt > width:
                raise ValueError(
                    f"request {req.rid}: prompt needs {need_prompt} KV "
                    f"pages but a row's block table holds {width} pages "
                    f"({self.cache_slots} slots at page_size "
                    f"{self.page_size}); raise cache_slots or truncate "
                    "the prompt before submitting")
            # decoding needs the KV pages AND the adapter's pages resident
            # simultaneously — a demand above the whole pool can never be
            # admitted (it would spin in the queue forever, not defer)
            need = self.kv_page_demand(req)
            spec = self.store.specs.get(req.adapter_uid)
            ad_need = self.pool.pages_for(spec.nbytes(self.cfg)) \
                if spec is not None else 0
            if need + ad_need > self.allocator.n_pages:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV pages plus "
                    f"{ad_need} adapter pages but the unified page pool "
                    f"holds {self.allocator.n_pages} in total; raise "
                    "total_pages or shrink the request")
        elif self.backend is not None and req.prompt_len > self.cache_slots:
            raise ValueError(
                f"request {req.rid}: prompt is {req.prompt_len} tokens but "
                f"each KV-cache row holds {self.cache_slots} slots; raise "
                "cache_slots or truncate the prompt before submitting")
        st = RequestState(req)
        self.states.append(st)
        self.admission.enqueue(st)
        return st

    def kv_page_demand(self, req: Request) -> int:
        """Pages this request would claim at admission (0 on dense)."""
        return self.admission.kv_pages_needed(req)

    def free_pages(self) -> Optional[int]:
        """Free pages in the unified KV/LoRA pool (None on dense) — the
        scheduler's memory-demand steering signal."""
        return self.allocator.free_pages if self.allocator else None

    def oversub_ratio(self) -> float:
        """Admitted lifetime KV demand over the capacity left for KV in
        the unified pool (total minus resident adapter pages): > 1.0 means
        the running batch's full footprints no longer fit simultaneously
        and mid-decode preemption is possible (0.0 on dense)."""
        if self.allocator is None:
            return 0.0
        demand = sum(self.admission.kv_pages_needed(r.req)
                     for r in self.rows if r is not None)
        cap = self.allocator.n_pages \
            - len(self.allocator.owned_by("adapter:"))
        return demand / max(cap, 1)

    def preempt_pressure(self, now_ms: Optional[float] = None) -> float:
        """Recent preemptions per simulated second (window
        PREEMPT_WINDOW_MS) — the routing signal that steers arrivals away
        from a thrashing pool without penalizing old history forever."""
        now = self.clock if now_ms is None else max(now_ms, self.clock)
        while self._preempt_times and \
                self._preempt_times[0] < now - PREEMPT_WINDOW_MS:
            self._preempt_times.popleft()
        return len(self._preempt_times) / (PREEMPT_WINDOW_MS / 1e3)

    def busy(self) -> bool:
        return self.admission.busy()

    def running_ranks(self) -> List[int]:
        return [self.store.specs[r.req.adapter_uid].rank
                for r in self.rows if r is not None]

    def decode_commit_tokens(self) -> int:
        """Output tokens the resident batch is still committed to produce
        — the depth of decode work a newly routed prefill would interfere
        with. The cluster's cost model uses it to steer long prompts away
        from servers with deep resident decode batches."""
        return sum(max(r.req.max_new_tokens - r.issued, 0)
                   for r in self.rows if r is not None)

    def itl_samples(self) -> List[float]:
        """Every inter-token gap observed so far, across all requests."""
        return [g for s in self.states for g in s.itl_ms()]

    def itl_stats(self) -> dict:
        return itl_percentiles(self.itl_samples())

    def loading_ranks(self) -> List[int]:
        """Ranks of adapters whose *demand-class* upload is still on the
        host link — the scheduler's view of in-flight cold starts. This
        includes prefetches promoted by a demand admission (a request now
        rides them). Pure speculative prefetch uploads occupy the link
        (link_busy_ms) but have no request attached, so they never join the
        decode batch on their own and are excluded here."""
        return [self.store.specs[e.uid].rank
                for e in self.cold.tracker.inflight
                if e.demand and e.uid in self.store.specs]

    def link_busy_ms(self) -> float:
        """Queueing delay a new demand upload would face past `clock`:
        earliest-free-lane time after the uploads the link policy schedules
        ahead of it (fifo: everything inflight; priority/preempt: demand
        class only — queued prefetch is jumped)."""
        return max(0.0, self.cold.tracker.link_busy_until_ms() - self.clock)

    def next_event_ms(self) -> Optional[float]:
        """Earliest future time at which this server can make progress
        (queued arrival, decode-ready request, or load completion)."""
        cands = []
        if self.queue:
            cands.append(self.queue[0].req.arrival_ms)
        for r in self.rows:
            if r is not None and not r.done:
                cands.append(r.ready_ms)
        nf = self.cold.tracker.next_finish_ms()
        if nf is not None:
            cands.append(nf)
        future = [t for t in cands if t > self.clock]
        return min(future) if future else None

    # ------------------------------------------------------ one iteration ----
    def step(self, horizon_ms: Optional[float] = None):
        """One continuous-batching iteration; advances the virtual clock.
        When the iteration is empty (everything waits on a future event) the
        clock jumps to the next actionable time, clamped to `horizon_ms`
        (the caller's next arrival) so admissions are never skipped over."""
        # 0. uploads finished by now land (queued for the flip below)
        self.cold.poll(self.clock)

        # 1. admission: new arrivals preempt decoding (paper Fig 2);
        # preempted requests at the queue front resume (swap-in/recompute)
        admitted, iter_ms = self._admit_pass()
        # every completion retired above or inside admit(), exactly once
        self._flip(self.cold.drain_completions())

        # 1b. re-derive decode gates from the live link schedule: queued
        # finish times move on every insertion/promotion/cancellation, so a
        # ready/finish stamp captured at admit() time can go stale in either
        # direction (a promoted prefetch may land earlier; a later demand
        # may jump a queued promoted upload and push it back). Every row
        # with a pending upload is re-gated — not just phase "loading":
        # a rider admitted when the provisional finish fell inside its
        # prefill window starts in phase "decode" yet can still be jumped.
        # Exact no-op under fifo (finish times never move after begin()).
        rows = self.admission.rows
        for st in rows:
            if st is None or st.done:
                continue
            if st.first_token_ms is None and st.phase != "prefill":
                continue
            # a resumed row's KV swap-in is link traffic too: its queued
            # finish is as provisional as an adapter upload's
            kev = self.cold.tracker.pending_for(f"kvswap:{st.req.rid}") \
                if st.kv_resume_ms > 0.0 else None
            if kev is not None:
                st.kv_resume_ms = kev.finish_ms
                st.ready_ms = max(st.ready_ms, kev.finish_ms)
            ev = self.cold.tracker.pending_for(st.req.adapter_uid)
            if ev is not None:
                st.load_finish_ms = ev.finish_ms
                if st.phase != "prefill":
                    if ev.attempt > 0 and self.mode == "caraserve":
                        # degraded-mode fault shield (core/faults.py): the
                        # adapter upload failed and is mid-retry. Instead
                        # of stalling until a retry lands, decode rides
                        # the CPU-assist path — the host computes the
                        # per-token x·A·B exactly as during an assisted
                        # prefill — and _flip returns the row to the
                        # device path when an attempt succeeds.
                        if not st.assist_decode:
                            st.assist_decode = True
                            st.assist_used = True
                            self.fault_stats["assist_shield_rows"] += 1
                        st.ready_ms = max(st.first_token_ms,
                                          st.kv_resume_ms)
                    else:
                        # a chunking row's ready_ms gates its *chunks*, not
                        # decode — the final chunk re-derives the decode
                        # gate
                        st.ready_ms = max(st.first_token_ms, ev.finish_ms,
                                          st.kv_resume_ms)
            elif st.assist_decode:
                st.assist_decode = False   # upload landed or was canceled

        # 2. decode over ready rows: a megastep of K fused iterations when
        # the event horizon allows, else one iteration. First, lazy
        # block-table growth: any ready row whose next write crosses a page
        # boundary claims its page now — and if the allocator is dry, the
        # victim policy preempts rows to make room (possibly shrinking the
        # ready set).
        # 2a. chunked prefill interleave: the oldest ready chunking row is
        # fed at most `chunk_budget` prompt tokens this iteration, riding
        # the decode step (piggyback batching) — its chunk pages are
        # claimed here, chunk-by-chunk, with the same victim fallback as
        # lazy decode growth. Rows in phase "prefill" never decode.
        chunk_st, chunk_n = self._plan_chunk(iter_ms)
        ready = [r for r in rows
                 if r is not None and r.phase != "prefill"
                 and r.ready_ms <= self.clock + iter_ms
                 and not r.done]
        for r in ready:
            if r.phase == "loading":
                r.phase = "decode"
        ready = self._ensure_pages(ready)
        if chunk_st is not None and chunk_st.row < 0:
            chunk_st, chunk_n = None, 0   # preempted by decode growth above
        if ready:
            plan = self._plan_megastep(ready, horizon_ms) \
                if (self.backend and not admitted and iter_ms == 0.0
                    and chunk_st is None) \
                else None
            if plan is not None:
                K, nsteps, per_iter = plan
                self.backend.megastep(ready, nsteps, K,
                                      self.admission.row_slot,
                                      self.admission.row_pages)
                # bill exactly like K single steps: the batch shrinks as
                # rows hit their stop target, each surviving row gets its
                # token timestamp at that iteration's end
                t = self.clock
                for k in range(K):
                    t += per_iter[k]
                    for r, n in zip(ready, nsteps):
                        if n > k:
                            r.token_times_ms.append(t)
                            self.admission.row_pos[r.row] += 1
                iter_ms += sum(per_iter)
            else:
                # rows on the CPU-assist fault shield take their LoRA
                # delta from the host (their adapter upload is retrying):
                # the device kernel only serves the healthy rows, the host
                # GEMV runs concurrently, and the iteration pays the
                # slower of the two paths
                ranks = [self.store.specs[r.req.adapter_uid].rank
                         for r in ready if not r.assist_decode]
                cpu_ranks = [self.store.specs[r.req.adapter_uid].rank
                             for r in ready if r.assist_decode]
                if chunk_st is not None:
                    # mixed iteration: one device call carries the decode
                    # batch AND the prefill chunk — one step overhead, the
                    # chunk's compute hides under the memory-bound decode
                    dev_ms = self.tm.mixed_step_ms(
                        len(ready), self.avg_ctx, chunk_n,
                        chunk_st.prefill_pos) \
                        + self.tm.lora_decode_ms(ranks, self.kernel) \
                        + self._chunk_lora_ms(chunk_st, chunk_n)
                else:
                    dev_ms = self.tm.base_decode_ms(len(ready),
                                                    self.avg_ctx) \
                        + self.tm.lora_decode_ms(ranks, self.kernel)
                dec_ms = max(dev_ms, self.tm.cpu_lora_decode_ms(cpu_ranks))
                if cpu_ranks:
                    self.fault_stats["assist_shield_tokens"] += \
                        len(cpu_ranks)
                iter_ms += dec_ms
                if self.backend:
                    self.backend.decode(ready, self.admission.row_slot,
                                        self.admission.row_pos,
                                        self.admission.row_pages)
                else:
                    for r in ready:
                        r.generated.append(0)
                for r in ready:
                    r.token_times_ms.append(self.clock + iter_ms)
                    self.admission.row_pos[r.row] += 1
        elif chunk_st is not None:
            # no decode batch to ride: the chunk runs alone this iteration
            iter_ms += self.tm.chunk_prefill_ms(chunk_n,
                                                chunk_st.prefill_pos) \
                + self._chunk_lora_ms(chunk_st, chunk_n)
        if chunk_st is not None:
            self._run_chunk(chunk_st, chunk_n, self.clock + iter_ms)

        # 2b. prefetch rides the otherwise-idle host link asynchronously
        self.admission.prefetch_tick(self.clock + iter_ms)

        # 3. advance the virtual clock
        if iter_ms > 0:
            self.clock += iter_ms
        else:
            nxt = self.next_event_ms()
            if horizon_ms is not None:
                nxt = min(nxt, horizon_ms) if nxt is not None else horizon_ms
            self.clock = nxt if nxt is not None and nxt > self.clock \
                else self.clock + IDLE_TICK_MS

        # 4. retire finished requests
        for row, st in enumerate(rows):
            if st is not None and st.done:
                st.finish_ms = st.token_times_ms[-1] if st.token_times_ms \
                    else self.clock
                st.phase = "done"
                self.admission.release(row)

        # 4b. pages freed this step (retires, preemptions, adapter sheds —
        # the allocator's on_free hook sets the flag) un-defer queued work
        # immediately instead of waiting for the next step's admit attempt
        if self.allocator is not None and self.admission.pages_freed \
                and self.queue:
            admitted2, extra_ms = self._admit_pass()
            self._flip(self.cold.drain_completions())
            if extra_ms > 0:
                self.clock += extra_ms
            for st, _ in admitted2:      # prefill-only requests can finish
                if st.done and st.row >= 0:
                    st.finish_ms = st.token_times_ms[-1] \
                        if st.token_times_ms else self.clock
                    st.phase = "done"
                    self.admission.release(st.row)

    def _admit_pass(self):
        """Run the admission plane and dispatch its outcomes to the
        numerics backend: batched prefill for fresh admissions and
        recompute resumes (one padded call rebuilds a preempted row's KV
        bitwise), page re-upload for swap resumes."""
        admitted, iter_ms = self.admission.admit(self.clock)
        if admitted and self.allocator is not None:
            self.peak_oversub = max(self.peak_oversub, self.oversub_ratio())
        if admitted:
            resumes = [st for st, _ in admitted if st.preempted]
            fresh = [st for st, _ in admitted if not st.preempted]
            # chunking admissions (phase "prefill") run no prefill here:
            # the interleaver feeds their chunks per-iteration. Fresh ones
            # just need their claimed pages scrubbed; swap resumes restore
            # the written chunk prefix byte-for-byte (pages only — there
            # is no sampled token to re-seed the decode pipeline with).
            chunking = [st for st, _ in admitted if st.phase == "prefill"]
            if self.backend:
                swaps = [st for st in resumes if st.resume_kind == "swap"
                         and st.phase != "prefill"]
                recs = [st for st in resumes if st.resume_kind != "swap"
                        and st.phase != "prefill"]
                mono = [st for st in fresh if st.phase != "prefill"]
                if swaps:
                    self.backend.swap_in(swaps, self.admission.row_pages)
                for st in chunking:
                    if st.swap_payload is not None:
                        self.backend.restore_pages(st)
                    elif st.kv_pages:
                        self.backend.clear_pages(st.kv_pages)
                if mono or recs:
                    self.backend.prefill_admitted(mono + recs)
            else:
                for st in fresh:
                    if st.phase == "prefill":
                        continue    # first token arrives with the final chunk
                    st.generated.append(0)
                    st.token_times_ms.append(st.first_token_ms)
            for st in resumes:
                st.preempted = False
                st.resume_kind = ""
                st.swap_payload = None
        return admitted, iter_ms

    def _ensure_pages(self, ready):
        """Lazy block-table growth for this iteration's decode writes.
        Each ready row whose ring position has crossed into an unclaimed
        logical page claims one page (scrubbed before use — it may carry a
        previous tenant's slots). When the allocator is dry even after
        shedding cold adapter pages, `select_victim` preempts running rows
        (LRU-by-last-token, SLO-aware tiebreak) until the claim succeeds;
        a row that still cannot grow stalls this iteration. Returns the
        rows that can actually decode (growers minus preempted victims)."""
        if self.allocator is None:
            return ready
        adm = self.admission
        width = self.cache_slots // self.page_size
        preempted: set = set()
        stalled: set = set()
        for st in ready:
            if id(st) in preempted:
                continue
            while True:
                steps = boundary_steps(int(adm.row_pos[st.row]),
                                       len(adm.row_pages[st.row]),
                                       self.page_size, width)
                if steps is None or steps > 0:
                    break
                ids = adm.grow_row(st.row)
                if ids is not None:
                    self.preempt_stats["grown_pages"] += len(ids)
                    if self.backend:
                        self.backend.clear_pages(ids)
                    continue
                # allocator dry: preempt a victim (never the grower, never
                # a row mid-restore) and retry the claim
                cands = [r for r in adm.rows
                         if r is not None and r.phase != "loading"
                         and adm.row_pages[r.row]]
                victim = select_victim(cands, exclude=(st,))
                if victim is None:
                    stalled.add(id(st))
                    break
                preempted.add(id(victim))
                self._preempt(victim)
        return [r for r in ready
                if id(r) not in preempted and id(r) not in stalled]

    def _plan_chunk(self, iter_ms: float):
        """Pick this iteration's prefill chunk: the oldest row in phase
        "prefill" whose gate (swap-in link, blocking load) has passed gets
        min(chunk_budget, remaining prompt) tokens. Claims the chunk's KV
        pages first — chunk-by-chunk over-subscription with the same
        victim fallback as lazy decode growth. Returns (row, n_tokens) or
        (None, 0) when nothing is chunking (or the allocator stays dry:
        the chunk stalls this iteration and retries when pages free)."""
        if self.chunk_budget <= 0:
            return None, 0
        cands = [r for r in self.admission.rows
                 if r is not None and r.phase == "prefill" and not r.done
                 and r.ready_ms <= self.clock + iter_ms]
        if not cands:
            return None, 0
        st = min(cands, key=lambda r: r.req.rid)
        n = min(self.chunk_budget, st.req.prompt_len - st.prefill_pos)
        if not self._ensure_chunk_pages(st, st.prefill_pos + n):
            return None, 0
        return st, n

    def _ensure_chunk_pages(self, st: RequestState, upto_tokens: int) -> bool:
        """Grow the chunking row's block table to cover `upto_tokens`
        prompt slots before the chunk's KV scatter lands, page by page,
        shedding cold adapters and preempting victims when the unified
        pool runs dry (never the chunking row itself). False = stall."""
        if self.allocator is None:
            return True
        adm = self.admission
        need = pages_for_tokens(min(upto_tokens, self.cache_slots),
                                self.page_size)
        while len(adm.row_pages[st.row]) < need:
            ids = adm.grow_row(st.row)
            if ids is not None:
                self.preempt_stats["grown_pages"] += len(ids)
                if self.backend:
                    self.backend.clear_pages(ids)
                continue
            cands = [r for r in adm.rows
                     if r is not None and r.phase != "loading"
                     and adm.row_pages[r.row]]
            victim = select_victim(cands, exclude=(st,))
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _chunk_lora_ms(self, st: RequestState, n: int) -> float:
        spec = self.store.specs.get(st.req.adapter_uid)
        return self.tm.lora_prefill_gpu_ms(n, spec.rank) if spec else 0.0

    def _run_chunk(self, st: RequestState, n: int, t_end: float):
        """Execute/bill one prefill chunk for `st`, landing at `t_end`
        (this iteration's end). The final chunk samples the first token
        and transitions the row toward decode, gated on any pending
        adapter upload or KV swap-in exactly like a monolithic
        admission."""
        adm = self.admission
        start = st.prefill_pos
        final = start + n >= st.req.prompt_len
        if self.backend:
            self.backend.prefill_chunk(st, adm.row_pages[st.row], start, n,
                                       final)
        st.prefill_pos = start + n
        if not final:
            return
        st.first_token_ms = t_end
        st.token_times_ms.append(t_end)
        if not self.backend:
            st.generated.append(0)
        adm.row_pos[st.row] = st.req.prompt_len
        lf = st.load_finish_ms if st.load_finish_ms is not None else 0.0
        st.ready_ms = max(t_end, lf, st.kv_resume_ms)
        st.phase = "decode" if st.ready_ms <= t_end else "loading"

    def _preempt(self, st: RequestState):
        """Evict a running row to free its KV pages. The swap path copies
        the pages to host first (restored byte-for-byte on resume via the
        link scheduler); the recompute path drops them and re-prefills
        prompt + generated-so-far on resume — token-for-token identical
        either way, since greedy resampling of a replayed prefix
        reproduces it. A row whose ring has wrapped past `cache_slots`
        cannot be replayed by the padded prefill path, so recompute falls
        back to swap for it. The victim re-enters at the queue *front*:
        resumes beat fresh arrivals (S-LoRA's preemptive scheduling)."""
        adm = self.admission
        row = st.row
        if self.backend:
            self.backend.flush_readback()   # `generated` must be complete
        kind = self.preempt_policy
        # a half-prefilled (chunking) row has no decode position yet: its
        # written KV is the chunk prefix. Swap preserves chunk progress
        # (`prefill_pos` survives, resume restores the written pages and
        # chunking continues where it left off); recompute simply restarts
        # the prompt as a fresh chunked admission.
        chunking = st.phase == "prefill"
        pos = st.prefill_pos if chunking else int(adm.row_pos[row])
        if kind == "recompute" and pos > self.cache_slots:
            kind = "swap"
        if chunking and pos == 0:
            kind = "recompute"       # nothing written: plain re-admission
        st.resume_pos = pos
        # only pages with written slots travel: a freshly grown page the
        # row never wrote into (preempted at the boundary) is dropped —
        # the resume claim re-requests exactly the written prefix, and
        # growth re-claims the boundary page when decode reaches it again
        keep = -(-min(pos, self.cache_slots) // self.page_size)
        pages = list(adm.row_pages[row])[:keep]
        if kind == "swap":
            if self.backend and pages:
                st.swap_payload = self.backend.swap_out(pages)
            self.preempt_stats["swap_preemptions"] += 1
            self.preempt_stats["swapped_pages"] += len(pages)
        else:
            self.preempt_stats["recompute_preemptions"] += 1
            self.preempt_stats["recompute_tokens"] += \
                min(pos, self.cache_slots)
            if chunking:
                st.prefill_pos = 0
                st.resume_pos = 0
        adm.release(row)                    # frees pages, fires on_free
        st.kv_pages = []
        st.row = -1
        st.phase = "queued"
        # a recompute-dropped chunking row is a *fresh* chunked admission,
        # not a resume: nothing of it survives on device
        st.preempted = not (chunking and kind != "swap")
        st.resume_kind = "" if (chunking and kind != "swap") else kind
        st.preemptions += 1
        self.preempt_stats["preemptions"] += 1
        self._preempt_times.append(self.clock)
        adm.queue.appendleft(st)

    def _plan_megastep(self, ready, horizon_ms):
        """Choose K >= 2 decode iterations to fuse into one device call
        (`NumericsBackend.megastep`). Eligible only when the window
        provably contains no event single-step execution would have acted
        on: no queued arrival before the window end (nor the caller's
        horizon), no upload completion (a flip or a ready transition), no
        live row outside the ready set, and prefetch disabled (its
        per-iteration tick would drift against the single-step timeline).
        Returns (K, nsteps, per_iter_ms) — nsteps[i] is the tokens row i
        actually produces before its stop target freezes it — or None."""
        be = self.backend
        if be is None or be.pipeline != "fused" or be.megastep_max < 2:
            return None
        if self.prefetch or self.queue:
            return None
        live = [r for r in self.admission.rows
                if r is not None and not r.done]
        if any(r.phase == "prefill" for r in live):
            return None      # in-flight chunked prefill = boundary event
        if len(live) != len(ready):
            return None      # a loading row could become ready mid-window
        if any(r.assist_decode for r in ready):
            return None      # fault-shield rows flip event-by-event
        steps_left = [r.req.max_new_tokens - r.issued for r in ready]
        cap = min(be.megastep_max, max(steps_left))
        if self.allocator is not None:
            # lazy block tables: the window must end at the nearest
            # boundary-claim event — a row writing into an unclaimed page
            # mid-scan would corrupt the OOB-drop invariant. Rows that
            # finish before their boundary impose no bound.
            width = self.cache_slots // self.page_size
            for r, s in zip(ready, steps_left):
                b = boundary_steps(int(self.admission.row_pos[r.row]),
                                   len(self.admission.row_pages[r.row]),
                                   self.page_size, width)
                if b is not None and b < s:
                    cap = min(cap, b)
        if cap < 2:
            return None
        limit = horizon_ms if horizon_ms is not None else float("inf")
        nf = self.cold.tracker.next_finish_ms()
        if nf is not None:
            limit = min(limit, nf)
        # bill forward with the batch shrinking as rows finish (identical
        # to K single steps); stop at the first iteration that would cross
        # an event. An event exactly at the window end is fine — the next
        # step() acts on it at the same clock single-stepping would.
        per_iter = []
        t = self.clock
        for k in range(cap):
            batch_ranks = [self.store.specs[r.req.adapter_uid].rank
                           for r, s in zip(ready, steps_left) if s > k]
            d = self.tm.base_decode_ms(len(batch_ranks), self.avg_ctx) \
                + self.tm.lora_decode_ms(batch_ranks, self.kernel)
            if t + d > limit:
                break
            t += d
            per_iter.append(d)
        K = 1
        while K * 2 <= len(per_iter):
            K *= 2               # power-of-two K bounds scan compilations
        if K < 2:
            return None
        return K, [min(s, K) for s in steps_left], per_iter[:K]

    def _flip(self, events):
        """Load-complete events switch in-flight requests of that adapter
        from the CPU-assist LoRA path to the device pool (paper Fig 1/7)."""
        if not events:
            return
        for ev in events:
            for st in self.rows:
                if st is None or st.req.adapter_uid != ev.uid:
                    continue
                if st.assist_used and st.flip_ms is None:
                    st.flip_ms = ev.finish_ms
                st.assist_decode = False   # retry landed: back on device
                if st.phase == "loading":
                    st.phase = "decode"

    # ---------------------------------------------------- failure plane ----
    def _drain_row(self, st: RequestState, row: int):
        """Strip a live row off the dead device with a forced
        drop-and-recompute resume plan — swap is impossible, the KV pages
        died with the device. Mirrors `_preempt`'s recompute branch: the
        adopting server replays prompt + generated-so-far through the
        PR-6 machinery, token-for-token. A ring-wrapped row
        (pos > cache_slots) can only replay the ring depth — a documented
        parity limitation of crash recovery (the chaos benches keep
        outputs inside the ring). A half-prefilled chunking row restarts
        as a fresh chunked admission (its chunk prefix is gone)."""
        adm = self.admission
        chunking = st.phase == "prefill"
        pos = st.prefill_pos if chunking else int(adm.row_pos[row])
        st.resume_pos = pos
        if chunking:
            st.prefill_pos = 0
            st.resume_pos = 0
        adm.release(row)
        st.kv_pages = []
        st.row = -1
        st.phase = "queued"
        st.swap_payload = None
        st.kv_resume_ms = 0.0
        st.assist_decode = False
        st.load_finish_ms = None
        st.ready_ms = 0.0
        st.preempted = not chunking and pos > 0
        st.resume_kind = "recompute" if st.preempted else ""

    def crash(self, now_ms: float) -> List[RequestState]:
        """Fail-stop loss of this server's device state at `now_ms`
        (core/faults.py). Uploads already finished by the crash land
        first (they genuinely completed); everything else on the device
        dies — KV pages, the adapter pool, in-flight and queued uploads
        (canceled; LinkSan holds canceled uploads to never retire). Every
        queued and in-flight request is drained and returned for the
        cluster to re-admit on survivors. Tokens billed at iteration
        boundaries before the crash are kept (the crash lands between
        iterations — the simulator's granularity); `flush_readback` makes
        `generated` complete for the replay. The host store survives —
        host memory outlives the device in this failure model — and
        `restart` decides what to re-warm."""
        t = max(now_ms, self.clock)
        self.clock = t
        self.cold.poll(t)
        self._flip(self.cold.drain_completions())
        if self.backend:
            self.backend.flush_readback()   # `generated` must be complete
        adm = self.admission
        drained: List[RequestState] = []
        for row, st in enumerate(adm.rows):
            if st is None:
                continue
            if st.done:
                # full output already produced: retire, nothing to recover
                st.finish_ms = st.token_times_ms[-1] \
                    if st.token_times_ms else t
                st.phase = "done"
                adm.release(row)
                continue
            self._drain_row(st, row)
            drained.append(st)
        while adm.queue:
            st = adm.queue.popleft()
            st.row = -1
            drained.append(st)
        # the link dies with the device: cancel every upload, release the
        # canceled reservations, then evict every (ready) resident
        for ev in self.cold.tracker.cancel_all():
            if ev.slot >= 0 and not self.pool.slot_ready[ev.slot]:
                self.pool.release(ev.slot)
        for s in range(self.pool.n_slots):
            if self.pool.slot_uid[s] is not None:
                self.pool.evict(s)
        # drained requests leave this server's ledger entirely — they
        # complete (or are shed) on whichever server adopts them
        gone = set(id(s) for s in drained)
        self.states = [s for s in self.states if id(s) not in gone]
        self.fault_stats["crashes"] += 1
        self.fault_stats["drained_requests"] += len(drained)
        return drained

    def restart(self, now_ms: float):
        """Bring a crashed server back at `now_ms`: the device starts
        empty and cold. The cluster re-registers its placement-hosted
        adapters and warms the hottest through the normal prefetch path
        (warm rejoin, not cold); host store and telemetry survive."""
        self.clock = max(self.clock, now_ms)
        self.fault_stats["restarts"] += 1

    def adopt(self, st: RequestState, now_ms: float):
        """Admit a request drained from a crashed replica: the state —
        with its emitted tokens and recompute resume plan — joins this
        server's timeline. A resume re-enters at the queue *front*
        (resumes beat fresh arrivals, exactly as with preemption); a
        request that was still queued on the victim lines up normally."""
        if st.req.adapter_uid not in self.store:
            raise LookupError(
                f"adopting server does not host adapter "
                f"{st.req.adapter_uid!r} — the cluster must install it "
                "first (register-on-miss)")
        self.clock = max(self.clock, now_ms)
        self.states.append(st)
        st.row = -1
        if st.preempted:
            self.admission.queue.appendleft(st)
        else:
            self.admission.enqueue(st)
        self.fault_stats["adopted_requests"] += 1

    def run(self, requests: List[Request], max_iters: int = 100000):
        """Drive the engine over a trace; returns summary metrics."""
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        i = 0
        iters = 0
        while (i < len(pending) or self.busy()) and iters < max_iters:
            while i < len(pending) and pending[i].arrival_ms <= self.clock:
                self.submit(pending[i])
                i += 1
            if not self.busy() and i < len(pending):
                self.clock = pending[i].arrival_ms   # jump to next arrival
                continue
            horizon = pending[i].arrival_ms if i < len(pending) else None
            self.step(horizon_ms=horizon)
            iters += 1
        if self.backend:
            self.backend.flush_readback()   # drain async token readbacks
        return summarize(self.states)
