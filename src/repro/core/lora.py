"""LoRA adapters: specs, weight synthesis, the device slot pool, and the
batched delta computation (ref path; Pallas BGMV/MBGMV kernels in
repro.kernels are the TPU-target equivalents).

Semantics shared by all paths: the pool stores A/B padded with zeros beyond
each adapter's true rank, so the padding path (BGMV: compute r_max) and the
rank-block-skip path (MBGMV: compute ceil(rank/rank_block) blocks) produce
identical numerics — only their cost differs (max-rank law vs sum-rank law,
paper sec 2.3/ sec 5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.param import Box


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    uid: str
    rank: int
    base_model: str
    seed: int = 0

    def nbytes(self, cfg: ModelConfig) -> int:
        """Host->device upload size of this adapter (bf16)."""
        total = 0
        for tgt in cfg.lora.targets:
            d_in, d_out = lora_target_dims(cfg, tgt)
            total += (d_in + d_out) * self.rank
        n_blocks = cfg.n_layers + cfg.n_enc_layers
        return total * n_blocks * 2


def lora_target_dims(cfg: ModelConfig, target: str) -> Tuple[int, int]:
    d = cfg.d_model
    if target == "q":
        return d, cfg.n_heads * cfg.hd
    if target in ("k", "v"):
        return d, cfg.n_kv_heads * cfg.hd
    if target == "in_proj":              # mamba2: full in-projection
        s = cfg.ssm
        d_in_total = 2 * s.expand * d + 2 * s.n_groups * s.state_dim \
            + (s.expand * d) // s.head_dim
        return d, d_in_total
    if target == "out_proj":
        return cfg.ssm.expand * d, d
    raise ValueError(target)


def make_adapter_weights(cfg: ModelConfig, spec: AdapterSpec,
                         dtype=None) -> Dict[str, Dict[str, np.ndarray]]:
    """Synthesize adapter weights (paper uses dummy weights, sec 7.1 footnote;
    numerics still exercise the full pipeline). Padded to max_rank with zeros.
    Returns {target: {a: (L, d_in, r_max), b: (L, r_max, d_out)}} on host."""
    dtype = dtype or cfg.jdtype
    r_max = cfg.lora.max_rank
    L = cfg.n_layers + cfg.n_enc_layers
    rng = np.random.default_rng(abs(hash((spec.uid, spec.seed))) % (2 ** 31))
    r = min(spec.rank, r_max)      # pool is sized for max_rank
    out = {}
    for tgt in cfg.lora.targets:
        d_in, d_out = lora_target_dims(cfg, tgt)
        a = np.zeros((L, d_in, r_max), np.float32)
        b = np.zeros((L, r_max, d_out), np.float32)
        a[:, :, :r] = rng.normal(0, d_in ** -0.5, (L, d_in, r))
        b[:, :r, :] = rng.normal(0, r ** -0.5, (L, r, d_out))
        out[tgt] = {"a": a.astype(dtype), "b": b.astype(dtype)}
    return out


# ------------------------------------------------------------- pool ----

def pool_abstract(cfg: ModelConfig, n_slots: Optional[int] = None):
    """Box tree of the device LoRA slot pool (for init / dry-run shapes)."""
    r_max, slots = cfg.lora.max_rank, n_slots or cfg.lora.n_slots
    L = cfg.n_layers + cfg.n_enc_layers
    pool = {}
    for tgt in cfg.lora.targets:
        d_in, d_out = lora_target_dims(cfg, tgt)
        pool[tgt] = {
            "a": Box(jax.ShapeDtypeStruct((L, slots, d_in, r_max), cfg.jdtype),
                     ("layers", "slots", "lora_in", "lora_rank")),
            "b": Box(jax.ShapeDtypeStruct((L, slots, r_max, d_out), cfg.jdtype),
                     ("layers", "slots", "lora_rank", "qkv")),
        }
    pool["ranks"] = Box(jax.ShapeDtypeStruct((slots,), jnp.int32), ("slots",))
    return pool


def pool_init(cfg: ModelConfig, n_slots: Optional[int] = None):
    """Zero-initialized device pool (values only)."""
    ab = pool_abstract(cfg, n_slots)
    return jax.tree.map(lambda b: jnp.zeros(b.value.shape, b.value.dtype),
                        ab, is_leaf=lambda x: isinstance(x, Box))


def pool_insert(pool, cfg, weights, slot: int, rank: int):
    """Functionally write adapter weights into device slot `slot`."""
    new = dict(pool)
    for tgt, ab in weights.items():
        new[tgt] = {
            "a": pool[tgt]["a"].at[:, slot].set(jnp.asarray(ab["a"])),
            "b": pool[tgt]["b"].at[:, slot].set(jnp.asarray(ab["b"])),
        }
    new["ranks"] = pool["ranks"].at[slot].set(rank)
    return new


# ------------------------------------------------- batched delta (ref) ----

def lora_delta_ref(x, a, b, idx, *, ranks=None, mode="bgmv", rank_block=16,
                   scale=1.0):
    """Batched heterogeneous-rank LoRA delta, pure-jnp oracle.

    x: (B, T, d_in); a: (slots, d_in, r_max); b: (slots, r_max, d_out);
    idx: (B,) slot per request (-1 = no adapter -> zero delta).

    mode="bgmv": pad-to-max semantics (compute all r_max columns).
    mode="mbgmv": rank-block masking — only ceil(rank/block) blocks computed;
      numerically identical because the pool is zero-padded, but models the
      sum-rank cost law. The mask also guards against junk beyond `rank`.
    """
    valid = (idx >= 0)
    safe = jnp.where(valid, idx, 0)
    a_sel = a[safe]                                    # (B, d_in, r_max)
    b_sel = b[safe]                                    # (B, r_max, d_out)
    xa = jnp.einsum("btd,bdr->btr", x, a_sel)
    if mode == "mbgmv":
        if ranks is None:
            raise ValueError("rank-aware store needs per-adapter ranks")
        r_max = a.shape[-1]
        nblk = (ranks[safe] + rank_block - 1) // rank_block * rank_block
        xa = xa * (jnp.arange(r_max)[None, None, :] < nblk[:, None, None])
    delta = jnp.einsum("btr,bro->bto", xa, b_sel)
    delta = delta * valid[:, None, None]
    return (scale * delta).astype(x.dtype)


def lora_apply(x, lora_layer, target, lora_idx, ranks, mode="bgmv",
               rank_block=16):
    """Hook used inside model blocks. lora_layer: per-layer slice of the pool
    ({target: {a,b}}); returns delta or 0 if this target has no adapter."""
    if lora_layer is None or target not in lora_layer:
        return 0.0
    ab = lora_layer[target]
    return lora_delta_ref(x, ab["a"], ab["b"], lora_idx, ranks=ranks,
                          mode=mode, rank_block=rank_block)


# --------------------------------------------------------- host store ----

class HostLoRAStore:
    """In-memory local LoRA repository (paper Fig 6): all adapters of a
    server live in host memory; device pool holds the hot subset."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.specs: Dict[str, AdapterSpec] = {}
        self._weights: Dict[str, dict] = {}
        # when each adapter joined this store (simulated ms); adapters
        # installed mid-run by the cluster's register-on-miss path have
        # registered_ms > 0
        self.registered_ms: Dict[str, float] = {}

    def register(self, spec: AdapterSpec, materialize=True,
                 now_ms: float = 0.0):
        self.specs[spec.uid] = spec
        self.registered_ms[spec.uid] = now_ms
        if materialize:
            self._weights[spec.uid] = make_adapter_weights(self.cfg, spec)

    def weights(self, uid: str):
        if uid not in self._weights:
            self._weights[uid] = make_adapter_weights(self.cfg, self.specs[uid])
        return self._weights[uid]

    def __contains__(self, uid):
        return uid in self.specs


class StagingCache:
    """Small LRU of per-adapter *device* copies of host LoRA weights — the
    CPU-assist prefill path's staging area.

    The batched prefill builds its pseudo-pool by stacking the admitted
    requests' host weights; without a cache every prefill of a hot adapter
    re-uploads the same arrays over the host link. Entries are keyed by
    ``(uid, registered_ms)`` so a re-registered adapter (the cluster's
    install/rebalance paths bump ``HostLoRAStore.registered_ms``) never
    serves a stale copy. Eviction is LRU with a small bound — the staging
    area is a prefill-window cache, not a second device pool.

    ``hits``/``misses``/``evictions`` are telemetry for the pipeline
    benchmark and tests; ``on_upload(nbytes)`` lets the owner count the
    host-link transfers the misses cost."""

    def __init__(self, slots: int = 16, on_upload=None):
        if slots < 1:
            raise ValueError(f"need at least one adapter slot, got {slots}")
        self.slots = slots
        self._entries: "Dict[Tuple[str, float], dict]" = {}
        self._order: List[Tuple[str, float]] = []
        self._on_upload = on_upload
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, uid: str, store: "HostLoRAStore"):
        """Device copy of `uid`'s weights ({target: {a, b}} jnp arrays)."""
        key = (uid, store.registered_ms.get(uid, 0.0))
        ent = self._entries.get(key)
        if ent is not None:
            self.hits += 1
            self._order.remove(key)
            self._order.append(key)
            return ent
        self.misses += 1
        # a re-registered adapter supersedes its old generation: purge any
        # stale (uid, older_ms) entries so dead copies never hold LRU slots
        for stale in [k for k in self._order if k[0] == uid]:
            self._order.remove(stale)
            del self._entries[stale]
        w = store.weights(uid)
        ent = {t: {"a": jnp.asarray(w[t]["a"]), "b": jnp.asarray(w[t]["b"])}
               for t in w}
        if self._on_upload is not None:
            self._on_upload(sum(int(w[t][ab].nbytes) for t in w
                                for ab in ("a", "b")))
        self._entries[key] = ent
        self._order.append(key)
        while len(self._order) > self.slots:
            old = self._order.pop(0)
            del self._entries[old]
            self.evictions += 1
        return ent

    def __len__(self):
        return len(self._entries)


class DevicePool:
    """Stateful wrapper around the functional slot pool with LRU eviction and
    in-flight slot reservation: a cold start *reserves* its slot when the
    upload begins (so concurrent admissions cannot double-claim it) and the
    slot becomes *ready* only when the LoadTracker retires the upload.
    Reserved-but-not-ready slots are never eviction victims.
    materialize=False keeps slot bookkeeping only (timing-only simulations).

    With `allocator` (the paged memory plane's `PageAllocator`) each
    resident adapter additionally holds ``ceil(nbytes / page_bytes)`` pages
    from the unified KV/LoRA pool: reserve claims them, evict/release frees
    them, and `shed_cold` lets a KV-hungry admission reclaim the pages of
    cold (ready, unpinned) residents LRU-first. Without an allocator the
    pool behaves exactly as before (a static reservation)."""

    def __init__(self, cfg: ModelConfig, n_slots: Optional[int] = None,
                 materialize: bool = True, allocator=None,
                 page_bytes: int = 0):
        self.cfg = cfg
        self.n_slots = n_slots or cfg.lora.n_slots
        self.materialize = materialize
        self.pool = pool_init(cfg, self.n_slots) if materialize else None
        self.slot_uid: List[Optional[str]] = [None] * self.n_slots
        self.slot_ready: List[bool] = [True] * self.n_slots
        self.allocator = allocator
        self.page_bytes = page_bytes
        self.slot_pages: List[List[int]] = [[] for _ in range(self.n_slots)]
        self._clock = 0
        self._last_used = [0] * self.n_slots

    def pages_for(self, nbytes: int) -> int:
        """Unified-pool page cost of an adapter of `nbytes` (0 when the
        pool is not page-accounted)."""
        if self.allocator is None:
            return 0
        return max(1, -(-int(nbytes) // self.page_bytes))

    def lookup(self, uid: str) -> Optional[int]:
        for s, u in enumerate(self.slot_uid):
            if u == uid:
                self._touch(s)
                return s
        return None

    def is_ready(self, slot: int) -> bool:
        return self.slot_ready[slot]

    def inflight_slots(self) -> List[int]:
        return [s for s, u in enumerate(self.slot_uid)
                if u is not None and not self.slot_ready[s]]

    def _touch(self, slot):
        self._clock += 1
        self._last_used[slot] = self._clock

    def choose_victim(self, pinned: Sequence[int] = ()) -> Optional[int]:
        cands = [s for s in range(len(self.slot_uid))
                 if s not in pinned
                 and (self.slot_uid[s] is None or self.slot_ready[s])]
        if not cands:
            return None       # every slot pinned or mid-upload
        free = [s for s in cands if self.slot_uid[s] is None]
        if free:
            return free[0]
        return min(cands, key=lambda s: self._last_used[s])

    def reserve(self, uid: str, weights, rank: int,
                pinned: Sequence[int] = (),
                nbytes: int = 0) -> Optional[int]:
        """Claim a slot for an upload in flight. The device copy is written
        eagerly when materialized (numerics must be valid the moment the
        virtual-time upload lands); readiness gates the *timeline* and the
        eviction policy, not the arrays. Under the unified pool the
        adapter's pages are claimed here (shedding colder residents if the
        budget is short); on failure nothing is evicted — the chosen victim
        survives a reservation that cannot be honoured."""
        slot = self.choose_victim(pinned)
        if slot is None:
            return None
        if self.allocator is not None:
            need = self.pages_for(nbytes)
            pin = tuple(pinned) + (slot,)
            if (self.allocator.free_pages + len(self.slot_pages[slot])
                    + self.sheddable_pages(pin)) < need:
                return None          # doomed: evict nothing, victim stays
            while (self.allocator.free_pages
                   + len(self.slot_pages[slot])) < need:
                if not self.shed_cold(pinned=pin):
                    return None      # budget exhausted, victim untouched
            if self.slot_pages[slot]:
                self.allocator.free(self.slot_pages[slot])
            self.slot_pages[slot] = self.allocator.claim(
                need, f"adapter:{uid}")
        if self.materialize:
            self.pool = pool_insert(self.pool, self.cfg, weights, slot, rank)
        self.slot_uid[slot] = uid
        self.slot_ready[slot] = False
        self._touch(slot)
        return slot

    def commit(self, slot: int):
        """Upload landed: the slot joins the ready set."""
        self.slot_ready[slot] = True
        self._touch(slot)

    def _free_pages_of(self, slot: int):
        if self.allocator is not None and self.slot_pages[slot]:
            self.allocator.free(self.slot_pages[slot])
            self.slot_pages[slot] = []

    def evict(self, slot: int):
        """Drop a resident adapter (prefetch victim selection / unified-
        pool reclaim); its pages return to the shared allocator."""
        if not self.slot_ready[slot]:
            raise RuntimeError("cannot evict a slot mid-upload")
        self.slot_uid[slot] = None
        self.slot_ready[slot] = True
        self._free_pages_of(slot)

    def release(self, slot: int):
        """Abandon an in-flight reservation (the link scheduler canceled a
        queued speculative upload): the slot returns to the free set. Any
        eagerly-written weights are simply overwritten by the next tenant."""
        if self.slot_ready[slot]:
            raise RuntimeError("release is for mid-upload slots")
        self.slot_uid[slot] = None
        self.slot_ready[slot] = True
        self._free_pages_of(slot)

    def _shed_candidates(self, pinned: Sequence[int] = ()) -> List[int]:
        return [s for s in range(self.n_slots)
                if s not in pinned and self.slot_uid[s] is not None
                and self.slot_ready[s]]

    def sheddable_pages(self, pinned: Sequence[int] = ()) -> int:
        """Pages reclaimable by evicting every cold (ready, unpinned)
        resident — callers check this *before* shedding, so a claim that
        can never succeed evicts nothing (doomed reclaims must not flush
        the warm set)."""
        return sum(len(self.slot_pages[s])
                   for s in self._shed_candidates(pinned))

    def shed_cold(self, pinned: Sequence[int] = ()) -> bool:
        """Evict the least-recently-used ready, unpinned resident — the
        unified pool's reclaim lever: a KV-hungry admission (or a hotter
        adapter) frees a cold speculative adapter's pages. Returns False
        when nothing evictable remains."""
        cands = self._shed_candidates(pinned)
        if not cands:
            return False
        self.evict(min(cands, key=lambda s: self._last_used[s]))
        return True

    def insert(self, uid: str, weights, rank: int,
               pinned: Sequence[int] = (),
               nbytes: int = 0) -> Optional[int]:
        """Synchronous reserve+commit (cached oracle / tests)."""
        slot = self.reserve(uid, weights, rank, pinned, nbytes=nbytes)
        if slot is not None:
            self.commit(slot)
        return slot
