"""Admission plane of the inference server: row assignment, the admission
policy (arrivals preempt decoding, paper Fig 2), and the popularity-EWMA
adapter prefetcher (beyond-paper: the mechanism S-LoRA leaves unspecified,
paper sec 2.3 — here concrete and composable with CPU-assist).

Owns the request queue, the batch-row bookkeeping, and the mapping from rows
to device pool slots. Knows nothing about JAX arrays (that is the
NumericsBackend) or the virtual clock (that is the InferenceServer): it is
handed `clock` and returns the admissions plus the serial time they cost.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cold_start import AdmitPlan, ColdStartManager
from repro.core.lora import DevicePool, HostLoRAStore
from repro.serving.cache import pages_for_tokens
from repro.serving.request import RequestState

POP_HALFLIFE_MS = 5000.0     # popularity EWMA half-life (simulated time)
PREFETCH_PER_TICK = 4        # uploads started per iteration at most
PREFETCH_HYSTERESIS = 1.5    # replace a resident only on a clear win


class AdmissionPlane:
    def __init__(self, cold: ColdStartManager, store: HostLoRAStore,
                 pool: DevicePool, max_batch: int, prefetch: bool = False,
                 allocator=None, page_size: int = 32,
                 cache_slots: int = 0, admit_footprint: str = "prompt",
                 kv_page_bytes: int = 0, chunk_budget: int = 0,
                 shed_late_slo: float = 0.0):
        if admit_footprint not in ("prompt", "full"):
            raise ValueError(f"unknown admit_footprint {admit_footprint!r}")
        # brownout shedding (core/faults.py): with shed_late_slo > 0, a
        # queued fresh request that has already waited longer than
        # shed_late_slo * slo_tpt_ms * max_new_tokens — i.e. its SLO is
        # provably unattainable even at zero serving time — is shed at
        # admission instead of dragging every resident row's ITL. 0 = off.
        self.shed_late_slo = shed_late_slo
        self.shed_count = 0
        # chunked prefill: prompts longer than chunk_budget are admitted in
        # phase "prefill" — pages claimed chunk-by-chunk by the engine's
        # interleaver, prefill compute billed per-iteration, only the
        # blocking part of a cold start charged serially here. 0 = off.
        self.chunk_budget = chunk_budget
        self.cold = cold
        self.store = store
        self.pool = pool
        self.max_batch = max_batch
        self.prefetch = prefetch
        # paged memory plane: admission claims each request's KV pages from
        # the unified KV/LoRA allocator (None: dense rows, no page gating).
        # `admit_footprint="prompt"` claims prompt pages only and lets the
        # block table grow lazily during decode (KV over-subscription);
        # "full" is the PR-5 baseline that reserves the whole lifetime
        # footprint up front.
        self.allocator = allocator
        self.page_size = page_size
        self.cache_slots = cache_slots
        self.admit_footprint = admit_footprint
        self.kv_page_bytes = kv_page_bytes   # link bytes per swapped page
        self.row_pages: List[List[int]] = [[] for _ in range(max_batch)]
        self.peak_active_rows = 0
        # set by the allocator's on_free hook: pages came back (retire,
        # preemption, adapter shed) since the last admit pass — the engine
        # re-checks deferred admissions promptly instead of waiting a step
        self.pages_freed = False
        if allocator is not None:
            allocator.on_free = self._note_pages_freed
        self.queue: collections.deque = collections.deque()
        self.rows: List[Optional[RequestState]] = [None] * max_batch
        self.row_slot = np.full(max_batch, -1, np.int64)   # adapter pool slot
        self.row_pos = np.zeros(max_batch, np.int64)       # next decode pos
        # popularity EWMA over *simulated time* (half-life POP_HALFLIFE_MS,
        # so scores on a server whose traffic dries up still fade), O(1)
        # per arrival: instead of decaying every key, scores are kept in an
        # inflated scale that grows as time passes; an occasional O(K)
        # renormalization keeps the scale finite
        self._popularity: Dict[str, float] = {}
        self._pop_scale = 1.0
        self._pop_t = 0.0        # simulated ms of the last update

    # ----------------------------------------------------------- queue ----
    def enqueue(self, st: RequestState):
        self.queue.append(st)
        # EWMA popularity update — always tracked (the cluster's placement
        # rebalance consumes it even when local prefetch is off)
        t = st.req.arrival_ms
        e = min(max(t - self._pop_t, 0.0) / POP_HALFLIFE_MS, 60.0)
        self._pop_scale *= 2.0 ** e
        self._pop_t = max(self._pop_t, t)
        self._popularity[st.req.adapter_uid] = \
            self._popularity.get(st.req.adapter_uid, 0.0) + self._pop_scale
        if self._pop_scale > 1e12:
            for k in self._popularity:
                self._popularity[k] /= self._pop_scale
            self._pop_scale = 1.0

    def popularity(self, now_ms: Optional[float] = None) -> Dict[str, float]:
        """Snapshot of the per-adapter popularity EWMA as of `now_ms`
        (default: as of the last arrival). Time-indexed: a server that
        stopped receiving traffic reports faded scores, not its frozen
        peak — the cluster aggregates these across servers at one instant
        to drive replica add/drop decisions."""
        ref = self._pop_t if now_ms is None else max(now_ms, self._pop_t)
        fade = 0.5 ** min((ref - self._pop_t) / POP_HALFLIFE_MS, 60.0)
        return {k: v / self._pop_scale * fade
                for k, v in self._popularity.items()}

    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.rows)

    def free_row(self) -> Optional[int]:
        for i, r in enumerate(self.rows):
            if r is None:
                return i
        return None

    def pinned_slots(self) -> List[int]:
        return [int(s) for s in self.row_slot if s >= 0]

    # ----------------------------------------------------------- paging ----
    def _note_pages_freed(self):
        self.pages_freed = True

    def kv_pages_needed(self, req) -> int:
        """*Lifetime* page demand of a request: prompt plus generated
        tokens, capped by the per-row ring depth. This gates `submit` (a
        request whose full footprint can never fit must be rejected, not
        deferred forever) — admission itself claims only `kv_pages_admit`
        and grows the block table lazily."""
        if self.allocator is None:
            return 0
        tokens = min(req.prompt_len + req.max_new_tokens, self.cache_slots)
        return pages_for_tokens(tokens, self.page_size)

    def kv_pages_admit(self, req, chunked: bool = False) -> int:
        """Pages claimed at admission: prompt only under over-subscription
        (`admit_footprint="prompt"`), the whole lifetime footprint under
        the up-front baseline. A chunked admission claims the first
        chunk's pages only — the rest arrive chunk-by-chunk through the
        engine's interleaver (the "full" baseline still reserves
        everything up front; chunking only staggers the writes)."""
        if self.allocator is None:
            return 0
        if self.admit_footprint == "full":
            return self.kv_pages_needed(req)
        tokens = min(req.prompt_len, self.cache_slots)
        if chunked:
            tokens = min(tokens, self.chunk_budget)
        return pages_for_tokens(tokens, self.page_size)

    def chunk_eligible(self, req) -> bool:
        """Prompts longer than one chunk take the chunked prefill path."""
        return 0 < self.chunk_budget < req.prompt_len

    def _chunk_admit(self, st: RequestState) -> bool:
        """Should this admission enter in phase "prefill"? Fresh long
        prompts always; preempted rows only when the prefill itself was
        interrupted (a swap-out mid-chunking preserves `prefill_pos` so
        resume restores chunk progress instead of the decode position)."""
        return self.chunk_eligible(st.req) and \
            (not st.preempted or st.prefill_pos < st.req.prompt_len)

    def kv_pages_resume(self, st: RequestState) -> int:
        """Pages a preempted request needs to re-admit: every KV slot
        written before preemption (`resume_pos` tokens, ring-capped) must
        be resident again — restored by swap-in or rebuilt by recompute —
        before decode can continue."""
        return pages_for_tokens(min(st.resume_pos, self.cache_slots),
                                self.page_size)

    def _claim_kv(self, st: RequestState) -> Optional[List[int]]:
        """Claim the request's admission KV pages (prompt pages, or the
        full restore set for a preempted resume), reclaiming cold resident
        adapters' pages (LRU-first, pinned slots excluded) when the unified
        pool is short — the KV-hungry-burst side of the shared budget. A
        demand that cannot be met even by shedding everything evictable
        defers without evicting anything (a doomed claim must not flush the
        warm adapter set)."""
        need = self.kv_pages_resume(st) if st.preempted \
            else self.kv_pages_admit(st.req, chunked=self._chunk_admit(st))
        pinned = self.pinned_slots()
        if self.allocator.free_pages + self.pool.sheddable_pages(pinned) \
                < need:
            return None
        owner = f"kv:{st.req.rid}"
        ids = self.allocator.claim(need, owner)
        while ids is None and self.pool.shed_cold(pinned=pinned):
            ids = self.allocator.claim(need, owner)
        return ids

    def grow_row(self, row: int) -> Optional[List[int]]:
        """Lazy block-table growth: claim the next logical page for a row
        whose decode write is crossing a page boundary, shedding cold
        adapter pages if the pool is short. Returns the claimed page ids
        (the caller must scrub them before the write — they may carry a
        previous tenant's entries) or None when the allocator is dry even
        after shedding: the engine's victim policy takes over."""
        st = self.rows[row]
        pinned = self.pinned_slots()
        owner = f"kv:{st.req.rid}"
        ids = self.allocator.claim(1, owner)
        while ids is None and self.pool.shed_cold(pinned=pinned):
            ids = self.allocator.claim(1, owner)
        if ids is None:
            return None
        self.row_pages[row].extend(ids)
        st.kv_pages.extend(ids)
        return ids

    def running_states(self) -> List[RequestState]:
        return [r for r in self.rows if r is not None]

    # ------------------------------------------------------- admission ----
    def admit(self, clock: float) -> Tuple[List[Tuple[RequestState,
                                                      AdmitPlan]], float]:
        """Admit queued arrivals into free rows (new arrivals preempt
        decoding, paper Fig 2). Preempted requests re-enter through the
        same path: they sit at the queue front, re-claim their restore
        pages, and are billed either a recompute prefill (drop path) or a
        link-scheduled KV swap-in (swap path) — never a new first token.
        Returns (admitted, serial_ms): the serial prefill/stall time the
        admissions add to this iteration."""
        self.pages_freed = False
        iter_ms = 0.0
        admitted = []
        while self.queue and self.free_row() is not None \
                and self.queue[0].req.arrival_ms <= clock:
            st = self.queue.popleft()
            if self._should_shed(st, clock):
                st.phase = "shed"
                st.shed = True
                st.row = -1
                self.shed_count += 1
                continue
            row = self.free_row()
            st.row = row
            self.rows[row] = st
            pages = None
            if self.allocator is not None:
                pages = self._claim_kv(st)
                if pages is None:   # pool exhausted: defer the admission
                    self.rows[row] = None
                    st.row = -1
                    self.queue.appendleft(st)
                    break
            resume = st.preempted
            chunked = self._chunk_admit(st)
            # swap resume restores KV bytes over the link — no prefill
            # compute; recompute resume re-prefills every written slot
            prefill_tokens = st.req.prompt_len if not resume else (
                0 if st.resume_kind == "swap"
                else min(st.resume_pos, self.cache_slots))
            plan = self.cold.admit(st.req.adapter_uid, clock + iter_ms,
                                   prefill_tokens,
                                   pinned=self.pinned_slots())
            if plan is None:     # every device slot pinned: requeue, stop
                if pages is not None:
                    self.allocator.free(pages)
                self.rows[row] = None
                st.row = -1
                self.queue.appendleft(st)
                break
            if pages is not None:
                # distinct lists: grow_row extends both (aliasing them
                # would double-append every lazy growth claim)
                self.row_pages[row] = list(pages)
                st.kv_pages = list(pages)
            st.cold_start = st.cold_start or plan.cold
            st.assist_used = st.assist_used or plan.assist
            if chunked:
                # chunked prefill: the compute is billed per-chunk inside
                # decode iterations by the engine's interleaver — only the
                # blocking part of a cold start (ondemand/slora upload
                # wait) and any KV swap-in link time charge serially here.
                # No first token yet: it arrives with the final chunk.
                iter_ms += plan.blocking_ms
                if resume and st.resume_kind == "swap" and pages:
                    ev = self.cold.upload_kv(st.req.rid,
                                             len(pages) * self.kv_page_bytes,
                                             clock + iter_ms)
                    st.kv_resume_ms = ev.finish_ms
                st.ready_ms = max(clock + iter_ms, st.kv_resume_ms)
                st.load_finish_ms = plan.load_finish_ms
                st.phase = "prefill"
                self.row_slot[row] = plan.slot
                self.row_pos[row] = st.prefill_pos
                admitted.append((st, plan))
                self.peak_active_rows = max(
                    self.peak_active_rows,
                    sum(r is not None for r in self.rows))
                continue
            # monolithic: the whole prompt's KV lands in one shot
            st.prefill_pos = st.req.prompt_len
            # prefill_ms is the full first-token latency post queue and
            # already contains any blocking load (ondemand/slora);
            # blocking_ms is reported separately for Fig 2 accounting, so
            # adding both would double-count the upload
            iter_ms += plan.prefill_ms
            if resume:
                st.ready_ms = plan.ready_decode_ms
                if st.resume_kind == "swap" and pages:
                    ev = self.cold.upload_kv(st.req.rid,
                                             len(pages) * self.kv_page_bytes,
                                             clock + iter_ms)
                    st.kv_resume_ms = ev.finish_ms
                    st.ready_ms = max(st.ready_ms, ev.finish_ms)
            else:
                st.first_token_ms = clock + iter_ms
                st.ready_ms = plan.ready_decode_ms
            st.load_finish_ms = plan.load_finish_ms
            st.phase = "loading" if st.ready_ms > clock + iter_ms \
                else "decode"
            self.row_slot[row] = plan.slot
            self.row_pos[row] = st.resume_pos if resume \
                else st.req.prompt_len
            admitted.append((st, plan))
            self.peak_active_rows = max(
                self.peak_active_rows,
                sum(r is not None for r in self.rows))
        return admitted, iter_ms

    def _should_shed(self, st: RequestState, clock: float) -> bool:
        """Brownout shedding gate: only fresh requests with a TPT SLO and
        no emitted work are eligible — a preempted/recovered request
        already holds tokens the caller promised, shedding it would lose
        them."""
        if self.shed_late_slo <= 0.0 or st.preempted or st.generated \
                or st.pending_tokens or st.recovered \
                or st.req.slo_tpt_ms is None:
            return False
        budget = self.shed_late_slo * st.req.slo_tpt_ms \
            * st.req.max_new_tokens
        return clock - st.req.arrival_ms > budget

    def release(self, row: int):
        self.rows[row] = None
        self.row_slot[row] = -1
        if self.allocator is not None and self.row_pages[row]:
            self.allocator.free(self.row_pages[row])
        self.row_pages[row] = []

    # -------------------------------------------------------- prefetch ----
    def prefetch_tick(self, now_ms: float):
        """Start async uploads of the hottest non-resident adapters into
        free, unpinned slots. The upload rides the host link through the
        LoadTracker — it occupies the link but never blocks the iteration.
        When demand traffic owns the link (a cold start's upload is still
        running or queued) the prefetcher backs off entirely: speculative
        transfers must never steal lane time a waiting request needs, and
        under `fifo` they would queue *ahead* of the next demand upload."""
        if not (self.prefetch and self._popularity):
            return
        if self.cold.tracker.demand_busy_ms(now_ms) > 0.0:
            return
        pinned = set(self.pinned_slots())
        pop = lambda u: self._popularity.get(u, 0.0)
        hot = sorted((u for u in self._popularity
                      if self.pool.lookup(u) is None),
                     key=pop, reverse=True)
        for uid in hot[:PREFETCH_PER_TICK]:
            # victim: unpinned ready slot with the least-popular resident,
            # replaced only on a clear popularity win (hysteresis)
            cands = [s for s in range(self.pool.n_slots)
                     if s not in pinned and self.pool.is_ready(s)]
            if not cands:
                break
            victim = min(cands, key=lambda s: pop(self.pool.slot_uid[s])
                         if self.pool.slot_uid[s] else -1.0)
            vu = self.pool.slot_uid[victim]
            if vu is not None and pop(uid) < PREFETCH_HYSTERESIS * pop(vu):
                continue
            # reserve-first: pin every slot except the chosen victim so the
            # reservation can only land there (overwriting the resident in
            # place). If it fails, nothing was evicted and the resident
            # survives — the old evict-then-reserve order lost the resident
            # whenever the reservation could not be honoured.
            keep = tuple(s for s in range(self.pool.n_slots) if s != victim)
            if self.cold.load_async(uid, now_ms, pinned=keep,
                                    demand=False) is None:
                break
