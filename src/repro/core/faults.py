"""Fault-injection plane: seeded, scripted failures for the cluster
simulator (the CaraServe reproduction's chaos harness).

The fleet so far was fair-weather: servers never died, uploads never
failed, links never degraded. This module scripts exactly those events —
fully deterministically, so a chaos run is as replayable as a fault-free
one — and the cluster/engine recovery paths (crash drain + failover
re-admission via drop-and-recompute, upload retry with backoff, CPU-assist
degraded decode, SLO shedding) are what the injected faults exercise.

Fault model (fail-stop + transient):

  * ``crash`` / ``restart`` — fail-stop loss of one server: its device
    state (KV pages, adapter pool, in-flight uploads) vanishes; queued and
    in-flight requests are drained back to the router and re-admitted on
    surviving replicas. ``restart`` brings the server back empty; the
    cluster re-registers its placement-hosted adapters and warms the
    hottest through the normal prefetch path (warm rejoin, not cold).
  * ``upload_flaky`` — a window during which uploads *retiring* on a
    server's host link fail with probability ``fail_prob``. Failures are
    decided by a content hash (seed, server, uid, attempt, seq), not by
    draw order, so the decision set is independent of event interleaving.
  * ``brownout`` — a window scaling a server's host-link transfer times
    by ``slowdown`` (the `LoadTracker` applies it to every transfer that
    *starts* inside the window).

Crash/restart events ride the cluster event heap (kind ``FAULT``, ordered
before same-time arrivals); the window faults are installed up front on
each server's ``LoadTracker`` by ``attach()`` — windows are pure functions
of time, so nothing about them needs to be event-driven.

``log`` records every applied fault and every injected upload failure in
event order: two same-seed runs must produce byte-identical logs
(tests/test_faults.py's determinism gate).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Sequence, Tuple

FAULT_KINDS = ("crash", "restart", "upload_flaky", "brownout")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault. Point faults (crash/restart) fire at ``t_ms``;
    window faults (upload_flaky/brownout) are active on
    ``[t_ms, until_ms)``."""
    t_ms: float
    kind: str
    server: int
    until_ms: float = 0.0       # window faults: end of the window
    fail_prob: float = 0.0      # upload_flaky: P(one retirement fails)
    slowdown: float = 1.0       # brownout: transfer-time multiplier

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in ("upload_flaky", "brownout") \
                and self.until_ms <= self.t_ms:
            raise ValueError(
                f"{self.kind} window must end after it starts "
                f"({self.t_ms} .. {self.until_ms})")
        if self.kind == "upload_flaky" \
                and not 0.0 <= self.fail_prob <= 1.0:
            raise ValueError(f"fail_prob must be in [0, 1], "
                             f"got {self.fail_prob}")
        if self.kind == "brownout" and self.slowdown < 1.0:
            raise ValueError(
                f"brownout slows the link down (slowdown >= 1.0), "
                f"got {self.slowdown}")


def _unit(seed: int, *parts) -> float:
    """Deterministic unit-interval draw from a content hash — independent
    of evaluation order, so two runs (or a run and its replay) agree on
    every failure decision without sharing RNG state."""
    key = ":".join(str(p) for p in (seed,) + parts).encode()
    return zlib.crc32(key) / 2.0 ** 32


class FaultPlane:
    """A scripted fault schedule plus the hooks that inject it.

    * ``timed_events()`` — the crash/restart events the cluster pushes on
      its heap (kind ``FAULT``).
    * ``attach(cluster)`` — installs the window faults: per-server
      upload-failure hooks and brownout windows on each ``LoadTracker``.
    * ``record(...)``/``log`` — the applied-fault timeline; the cluster
      appends crash/restart/failover entries, the upload hook appends
      every injected failure. Same seed + same trace => identical log.
    """

    def __init__(self, events: Sequence[FaultEvent], seed: int = 0):
        self.events = sorted(events,
                             key=lambda e: (e.t_ms, e.server, e.kind))
        self.seed = seed
        self.log: List[Tuple] = []
        self.stats = {"upload_failures": 0}

    # ---------------------------------------------------------- views ----
    def timed_events(self) -> List[FaultEvent]:
        """Point faults for the event heap (crash/restart)."""
        return [e for e in self.events
                if e.kind in ("crash", "restart")]

    def windows(self, kind: str, server: int) -> List[FaultEvent]:
        return [e for e in self.events
                if e.kind == kind and e.server == server]

    # ------------------------------------------------------- recording ----
    def record(self, t_ms: float, kind: str, server: int, detail: str = ""):
        self.log.append((round(float(t_ms), 6), kind, int(server), detail))

    # ------------------------------------------------------ installation ----
    def attach(self, cluster):
        """Install the window faults on every server's link tracker. The
        cluster calls this once at the start of ``run()`` — re-attaching
        (a second ``run`` on the same cluster) is idempotent."""
        for i, srv in enumerate(cluster.servers):
            tr = srv.cold.tracker
            tr.brownouts = [(w.t_ms, w.until_ms, w.slowdown)
                            for w in self.windows("brownout", i)]
            flaky = self.windows("upload_flaky", i)
            tr.fail_hook = self._hook(i, flaky) if flaky else None
            # deterministic per-server backoff jitter stream
            tr.retry_seed = self.seed * 1_000_003 + i

    def _hook(self, server: int, windows: Sequence[FaultEvent]):
        def fails(ev) -> bool:
            for w in windows:
                if w.t_ms <= ev.finish_ms < w.until_ms:
                    if _unit(self.seed, server, ev.uid, ev.attempt,
                             ev.seq) < w.fail_prob:
                        self.stats["upload_failures"] += 1
                        self.record(ev.finish_ms, "upload_fail", server,
                                    f"{ev.uid}#a{ev.attempt}")
                        return True
            return False
        return fails


def chaos_schedule(n_servers: int, duration_ms: float, seed: int = 0,
                   n_crashes: int = 1, downtime_ms: float = 1500.0,
                   fail_prob: float = 0.4,
                   slowdown: float = 3.0) -> List[FaultEvent]:
    """Canned deterministic chaos scenario for benches/tests:
    ``n_crashes`` crash+restart pairs in the middle 40% of the run (victims
    drawn from servers 1..N-1, so server 0 — which carries the brownout —
    always survives), fleet-wide flaky uploads over the middle 60%, and
    one browned-out link on server 0."""
    if n_servers < 1:
        raise ValueError("need at least one server")
    evs: List[FaultEvent] = []
    for c in range(n_crashes):
        if n_servers > 1:
            victim = 1 + int(_unit(seed, "victim", c) * (n_servers - 1))
            victim = min(victim, n_servers - 1)
        else:
            victim = 0
        t = duration_ms * (0.3 + 0.4 * _unit(seed, "crash_t", c))
        evs.append(FaultEvent(t, "crash", victim))
        evs.append(FaultEvent(t + downtime_ms, "restart", victim))
    if fail_prob > 0.0:
        for i in range(n_servers):
            evs.append(FaultEvent(duration_ms * 0.2, "upload_flaky", i,
                                  until_ms=duration_ms * 0.8,
                                  fail_prob=fail_prob))
    if slowdown > 1.0:
        evs.append(FaultEvent(duration_ms * 0.4, "brownout", 0,
                              until_ms=duration_ms * 0.7,
                              slowdown=slowdown))
    return evs
