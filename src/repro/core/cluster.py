"""Multi-server cluster simulation (paper sec 7.5): N inference servers, a
front-end scheduler, trace-driven arrivals.

Event-driven: a global event heap orders request arrivals, per-server
iteration completions, and adapter load completions; each server advances
its own virtual clock only when an event fires for it, replacing the old
lockstep advance-everyone-to-the-next-arrival loop. The lockstep engine is
kept (``engine="lockstep"``) as a cross-check oracle — the event loop must
reproduce its summary metrics within tolerance (tests/test_load_tracker.py).

Servers are InferenceServer instances (numerics usually disabled at cluster
scale — same timeline engine the single-server evaluation uses, matching the
paper's simulator methodology). The scheduler observes in-flight loads
(ServerStats.loading_ranks / link_busy_ms) so rank-aware routing can steer
cold starts away from servers whose host link is saturated.
"""
from __future__ import annotations

import heapq
from typing import List, Sequence

from repro.core.engine import InferenceServer
from repro.core.scheduler import ServerStats
from repro.serving.request import Request, summarize

# event kinds, in tie-break priority order at equal timestamps: arrivals
# must be routed before a server iterates past them, and load completions
# land before the iteration that may use the adapter
ARRIVAL, LOAD_DONE, ITER = 0, 1, 2


class Cluster:
    def __init__(self, servers: Sequence[InferenceServer], scheduler,
                 engine: str = "events"):
        assert engine in ("events", "lockstep"), engine
        self.servers = list(servers)
        self.scheduler = scheduler
        self.engine = engine
        self.event_counts = {"arrival": 0, "iter": 0, "load_done": 0}

    def _stats(self, uid: str, now_ms: float) -> List[ServerStats]:
        out = []
        for s in self.servers:
            # retire uploads that finished (in simulated time) by the
            # arrival: an idle server's tracker is only polled inside
            # step(), so its resident/loading view can be stale here
            s.cold.poll(now_ms)
            ranks_run = s.running_ranks()
            ranks_q = [s.store.specs[r.req.adapter_uid].rank
                       for r in s.queue]
            slot = s.pool.lookup(uid)
            out.append(ServerStats(
                running_ranks=ranks_run,
                queued_ranks=ranks_q,
                hosts_adapter=uid in s.store,
                free_rows=sum(r is None for r in s.rows),
                n_requests=len(ranks_run) + len(ranks_q),
                loading_ranks=s.loading_ranks(),
                link_busy_ms=max(0.0, s.cold.tracker.link_busy_until_ms()
                                 - now_ms),
                adapter_ready=slot is not None and s.pool.is_ready(slot),
                adapter_loading=slot is not None
                and not s.pool.is_ready(slot),
            ))
        return out

    def _route(self, req: Request) -> int:
        stats = self._stats(req.adapter_uid, req.arrival_ms)
        rank = None
        for s in self.servers:
            if req.adapter_uid in s.store:
                rank = s.store.specs[req.adapter_uid].rank
                break
        return self.scheduler.route(rank, stats)

    # ------------------------------------------------------ event-driven ----
    def run(self, requests: List[Request], max_iters: int = 2_000_000):
        if self.engine == "lockstep":
            return self._run_lockstep(requests, max_iters)
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        heap: list = []
        seq = 0
        for req in pending:
            heapq.heappush(heap, (req.arrival_ms, ARRIVAL, seq, -1, req))
            seq += 1
        n_arrived = 0                 # arrivals pop in time order: a pointer
        scheduled = [False] * len(self.servers)
        iters = 0

        def schedule(i: int, t: float):
            nonlocal seq
            if scheduled[i]:
                return
            s = self.servers[i]
            t = max(t, s.clock)
            nf = s.cold.tracker.next_finish_ms()
            kind = LOAD_DONE if nf is not None and nf <= t else ITER
            heapq.heappush(heap, (t, kind, seq, i, None))
            scheduled[i] = True
            seq += 1

        while heap and iters < max_iters:
            t, kind, _, i, payload = heapq.heappop(heap)
            if kind == ARRIVAL:
                self.event_counts["arrival"] += 1
                n_arrived += 1
                idx = self._route(payload)
                self.servers[idx].submit(payload)
                schedule(idx, t)
                continue
            self.event_counts["iter" if kind == ITER else "load_done"] += 1
            scheduled[i] = False
            s = self.servers[i]
            if not s.busy():
                continue
            if s.clock < t:
                s.clock = t          # idle server woken by a later event
            horizon = pending[n_arrived].arrival_ms \
                if n_arrived < len(pending) else None
            s.step(horizon_ms=horizon)
            iters += 1
            if s.busy():
                schedule(i, s.clock)
        states = [st for s in self.servers for st in s.states]
        return summarize(states), states

    # --------------------------------------------------- lockstep oracle ----
    def _advance(self, until_ms: float):
        for s in self.servers:
            while s.busy() and s.clock < until_ms:
                s.step(horizon_ms=until_ms)
            if s.clock < until_ms:
                s.clock = until_ms

    def _run_lockstep(self, requests: List[Request],
                      max_iters: int = 2_000_000):
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        for req in pending:
            self._advance(req.arrival_ms)
            self.servers[self._route(req)].submit(req)
        iters = 0
        while any(s.busy() for s in self.servers) and iters < max_iters:
            for s in self.servers:
                if s.busy():
                    s.step()
            iters += 1
        states = [st for s in self.servers for st in s.states]
        return summarize(states), states
