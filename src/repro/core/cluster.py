"""Multi-server cluster simulation (paper sec 7.5): N inference servers, a
front-end scheduler, trace-driven arrivals. Servers are InferenceServer
instances (numerics usually disabled at cluster scale — same timeline engine
the single-server evaluation uses, matching the paper's simulator
methodology)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.engine import InferenceServer
from repro.core.scheduler import ServerStats
from repro.serving.request import Request, summarize


class Cluster:
    def __init__(self, servers: Sequence[InferenceServer], scheduler):
        self.servers = list(servers)
        self.scheduler = scheduler

    def _stats(self, uid: str) -> List[ServerStats]:
        out = []
        for s in self.servers:
            ranks_run = s.running_ranks()
            ranks_q = [s.store.specs[r.req.adapter_uid].rank
                       for r in s.queue]
            out.append(ServerStats(
                running_ranks=ranks_run,
                queued_ranks=ranks_q,
                hosts_adapter=uid in s.store,
                free_rows=sum(r is None for r in s.rows),
                n_requests=len(ranks_run) + len(ranks_q),
            ))
        return out

    def _advance(self, until_ms: float):
        for s in self.servers:
            while s.busy() and s.clock < until_ms:
                s.step()
            if s.clock < until_ms:
                s.clock = until_ms

    def run(self, requests: List[Request], max_iters: int = 2_000_000):
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        for req in pending:
            self._advance(req.arrival_ms)
            stats = self._stats(req.adapter_uid)
            rank = None
            for s in self.servers:
                if req.adapter_uid in s.store:
                    rank = s.store.specs[req.adapter_uid].rank
                    break
            idx = self.scheduler.route(rank, stats)
            self.servers[idx].submit(req)
        # drain
        iters = 0
        while any(s.busy() for s in self.servers) and iters < max_iters:
            for s in self.servers:
                if s.busy():
                    s.step()
            iters += 1
        states = [st for s in self.servers for st in s.states]
        return summarize(states), states
