"""Multi-server cluster simulation (paper sec 7.5): N inference servers, a
front-end scheduler, trace-driven arrivals.

Event-driven: a global event heap orders request arrivals, per-server wake
events (iteration completions / adapter load completions, classified at pop
time from the tracker's state), and periodic placement-rebalance passes;
each server advances its own virtual clock only when an event fires for it,
replacing the old lockstep advance-everyone-to-the-next-arrival loop. The
lockstep engine is kept (``engine="lockstep"``) as a cross-check oracle —
the event loop must reproduce its summary metrics within tolerance
(tests/test_load_tracker.py).

Placement plane (core/placement.py): when a ``Placement`` is given, each
adapter lives on a *subset* of servers and the scheduler routes only among
live hosting replicas. When no replica is alive — or every replica would
break the decode SLO (``RankAwareScheduler.saturated``) — the cluster falls
back to **register-on-miss**: the candidate set opens to every live server
with a one-time install cost (``ServerStats.miss_install_ms``) charged in
the routing score, the winner's host store installs the adapter mid-run
(``InferenceServer.install_adapter``; the host-side install is charged in
routing but approximated as instantaneous on the timeline — the device
upload it triggers pays the real link cost through the existing
``LoadTracker``), and the placement map gains the replica. A rebalance pass
driven by the admission plane's popularity EWMA adds replicas of hot
adapters (warmed by a speculative link upload) and drops surplus replicas
of cooled ones over simulated time.

Servers are InferenceServer instances (numerics usually disabled at cluster
scale — same timeline engine the single-server evaluation uses, matching the
paper's simulator methodology). The scheduler observes in-flight loads
(ServerStats.loading_ranks / link_busy_ms plus the per-class
demand_link_ms / prefetch_link_ms split) so rank-aware routing can steer
cold starts away from servers whose host link is saturated with demand
traffic — under the priority/preempt link policies, speculative prefetch
occupancy is jumped/canceled by a demand upload and correctly does not
count against the server. Upload finish times are recomputed by the link
scheduler on every insertion, so WAKE events never carry a cached
load_done timestamp: they are classified at pop time from
``next_finish_ms()`` / ``pending_completions()``.

Failure plane (core/faults.py): a ``FaultPlane`` injects scripted server
crashes, restarts, flaky-upload windows and a link brownout into the same
event heap (FAULT events order *before* same-time arrivals — a request
never routes to a server that died at its own arrival instant). A crash
fail-stops the victim's device: finished uploads land, live and queued
requests drain back through the router with a forced drop-and-recompute
resume plan and are adopted by survivors (``failovers``), in-flight uploads
are canceled (LinkSan holds them to never retire). A restart rejoins warm:
the host store survived, so the cluster re-warms the victim's hottest
hosted adapters through the normal prefetch path. Under
``shed_policy="slo"`` the router sheds fresh arrivals when every alive
candidate is decode-SLO-saturated (brownout back-pressure); crash
failovers are exempt — a recovered request is never shed.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Set

from repro.core.cold_start import CLS_DEMAND, CLS_PREFETCH, CLS_PROMOTED
from repro.core.engine import InferenceServer
from repro.core.faults import FaultPlane
from repro.core.lora import AdapterSpec
from repro.core.placement import Placement, replica_target
from repro.core.scheduler import ServerStats
from repro.serving.request import Request, RequestState, summarize

# event kinds, in tie-break priority order at equal timestamps: faults
# land first (a server that crashes at t is already dead to a t-arrival),
# arrivals must be routed before a server iterates past them, and a
# rebalance pass sees the popularity updates of same-time arrivals. WAKE
# events are generic "server makes progress" events — whether one is an
# iteration or a load completion is classified at *pop* time from the
# tracker's state (an upload can begin or retire between push and pop).
FAULT, ARRIVAL, REBALANCE, WAKE = 0, 1, 2, 3

# default one-time host-store install cost charged (in the routing score
# only) when a request must be placed on a server that does not host its
# adapter — stands in for the registry fetch that precedes the upload
MISS_INSTALL_MS = 25.0


class Cluster:
    def __init__(self, servers: Sequence[InferenceServer], scheduler,
                 engine: str = "events",
                 placement: Optional[Placement] = None,
                 specs: Optional[Sequence[AdapterSpec]] = None,
                 rebalance_every_ms: Optional[float] = None,
                 replica_spread: float = 1.5,
                 max_replicas: Optional[int] = None,
                 rebalance_max_adds: int = 8,
                 miss_install_ms: float = MISS_INSTALL_MS,
                 faults: Optional[FaultPlane] = None,
                 shed_policy: str = "none"):
        if engine not in ("events", "lockstep"):
            raise ValueError(f"unknown engine {engine!r}")
        if shed_policy not in ("none", "slo"):
            raise ValueError(f"unknown shed_policy {shed_policy!r}")
        if faults is not None and engine == "lockstep":
            raise ValueError("fault injection needs the event engine: the "
                             "lockstep oracle has no timeline to crash into")
        self.servers = list(servers)
        self.scheduler = scheduler
        self.engine = engine
        self.placement = placement
        self.rebalance_every_ms = rebalance_every_ms
        self.replica_spread = replica_spread
        self.max_replicas = max_replicas
        self.rebalance_max_adds = rebalance_max_adds
        self.miss_install_ms = miss_install_ms
        self.faults = faults
        self.shed_policy = shed_policy
        self.down: Set[int] = set()
        self.shed_states: List[RequestState] = []
        self.fault_stats = {"crashes": 0, "restarts": 0, "drained": 0,
                            "failovers": 0, "shed": 0}
        self.event_counts = {"arrival": 0, "iter": 0, "load_done": 0,
                             "rebalance": 0, "fault": 0}
        self.placement_stats = {"miss_installs": 0, "replica_adds": 0,
                                "replica_drops": 0, "replica_readds": 0}
        # cluster-wide adapter registry (rank lookup + late installs)
        self.specs: Dict[str, AdapterSpec] = {}
        for sp in specs or ():
            self.specs[sp.uid] = sp
        for s in self.servers:
            self.specs.update(s.store.specs)
        if placement is not None:
            if placement.n_servers != len(self.servers):
                raise ValueError(
                    f"placement spans {placement.n_servers} servers but the "
                    f"cluster has {len(self.servers)}")
            # materialize the assignment: each hosting server registers its
            # shard (servers may be built bare)
            for uid in list(self.specs):
                for i in placement.hosts(uid):
                    self.servers[i].install_adapter(self.specs[uid])

    # ----------------------------------------------------------- health ----
    def set_down(self, i: int, now_ms: Optional[float] = None):
        """Mark server `i` unhealthy. A busy server holds live requests
        that silently marking it down would strand forever (they would
        never be stepped again yet still count as submitted): pass
        `now_ms` to crash-drain them back through the router — failover
        semantics, identical to an injected crash — or get a
        RuntimeError."""
        if now_ms is not None:
            self._crash(i, now_ms)
            return
        if self.servers[i].busy():
            raise RuntimeError(
                f"server {i} is busy: set_down would strand its in-flight "
                "requests — pass now_ms to drain-and-requeue them "
                "(crash semantics)")
        self.down.add(i)

    def set_up(self, i: int):
        self.down.discard(i)

    def _crash(self, i: int, t: float) -> Set[int]:
        """Fail-stop server `i` at `t`: drain its queue and live rows and
        re-admit every drained request on a survivor through the normal
        router (never shed — failover must not be undermined by brownout
        back-pressure). Returns the set of adopting servers so the event
        loop can wake them."""
        if i in self.down:
            return set()
        self.down.add(i)
        drained = self.servers[i].crash(t)
        self.fault_stats["crashes"] += 1
        self.fault_stats["drained"] += len(drained)
        if self.faults is not None:
            self.faults.record(t, "crash", i, f"drained={len(drained)}")
        woken: Set[int] = set()
        for st in drained:
            st.recovered += 1
            try:
                idx = self._route(st.req, now_ms=t, allow_shed=False)
            except LookupError:
                # no alive replica and no placement map to open the
                # candidate set: fail over to the least-loaded survivor
                idx = min(self._alive(), key=self._server_load)
            srv = self.servers[idx]
            uid = st.req.adapter_uid
            if uid not in srv.store:   # placement-free clusters still heal
                srv.install_adapter(self.specs[uid], t)
            srv.adopt(st, t)
            self.fault_stats["failovers"] += 1
            woken.add(idx)
        return woken

    def _restart(self, i: int, t: float):
        """Rejoin server `i` at `t` with an empty device but a surviving
        host store: re-warm its hottest hosted adapters (cluster-wide
        popularity order) through the normal prefetch path, so the rejoin
        is warm, not cold — the first post-restart arrivals find their
        adapters already riding the link."""
        if i not in self.down:
            return
        self.down.discard(i)
        srv = self.servers[i]
        srv.restart(t)
        self.fault_stats["restarts"] += 1
        if self.faults is not None:
            self.faults.record(t, "restart", i)
        pop: Dict[str, float] = {}
        for s in self.servers:
            for u, v in s.admission.popularity(t).items():
                pop[u] = pop.get(u, 0.0) + v
        if self.placement is not None:
            hosted = [u for u in self.specs
                      if i in self.placement.hosts(u)]
        else:
            hosted = [u for u in srv.store.specs]
        hosted.sort(key=lambda u: pop.get(u, 0.0), reverse=True)
        t0 = max(t, srv.clock)
        pinned = tuple(srv.admission.pinned_slots())
        for uid in hosted[:srv.pool.n_slots]:
            if srv.pool.lookup(uid) is not None:
                continue
            if srv.cold.load_async(uid, t0, pinned=pinned,
                                   demand=False) is None:
                break                  # pool full: warmest slots claimed

    def _alive(self) -> List[int]:
        return [i for i in range(len(self.servers)) if i not in self.down]

    def _server_load(self, i: int) -> int:
        s = self.servers[i]
        return len(s.queue) + sum(r is not None for r in s.rows)

    # ------------------------------------------------------------ stats ----
    def _stats(self, uid: str, now_ms: float,
               hosting: Optional[Set[int]] = None,
               req: Optional[Request] = None) -> List[ServerStats]:
        out = []
        for i, s in enumerate(self.servers):
            # retire uploads that finished (in simulated time) by the
            # arrival: an idle server's tracker is only polled inside
            # step(), so its resident/loading view can be stale here. A
            # server mid-iteration can be ahead of the arrival; its link
            # occupancy is measured from the same reference, since a
            # request routed there cannot start before the server's clock
            ref = max(now_ms, s.clock)
            s.cold.poll(ref)
            cb = s.cold.tracker.class_busy_ms(ref)
            itl = s.itl_stats()
            ranks_run = s.running_ranks()
            ranks_q = [s.store.specs[r.req.adapter_uid].rank
                       for r in s.queue]
            slot = s.pool.lookup(uid)
            hosts = (i in hosting) if hosting is not None \
                else uid in s.store
            out.append(ServerStats(
                running_ranks=ranks_run,
                queued_ranks=ranks_q,
                hosts_adapter=hosts and i not in self.down,
                free_rows=sum(r is None for r in s.rows),
                n_requests=len(ranks_run) + len(ranks_q),
                loading_ranks=s.loading_ranks(),
                link_busy_ms=max(0.0, s.cold.tracker.link_busy_until_ms()
                                 - ref),
                demand_link_ms=cb[CLS_DEMAND] + cb[CLS_PROMOTED],
                prefetch_link_ms=cb[CLS_PREFETCH],
                link_policy=s.link_policy,
                adapter_ready=slot is not None and s.pool.is_ready(slot),
                adapter_loading=slot is not None
                and not s.pool.is_ready(slot),
                free_pages=s.free_pages(),
                # memory-demand steering (paged servers): the request's KV
                # pages plus, when the adapter is not yet resident, the
                # pages its upload would claim from the same unified pool
                req_pages=(s.kv_page_demand(req)
                           + (0 if slot is not None or uid not in s.store
                              else s.pool.pages_for(
                                  s.store.specs[uid].nbytes(s.cfg))))
                if req is not None else 0,
                # KV over-subscription telemetry: lifetime counters plus
                # the windowed preemption rate calc_cost charges as extra
                # per-token cost (steering arrivals off thrashing pools)
                preemptions=s.preempt_stats["preemptions"],
                swapped_kv_pages=s.preempt_stats["swapped_pages"],
                recompute_tokens=s.preempt_stats["recompute_tokens"],
                oversub_ratio=s.oversub_ratio(),
                preempt_pressure=s.preempt_pressure(ref),
                # prefill plane: decode commitment depth + chunk budget let
                # calc_cost price the interference a routed prompt's
                # prefill inflicts on the resident decode batch
                decode_commit_tokens=s.decode_commit_tokens(),
                chunk_budget=s.chunk_budget,
                itl_p50_ms=itl.get("itl_p50_ms", 0.0),
                itl_p99_ms=itl.get("itl_p99_ms", 0.0),
                # failure plane: a browned-out link stretches the cold
                # start terms in calc_cost; fault/retry history steers
                # arrivals off flaky or freshly-restarted servers only
                # through the truthful occupancy stats above
                link_slowdown=s.cold.tracker.slowdown_at(ref),
                crashes=s.fault_stats["crashes"],
                restarts=s.fault_stats["restarts"],
                upload_retries=s.cold.tracker.stats["retries"],
                shed_requests=s.admission.shed_count,
                adopted_requests=s.fault_stats["adopted_requests"],
            ))
        return out

    def _rank(self, uid: str) -> Optional[int]:
        sp = self.specs.get(uid)
        if sp is None:            # registered on a server after __init__
            for s in self.servers:
                if uid in s.store:
                    sp = s.store.specs[uid]
                    self.specs[uid] = sp
                    break
        return sp.rank if sp is not None else None

    # ---------------------------------------------------------- routing ----
    def _should_shed(self, req: Request, rank: Optional[int],
                     stats: List[ServerStats]) -> bool:
        """Brownout back-pressure (`shed_policy="slo"`): when *every*
        alive server is decode-SLO-saturated, admitting one more request
        only deepens the violation — reject it at the router instead, a
        controlled SLO miss counted by `summarize`. Crash failovers never
        reach here (`allow_shed=False`): a recovered request always
        lands."""
        if self.shed_policy != "slo" or rank is None:
            return False
        sat = getattr(self.scheduler, "saturated", None)
        alive = [stats[i] for i in self._alive()]
        return sat is not None and bool(alive) \
            and sat(rank, alive, prefill_tokens=req.prompt_len)

    def _route(self, req: Request, now_ms: Optional[float] = None,
               allow_shed: bool = True) -> Optional[int]:
        """Pick a server for `req`; returns None when the request is shed
        (only possible with `shed_policy="slo"` and `allow_shed`).
        `now_ms` overrides the stats reference time for re-routing after
        a crash — the failover decision must see link/batch occupancy at
        crash time, not at the original arrival."""
        uid = req.adapter_uid
        rank = self._rank(uid)
        t0 = req.arrival_ms if now_ms is None else now_ms
        if self.placement is None:
            stats = self._stats(uid, t0, req=req)
            if allow_shed and self._should_shed(req, rank, stats):
                return None
            return self.scheduler.route(rank, stats,
                                        prefill_tokens=req.prompt_len)
        hosting = {i for i in self.placement.hosts(uid)
                   if i not in self.down}
        stats = self._stats(uid, t0, hosting, req=req)
        if allow_shed and self._should_shed(req, rank, stats):
            return None
        if hosting:
            sat = getattr(self.scheduler, "saturated", None)
            if sat is None or not sat(rank, [stats[i]
                                             for i in sorted(hosting)],
                                      prefill_tokens=req.prompt_len):
                return self.scheduler.route(rank, stats,
                                            prefill_tokens=req.prompt_len)
        # register-on-miss: no live replica, or every replica SLO-saturated.
        if uid not in self.specs:
            raise LookupError(f"unknown adapter {uid!r}: not registered "
                              "with the cluster")
        # Open the candidate set to every live server; servers whose host
        # store lacks the adapter are charged the one-time install on top
        # of the cold upload (a replica dropped from the routing map keeps
        # its store weights — and possibly a ready pool slot — so its
        # truthful adapter_ready/adapter_loading stats stand)
        for i in self._alive():
            if i in hosting:
                continue
            stats[i].hosts_adapter = True
            if uid not in self.servers[i].store:
                stats[i].miss_install_ms = self.miss_install_ms
        idx = self.scheduler.route(rank, stats,
                                   prefill_tokens=req.prompt_len)
        if idx not in hosting:
            if uid not in self.servers[idx].store:
                self.servers[idx].install_adapter(self.specs[uid], t0)
                self.placement_stats["miss_installs"] += 1
            else:
                self.placement_stats["replica_readds"] += 1
            self.placement.add_replica(uid, idx)
        return idx

    # -------------------------------------------------------- rebalance ----
    def _rebalance(self, now_ms: float):
        """Popularity-EWMA-driven replica add/drop pass: an adapter carrying
        share p of the aggregate EWMA targets
        ``ceil(p * n_alive * replica_spread)`` replicas (>=1, capped)."""
        if self.placement is None:
            return
        pop: Dict[str, float] = {}
        for s in self.servers:
            # time-indexed snapshot: every server's EWMA is faded to the
            # same instant, so a server whose traffic dried up does not
            # contribute a frozen peak score
            for u, v in s.admission.popularity(now_ms).items():
                pop[u] = pop.get(u, 0.0) + v
        total = sum(pop.values())
        alive = self._alive()
        if total <= 0.0 or not alive:
            return
        n = len(alive)
        adds_left = self.rebalance_max_adds
        for uid in sorted(pop, key=pop.get, reverse=True):
            if uid not in self.specs:
                continue
            target = replica_target(pop[uid] / total, n,
                                    self.replica_spread, self.max_replicas)
            hosts = [i for i in self.placement.hosts(uid)
                     if i not in self.down]
            while len(hosts) < target and adds_left > 0:
                cands = [i for i in alive
                         if i not in self.placement.hosts(uid)]
                if not cands:
                    break
                i = min(cands, key=self._server_load)
                srv = self.servers[i]
                srv.install_adapter(self.specs[uid], now_ms)
                self.placement.add_replica(uid, i)
                self.placement_stats["replica_adds"] += 1
                adds_left -= 1
                # warm the new replica: a speculative (prefetch-class)
                # upload rides the link; slots of running requests are
                # pinned (never the victim); if no slot is evictable the
                # first demand admit pays the upload instead. Under the
                # preempt link policy a demand cold start may cancel this
                # warm-up while it is still queued — the replica then warms
                # on first admission. A re-added replica may still be
                # resident from before its drop — no second upload then
                if srv.pool.lookup(uid) is None:
                    srv.cold.load_async(uid, max(now_ms, srv.clock),
                                        pinned=tuple(
                                            srv.admission.pinned_slots()),
                                        demand=False)
                hosts.append(i)
            while len(hosts) > target and len(hosts) > 1:
                i = max(hosts, key=self._server_load)
                if not self.placement.drop_replica(uid, i):
                    break
                self.placement_stats["replica_drops"] += 1
                hosts.remove(i)

    # ------------------------------------------------------ event-driven ----
    def run(self, requests: List[Request], max_iters: int = 2_000_000):
        if self.engine == "lockstep":
            return self._run_lockstep(requests, max_iters)
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        heap: list = []
        seq = 0
        for req in pending:
            heapq.heappush(heap, (req.arrival_ms, ARRIVAL, seq, -1, req))
            seq += 1
        if pending and self.placement is not None \
                and self.rebalance_every_ms:
            t0 = pending[0].arrival_ms + self.rebalance_every_ms
            heapq.heappush(heap, (t0, REBALANCE, seq, -1, None))
            seq += 1
        if self.faults is not None:
            # flaky windows + brownouts hook the trackers directly; only
            # crash/restart are timeline events
            self.faults.attach(self)
            for fe in self.faults.timed_events():
                heapq.heappush(heap, (fe.t_ms, FAULT, seq, fe.server, fe))
                seq += 1
        n_arrived = 0                 # arrivals pop in time order: a pointer
        scheduled = [False] * len(self.servers)
        iters = 0

        def schedule(i: int, t: float):
            nonlocal seq
            if scheduled[i]:
                return
            t = max(t, self.servers[i].clock)
            heapq.heappush(heap, (t, WAKE, seq, i, None))
            scheduled[i] = True
            seq += 1

        while heap and iters < max_iters:
            t, kind, _, i, payload = heapq.heappop(heap)
            if kind == FAULT:
                self.event_counts["fault"] += 1
                if payload.kind == "crash":
                    for j in self._crash(i, t):
                        schedule(j, t)   # survivors adopt drained work now
                else:
                    self._restart(i, t)
                    schedule(i, t)       # harmless if it has nothing to do
                continue
            if kind == ARRIVAL:
                self.event_counts["arrival"] += 1
                n_arrived += 1
                idx = self._route(payload)
                if idx is None:          # brownout shed: controlled miss
                    st = RequestState(payload)
                    st.phase = "shed"
                    st.shed = True
                    self.shed_states.append(st)
                    self.fault_stats["shed"] += 1
                    if self.faults is not None:
                        self.faults.record(t, "shed", -1,
                                           f"rid={payload.rid}")
                    continue
                self.servers[idx].submit(payload)
                schedule(idx, t)
                continue
            if kind == REBALANCE:
                self.event_counts["rebalance"] += 1
                self._rebalance(t)
                if n_arrived < len(pending) \
                        or any(s.busy() for s in self.servers):
                    heapq.heappush(heap, (t + self.rebalance_every_ms,
                                          REBALANCE, seq, -1, None))
                    seq += 1
                continue
            # WAKE: classify from the cold-start plane's state *now* — an
            # upload that began (or retired) since the event was pushed is
            # labeled by what the server actually wakes to: a finish due
            # by t, or completions a routing-time poll already retired but
            # the engine has not drained yet
            scheduled[i] = False
            if i in self.down:
                continue                 # stale wake for a crashed server
            s = self.servers[i]
            nf = s.cold.tracker.next_finish_ms()
            load_done = (nf is not None and nf <= t) \
                or s.cold.pending_completions() > 0
            self.event_counts["load_done" if load_done else "iter"] += 1
            if not s.busy():
                continue
            if s.clock < t:
                s.clock = t          # idle server woken by a later event
            horizon = pending[n_arrived].arrival_ms \
                if n_arrived < len(pending) else None
            s.step(horizon_ms=horizon)
            iters += 1
            if s.busy():
                schedule(i, s.clock)
        return self._summarize()

    def _summarize(self):
        for s in self.servers:
            if s.backend:                # drain async token readbacks
                s.backend.flush_readback()
        states = [st for s in self.servers for st in s.states]
        states += self.shed_states       # zero-lost: n + shed == submitted
        return summarize(states), states

    # --------------------------------------------------- lockstep oracle ----
    def _advance(self, until_ms: float):
        for s in self.servers:
            while s.busy() and s.clock < until_ms:
                s.step(horizon_ms=until_ms)
            if s.clock < until_ms:
                s.clock = until_ms

    def _run_lockstep(self, requests: List[Request],
                      max_iters: int = 2_000_000):
        # placement-aware routing (incl. register-on-miss) is shared with
        # the event engine via _route; the rebalance pass is event-driven
        # only — lockstep is the static-placement oracle
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        for req in pending:
            self._advance(req.arrival_ms)
            self.servers[self._route(req)].submit(req)
        iters = 0
        while any(s.busy() for s in self.servers) and iters < max_iters:
            for s in self.servers:
                if s.busy():
                    s.step()
            iters += 1
        return self._summarize()
