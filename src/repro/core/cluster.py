"""Multi-server cluster simulation (paper sec 7.5): N inference servers, a
front-end scheduler, trace-driven arrivals.

Event-driven: a global event heap orders request arrivals, per-server wake
events (iteration completions / adapter load completions, classified at pop
time from the tracker's state), and periodic placement-rebalance passes;
each server advances its own virtual clock only when an event fires for it,
replacing the old lockstep advance-everyone-to-the-next-arrival loop. The
lockstep engine is kept (``engine="lockstep"``) as a cross-check oracle —
the event loop must reproduce its summary metrics within tolerance
(tests/test_load_tracker.py).

Placement plane (core/placement.py): when a ``Placement`` is given, each
adapter lives on a *subset* of servers and the scheduler routes only among
live hosting replicas. When no replica is alive — or every replica would
break the decode SLO (``RankAwareScheduler.saturated``) — the cluster falls
back to **register-on-miss**: the candidate set opens to every live server
with a one-time install cost (``ServerStats.miss_install_ms``) charged in
the routing score, the winner's host store installs the adapter mid-run
(``InferenceServer.install_adapter``; the host-side install is charged in
routing but approximated as instantaneous on the timeline — the device
upload it triggers pays the real link cost through the existing
``LoadTracker``), and the placement map gains the replica. A rebalance pass
driven by the admission plane's popularity EWMA adds replicas of hot
adapters (warmed by a speculative link upload) and drops surplus replicas
of cooled ones over simulated time.

Servers are InferenceServer instances (numerics usually disabled at cluster
scale — same timeline engine the single-server evaluation uses, matching the
paper's simulator methodology). The scheduler observes in-flight loads
(ServerStats.loading_ranks / link_busy_ms plus the per-class
demand_link_ms / prefetch_link_ms split) so rank-aware routing can steer
cold starts away from servers whose host link is saturated with demand
traffic — under the priority/preempt link policies, speculative prefetch
occupancy is jumped/canceled by a demand upload and correctly does not
count against the server. Upload finish times are recomputed by the link
scheduler on every insertion, so WAKE events never carry a cached
load_done timestamp: they are classified at pop time from
``next_finish_ms()`` / ``pending_completions()``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Set

from repro.core.cold_start import CLS_DEMAND, CLS_PREFETCH, CLS_PROMOTED
from repro.core.engine import InferenceServer
from repro.core.lora import AdapterSpec
from repro.core.placement import Placement, replica_target
from repro.core.scheduler import ServerStats
from repro.serving.request import Request, summarize

# event kinds, in tie-break priority order at equal timestamps: arrivals
# must be routed before a server iterates past them, and a rebalance pass
# sees the popularity updates of same-time arrivals. WAKE events are
# generic "server makes progress" events — whether one is an iteration or
# a load completion is classified at *pop* time from the tracker's state
# (an upload can begin or retire between push and pop).
ARRIVAL, REBALANCE, WAKE = 0, 1, 2

# default one-time host-store install cost charged (in the routing score
# only) when a request must be placed on a server that does not host its
# adapter — stands in for the registry fetch that precedes the upload
MISS_INSTALL_MS = 25.0


class Cluster:
    def __init__(self, servers: Sequence[InferenceServer], scheduler,
                 engine: str = "events",
                 placement: Optional[Placement] = None,
                 specs: Optional[Sequence[AdapterSpec]] = None,
                 rebalance_every_ms: Optional[float] = None,
                 replica_spread: float = 1.5,
                 max_replicas: Optional[int] = None,
                 rebalance_max_adds: int = 8,
                 miss_install_ms: float = MISS_INSTALL_MS):
        if engine not in ("events", "lockstep"):
            raise ValueError(f"unknown engine {engine!r}")
        self.servers = list(servers)
        self.scheduler = scheduler
        self.engine = engine
        self.placement = placement
        self.rebalance_every_ms = rebalance_every_ms
        self.replica_spread = replica_spread
        self.max_replicas = max_replicas
        self.rebalance_max_adds = rebalance_max_adds
        self.miss_install_ms = miss_install_ms
        self.down: Set[int] = set()
        self.event_counts = {"arrival": 0, "iter": 0, "load_done": 0,
                             "rebalance": 0}
        self.placement_stats = {"miss_installs": 0, "replica_adds": 0,
                                "replica_drops": 0, "replica_readds": 0}
        # cluster-wide adapter registry (rank lookup + late installs)
        self.specs: Dict[str, AdapterSpec] = {}
        for sp in specs or ():
            self.specs[sp.uid] = sp
        for s in self.servers:
            self.specs.update(s.store.specs)
        if placement is not None:
            if placement.n_servers != len(self.servers):
                raise ValueError(
                    f"placement spans {placement.n_servers} servers but the "
                    f"cluster has {len(self.servers)}")
            # materialize the assignment: each hosting server registers its
            # shard (servers may be built bare)
            for uid in list(self.specs):
                for i in placement.hosts(uid):
                    self.servers[i].install_adapter(self.specs[uid])

    # ----------------------------------------------------------- health ----
    def set_down(self, i: int):
        self.down.add(i)

    def set_up(self, i: int):
        self.down.discard(i)

    def _alive(self) -> List[int]:
        return [i for i in range(len(self.servers)) if i not in self.down]

    def _server_load(self, i: int) -> int:
        s = self.servers[i]
        return len(s.queue) + sum(r is not None for r in s.rows)

    # ------------------------------------------------------------ stats ----
    def _stats(self, uid: str, now_ms: float,
               hosting: Optional[Set[int]] = None,
               req: Optional[Request] = None) -> List[ServerStats]:
        out = []
        for i, s in enumerate(self.servers):
            # retire uploads that finished (in simulated time) by the
            # arrival: an idle server's tracker is only polled inside
            # step(), so its resident/loading view can be stale here. A
            # server mid-iteration can be ahead of the arrival; its link
            # occupancy is measured from the same reference, since a
            # request routed there cannot start before the server's clock
            ref = max(now_ms, s.clock)
            s.cold.poll(ref)
            cb = s.cold.tracker.class_busy_ms(ref)
            itl = s.itl_stats()
            ranks_run = s.running_ranks()
            ranks_q = [s.store.specs[r.req.adapter_uid].rank
                       for r in s.queue]
            slot = s.pool.lookup(uid)
            hosts = (i in hosting) if hosting is not None \
                else uid in s.store
            out.append(ServerStats(
                running_ranks=ranks_run,
                queued_ranks=ranks_q,
                hosts_adapter=hosts and i not in self.down,
                free_rows=sum(r is None for r in s.rows),
                n_requests=len(ranks_run) + len(ranks_q),
                loading_ranks=s.loading_ranks(),
                link_busy_ms=max(0.0, s.cold.tracker.link_busy_until_ms()
                                 - ref),
                demand_link_ms=cb[CLS_DEMAND] + cb[CLS_PROMOTED],
                prefetch_link_ms=cb[CLS_PREFETCH],
                link_policy=s.link_policy,
                adapter_ready=slot is not None and s.pool.is_ready(slot),
                adapter_loading=slot is not None
                and not s.pool.is_ready(slot),
                free_pages=s.free_pages(),
                # memory-demand steering (paged servers): the request's KV
                # pages plus, when the adapter is not yet resident, the
                # pages its upload would claim from the same unified pool
                req_pages=(s.kv_page_demand(req)
                           + (0 if slot is not None or uid not in s.store
                              else s.pool.pages_for(
                                  s.store.specs[uid].nbytes(s.cfg))))
                if req is not None else 0,
                # KV over-subscription telemetry: lifetime counters plus
                # the windowed preemption rate calc_cost charges as extra
                # per-token cost (steering arrivals off thrashing pools)
                preemptions=s.preempt_stats["preemptions"],
                swapped_kv_pages=s.preempt_stats["swapped_pages"],
                recompute_tokens=s.preempt_stats["recompute_tokens"],
                oversub_ratio=s.oversub_ratio(),
                preempt_pressure=s.preempt_pressure(ref),
                # prefill plane: decode commitment depth + chunk budget let
                # calc_cost price the interference a routed prompt's
                # prefill inflicts on the resident decode batch
                decode_commit_tokens=s.decode_commit_tokens(),
                chunk_budget=s.chunk_budget,
                itl_p50_ms=itl.get("itl_p50_ms", 0.0),
                itl_p99_ms=itl.get("itl_p99_ms", 0.0),
            ))
        return out

    def _rank(self, uid: str) -> Optional[int]:
        sp = self.specs.get(uid)
        if sp is None:            # registered on a server after __init__
            for s in self.servers:
                if uid in s.store:
                    sp = s.store.specs[uid]
                    self.specs[uid] = sp
                    break
        return sp.rank if sp is not None else None

    # ---------------------------------------------------------- routing ----
    def _route(self, req: Request) -> int:
        uid = req.adapter_uid
        rank = self._rank(uid)
        if self.placement is None:
            return self.scheduler.route(
                rank, self._stats(uid, req.arrival_ms, req=req),
                prefill_tokens=req.prompt_len)
        hosting = {i for i in self.placement.hosts(uid)
                   if i not in self.down}
        stats = self._stats(uid, req.arrival_ms, hosting, req=req)
        if hosting:
            sat = getattr(self.scheduler, "saturated", None)
            if sat is None or not sat(rank, [stats[i]
                                             for i in sorted(hosting)],
                                      prefill_tokens=req.prompt_len):
                return self.scheduler.route(rank, stats,
                                            prefill_tokens=req.prompt_len)
        # register-on-miss: no live replica, or every replica SLO-saturated.
        if uid not in self.specs:
            raise LookupError(f"unknown adapter {uid!r}: not registered "
                              "with the cluster")
        # Open the candidate set to every live server; servers whose host
        # store lacks the adapter are charged the one-time install on top
        # of the cold upload (a replica dropped from the routing map keeps
        # its store weights — and possibly a ready pool slot — so its
        # truthful adapter_ready/adapter_loading stats stand)
        for i in self._alive():
            if i in hosting:
                continue
            stats[i].hosts_adapter = True
            if uid not in self.servers[i].store:
                stats[i].miss_install_ms = self.miss_install_ms
        idx = self.scheduler.route(rank, stats,
                                   prefill_tokens=req.prompt_len)
        if idx not in hosting:
            if uid not in self.servers[idx].store:
                self.servers[idx].install_adapter(self.specs[uid],
                                                  req.arrival_ms)
                self.placement_stats["miss_installs"] += 1
            else:
                self.placement_stats["replica_readds"] += 1
            self.placement.add_replica(uid, idx)
        return idx

    # -------------------------------------------------------- rebalance ----
    def _rebalance(self, now_ms: float):
        """Popularity-EWMA-driven replica add/drop pass: an adapter carrying
        share p of the aggregate EWMA targets
        ``ceil(p * n_alive * replica_spread)`` replicas (>=1, capped)."""
        if self.placement is None:
            return
        pop: Dict[str, float] = {}
        for s in self.servers:
            # time-indexed snapshot: every server's EWMA is faded to the
            # same instant, so a server whose traffic dried up does not
            # contribute a frozen peak score
            for u, v in s.admission.popularity(now_ms).items():
                pop[u] = pop.get(u, 0.0) + v
        total = sum(pop.values())
        alive = self._alive()
        if total <= 0.0 or not alive:
            return
        n = len(alive)
        adds_left = self.rebalance_max_adds
        for uid in sorted(pop, key=pop.get, reverse=True):
            if uid not in self.specs:
                continue
            target = replica_target(pop[uid] / total, n,
                                    self.replica_spread, self.max_replicas)
            hosts = [i for i in self.placement.hosts(uid)
                     if i not in self.down]
            while len(hosts) < target and adds_left > 0:
                cands = [i for i in alive
                         if i not in self.placement.hosts(uid)]
                if not cands:
                    break
                i = min(cands, key=self._server_load)
                srv = self.servers[i]
                srv.install_adapter(self.specs[uid], now_ms)
                self.placement.add_replica(uid, i)
                self.placement_stats["replica_adds"] += 1
                adds_left -= 1
                # warm the new replica: a speculative (prefetch-class)
                # upload rides the link; slots of running requests are
                # pinned (never the victim); if no slot is evictable the
                # first demand admit pays the upload instead. Under the
                # preempt link policy a demand cold start may cancel this
                # warm-up while it is still queued — the replica then warms
                # on first admission. A re-added replica may still be
                # resident from before its drop — no second upload then
                if srv.pool.lookup(uid) is None:
                    srv.cold.load_async(uid, max(now_ms, srv.clock),
                                        pinned=tuple(
                                            srv.admission.pinned_slots()),
                                        demand=False)
                hosts.append(i)
            while len(hosts) > target and len(hosts) > 1:
                i = max(hosts, key=self._server_load)
                if not self.placement.drop_replica(uid, i):
                    break
                self.placement_stats["replica_drops"] += 1
                hosts.remove(i)

    # ------------------------------------------------------ event-driven ----
    def run(self, requests: List[Request], max_iters: int = 2_000_000):
        if self.engine == "lockstep":
            return self._run_lockstep(requests, max_iters)
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        heap: list = []
        seq = 0
        for req in pending:
            heapq.heappush(heap, (req.arrival_ms, ARRIVAL, seq, -1, req))
            seq += 1
        if pending and self.placement is not None \
                and self.rebalance_every_ms:
            t0 = pending[0].arrival_ms + self.rebalance_every_ms
            heapq.heappush(heap, (t0, REBALANCE, seq, -1, None))
            seq += 1
        n_arrived = 0                 # arrivals pop in time order: a pointer
        scheduled = [False] * len(self.servers)
        iters = 0

        def schedule(i: int, t: float):
            nonlocal seq
            if scheduled[i]:
                return
            t = max(t, self.servers[i].clock)
            heapq.heappush(heap, (t, WAKE, seq, i, None))
            scheduled[i] = True
            seq += 1

        while heap and iters < max_iters:
            t, kind, _, i, payload = heapq.heappop(heap)
            if kind == ARRIVAL:
                self.event_counts["arrival"] += 1
                n_arrived += 1
                idx = self._route(payload)
                self.servers[idx].submit(payload)
                schedule(idx, t)
                continue
            if kind == REBALANCE:
                self.event_counts["rebalance"] += 1
                self._rebalance(t)
                if n_arrived < len(pending) \
                        or any(s.busy() for s in self.servers):
                    heapq.heappush(heap, (t + self.rebalance_every_ms,
                                          REBALANCE, seq, -1, None))
                    seq += 1
                continue
            # WAKE: classify from the cold-start plane's state *now* — an
            # upload that began (or retired) since the event was pushed is
            # labeled by what the server actually wakes to: a finish due
            # by t, or completions a routing-time poll already retired but
            # the engine has not drained yet
            s = self.servers[i]
            nf = s.cold.tracker.next_finish_ms()
            load_done = (nf is not None and nf <= t) \
                or s.cold.pending_completions() > 0
            self.event_counts["load_done" if load_done else "iter"] += 1
            scheduled[i] = False
            if not s.busy():
                continue
            if s.clock < t:
                s.clock = t          # idle server woken by a later event
            horizon = pending[n_arrived].arrival_ms \
                if n_arrived < len(pending) else None
            s.step(horizon_ms=horizon)
            iters += 1
            if s.busy():
                schedule(i, s.clock)
        return self._summarize()

    def _summarize(self):
        for s in self.servers:
            if s.backend:                # drain async token readbacks
                s.backend.flush_readback()
        states = [st for s in self.servers for st in s.states]
        return summarize(states), states

    # --------------------------------------------------- lockstep oracle ----
    def _advance(self, until_ms: float):
        for s in self.servers:
            while s.busy() and s.clock < until_ms:
                s.step(horizon_ms=until_ms)
            if s.clock < until_ms:
                s.clock = until_ms

    def _run_lockstep(self, requests: List[Request],
                      max_iters: int = 2_000_000):
        # placement-aware routing (incl. register-on-miss) is shared with
        # the event engine via _route; the rebalance pass is event-driven
        # only — lockstep is the static-placement oracle
        pending = sorted(requests, key=lambda r: r.arrival_ms)
        for req in pending:
            self._advance(req.arrival_ms)
            self.servers[self._route(req)].submit(req)
        iters = 0
        while any(s.busy() for s in self.servers) and iters < max_iters:
            for s in self.servers:
                if s.busy():
                    s.step()
            iters += 1
        return self._summarize()
