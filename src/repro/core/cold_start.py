"""Cold-start handling: asynchronous adapter loading + CPU-assisted prefill
(paper sec 4).

Two pieces:

``LoadTracker`` — the scheduled host→device link. The host link is a serial
resource (bandwidth `hw.load_bw`, `hw.load_concurrency` parallel lanes):
concurrent cold starts queue behind each other, so K simultaneous uploads
finish at t0 + K * load_ms rather than all at t0 + load_ms as the old
instantaneous model assumed. Beyond plain FIFO, the link is *scheduled*:
every upload carries a priority class —

  CLS_DEMAND    — a cold start with a request waiting on it,
  CLS_PROMOTED  — a speculative prefetch that a demand admission caught
                  mid-flight (promoted to demand class),
  CLS_PREFETCH  — a speculative prefetch, no request attached,

and the link policy decides how queued (not-yet-started) uploads share the
lanes:

  fifo      — strict begin order (the legacy lane model; the parity oracle).
  priority  — queued uploads run in (class, begin-order); a newly arriving
              demand upload jumps every queued prefetch. Started uploads
              always run to completion (no mid-transfer abort).
  preempt   — priority ordering, plus a demand upload *cancels* every
              queued prefetch outright, reclaiming their link time and
              (via the ColdStartManager) their reserved device slots.

Because queued uploads can be reordered, their start/finish times are
provisional: they are *recomputed on every insertion, promotion, and
cancellation*. Consumers must not cache a finish time captured at begin()
unless the upload has started or is plain CLS_DEMAND (nothing jumps that
class; a *promoted* prefetch is demand-class yet can still be jumped by a
later plain demand while queued); the engine re-derives decode gates from
`pending_for(...)` each iteration, and the cluster event heap classifies
wakes from `next_finish_ms()` at pop time.

The link is also where the failure plane bites (`core/faults.py`): a
`fail_hook` installed by a `FaultPlane` can declare a finishing transfer
failed, in which case demand-class uploads retry with exponential backoff
plus deterministic jitter (a fresh `LoadEvent`, `attempt + 1`, re-entering
the queue at its class — demand retries still jump queued prefetch) while
speculative prefetches are dropped outright (their slot reservation is
released via `drain_gave_up`). The retry budget is structural: once
`attempt` reaches `retry_budget` the hook is no longer consulted, so the
final attempt cannot fail and no request is ever stranded on a flaky
link. `brownouts` windows scale transfer times of uploads *starting*
inside the window (`_xfer_ms`), and `cancel_all` models a fail-stop crash
of the device the link feeds: every upload — queued or mid-transfer — is
aborted and must never retire (LinkSan enforces both invariants).

``ColdStartManager.admit`` — returns the admission timeline for a newly
admitted request under the engine's operating mode:

  CACHED     — oracle: adapter already on device, no load (paper sec 7.1).
  ONDMD      — on-demand blocking load: decode of in-flight requests stalls
               behind Load+Prefill (paper Fig 2).
  SLORA      — same loading behaviour as ONDMD (S-LoRA loads on demand); the
               kernel differs (MBGMV).
  CARASERVE  — CPU-assisted: host CPUs early-start the prefill's LoRA
               computation while the adapter uploads; the GPU/TPU runs the
               adapter-agnostic base prefill concurrently, switching the LoRA
               path to the device once the upload completes (paper Fig 1/7).

The numerics of the host-assist path are identical to the device path by
construction (same x·A·B, computed from the host copy of the weights); the
timeline model quantifies the overlap. Layer-wise coordination costs use the
sync-free-invocation and shared-memory constants (paper Figs 8, 16-18).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis import sanitizers
from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import TimingModel

MODES = ("cached", "ondemand", "slora", "caraserve")

# priority classes on the shared host link (lower = more urgent)
CLS_DEMAND, CLS_PROMOTED, CLS_PREFETCH = 0, 1, 2
LINK_POLICIES = ("fifo", "priority", "preempt")

# upload-retry defaults: a demand upload survives up to RETRY_BUDGET
# transient failures (the attempt after the budget is structurally
# infallible — liveness), backing off base * 2^attempt * (1 + jitter*u)
RETRY_BUDGET = 6
RETRY_BASE_MS = 4.0
RETRY_JITTER = 0.5


@dataclasses.dataclass
class AdmitPlan:
    prefill_ms: float          # time to produce the first token (post queue)
    ready_decode_ms: float     # absolute clock when decode iterations may include this request
    blocking_ms: float         # serial stall imposed on the whole iteration (Fig 2 "Load")
    cold: bool
    assist: bool               # CPU-assist engaged
    slot: int                  # device pool slot assigned
    load_finish_ms: Optional[float] = None  # upload completion (None: resident)


@dataclasses.dataclass
class LoadEvent:
    """One host→device adapter upload occupying the shared link.

    `start_ms`/`finish_ms` are provisional while the upload is queued (the
    link scheduler recomputes them on every insertion); they are final once
    `started` is True — a started upload is never aborted."""
    uid: str
    slot: int
    nbytes: int
    request_ms: float          # when the upload was requested
    start_ms: float            # when a link lane takes (or took) it
    finish_ms: float
    seq: int                   # begin order; deterministic tie-break
    demand: bool = True        # False: speculative prefetch, no request yet
    cls: int = CLS_DEMAND      # CLS_DEMAND | CLS_PROMOTED | CLS_PREFETCH
    started: bool = False
    canceled: bool = False
    attempt: int = 0           # 0: first try; >0: retry after a failure


class LoadTracker:
    """Priority-aware upload scheduler over the shared host→device link.

    Started uploads occupy their lane to completion; queued uploads are
    (re)ordered by the link policy — `fifo` preserves begin order, while
    `priority`/`preempt` run demand-class uploads first, so a queued
    prefetch never delays a demand cold start. `complete_until` retires
    finished uploads in deterministic (finish, begin-seq) order.

    Telemetry (`stats`): per-class begin counts, promotions, preempt
    cancellations, and `demand_delayed_by_prefetch` — the number of demand
    uploads whose start time would have been earlier had no speculative
    upload been queued ahead of them. The tracker only *schedules*;
    cancellation is orchestrated by the ColdStartManager (which owns the
    device-slot reservations), so the preempt guarantee — a demand upload
    is never delayed by a queued prefetch, counter stays 0 — holds for
    uploads begun through `ColdStartManager.load_async`, not for raw
    `begin()` calls on a bare tracker.
    """

    def __init__(self, tm: TimingModel, concurrency: Optional[int] = None,
                 policy: str = "fifo"):
        if policy not in LINK_POLICIES:
            raise ValueError(f"unknown link policy {policy!r}")
        self.tm = tm
        self.policy = policy
        n = concurrency or getattr(tm.hw, "load_concurrency", 1)
        self._lane_free_ms = [0.0] * max(1, n)
        self._seq = 0
        self._now = 0.0
        self._running: List[LoadEvent] = []
        self._queued: List[LoadEvent] = []
        self.stats = {"demand": 0, "promoted": 0, "prefetch": 0,
                      "preempted": 0, "demand_delayed_by_prefetch": 0,
                      "upload_failures": 0, "retries": 0,
                      "prefetch_dropped": 0, "crash_canceled": 0}
        # failure plane (core/faults.py installs these): fail_hook decides
        # whether a finishing transfer failed; brownouts are
        # (start, end, slowdown) windows scaling transfer times
        self.fail_hook: Optional[Callable[[LoadEvent], bool]] = None
        self.retry_budget = RETRY_BUDGET
        self.retry_base_ms = RETRY_BASE_MS
        self.retry_jitter = RETRY_JITTER
        self.retry_seed = 0
        self.brownouts: List[Tuple[float, float, float]] = []
        self._gave_up: List[LoadEvent] = []
        # LinkSan (REPRO_SANITIZE=1): happens-before checks on the link
        # schedule — started uploads frozen, retirements monotone, and the
        # preempt policy's demand-never-behind-prefetch guarantee enforced
        # at every manager-mediated demand begin.
        self.san = sanitizers.LinkSan() if sanitizers.enabled() else None

    # --------------------------------------------------------- schedule ----
    @property
    def inflight(self) -> List[LoadEvent]:
        """Every upload not yet retired (started + queued), in begin order."""
        return sorted(self._running + self._queued, key=lambda e: e.seq)

    def _key(self, ev: LoadEvent):
        if self.policy == "fifo":
            return (0, ev.seq)
        return (ev.cls, ev.seq)

    def _pick_lane(self, free: List[float]) -> int:
        return min(range(len(free)), key=lambda i: free[i])

    def slowdown_at(self, t_ms: float) -> float:
        """Brownout factor for a transfer starting at `t_ms` (1.0 when no
        window covers it; overlapping windows take the worst factor)."""
        f = 1.0
        for t0, t1, factor in self.brownouts:
            if t0 <= t_ms < t1:
                f = max(f, factor)
        return f

    def _xfer_ms(self, nbytes: int, start_ms: float) -> float:
        """Transfer duration on this link for an upload starting at
        `start_ms` — the base model scaled by any brownout window covering
        the start. Every schedule projection (dispatch, reschedule,
        occupancy, LinkSan's replay) must use this, not `tm.load_ms`."""
        return self.tm.load_ms(nbytes) * self.slowdown_at(start_ms)

    def _take(self, free: List[float], ev: LoadEvent) -> float:
        """The one greedy lane-projection rule, shared by real dispatch and
        every provisional schedule: the earliest-free lane takes `ev`;
        returns the start time and advances that lane past the transfer.
        (No flooring at the link clock: a lane that freed in the past takes
        a queued upload at the free time, matching actual dispatch.)"""
        lane = self._pick_lane(free)
        start = max(free[lane], ev.request_ms)
        free[lane] = start + self._xfer_ms(ev.nbytes, start)
        return start

    def _dispatch(self):
        """Lanes free by the link clock take the highest-priority queued
        upload; chained so advancing far ahead drains the whole queue.
        Retries backing off (request_ms in the future) are not eligible —
        the lane must not idle reserved for them, so other queued uploads
        may jump a backing-off retry regardless of class."""
        while self._queued:
            if min(self._lane_free_ms) > self._now:
                break
            cands = [e for e in self._queued if e.request_ms <= self._now]
            if not cands:
                break
            ev = min(cands, key=self._key)
            self._queued.remove(ev)
            ev.start_ms = self._take(self._lane_free_ms, ev)
            ev.finish_ms = ev.start_ms + self._xfer_ms(ev.nbytes,
                                                       ev.start_ms)
            ev.started = True
            self._running.append(ev)
            if self.san is not None:
                self.san.on_start(ev)

    def _advance(self, now_ms: float):
        self._now = max(self._now, now_ms)
        self._dispatch()

    def _reschedule(self):
        """Recompute provisional start/finish of every queued upload by
        projecting the policy order onto the lanes (called on insertion,
        promotion, and cancellation — queued finish times are never stale)."""
        free = list(self._lane_free_ms)
        for ev in sorted(self._queued, key=self._key):
            ev.start_ms = self._take(free, ev)
            ev.finish_ms = ev.start_ms + self._xfer_ms(ev.nbytes,
                                                       ev.start_ms)
        if self.san is not None:
            self.san.check_schedule(self)

    def _undelayed_start(self, ev: LoadEvent) -> float:
        """Start time `ev` would get with no queued prefetch ahead of it —
        the reference for the delayed-by-prefetch counter."""
        free = list(self._lane_free_ms)
        for e in sorted(self._queued, key=self._key):
            if e is ev:
                break
            if e.cls != CLS_PREFETCH:
                self._take(free, e)
        lane = self._pick_lane(free)
        return max(free[lane], ev.request_ms)

    # ----------------------------------------------------------- public ----
    def begin(self, uid: str, slot: int, nbytes: int, now_ms: float,
              demand: bool = True) -> LoadEvent:
        self._advance(now_ms)
        cls = CLS_DEMAND if demand else CLS_PREFETCH
        ev = LoadEvent(uid, slot, nbytes, now_ms, now_ms, now_ms, self._seq,
                       demand=demand, cls=cls)
        self._seq += 1
        self._queued.append(ev)
        self._dispatch()          # a lane free right now takes it immediately
        self._reschedule()
        self.stats["demand" if ev.demand else "prefetch"] += 1
        if ev.demand and not ev.started:
            if ev.start_ms > self._undelayed_start(ev) + 1e-9:
                self.stats["demand_delayed_by_prefetch"] += 1
        return ev

    def promote(self, uid: str, now_ms: float) -> Optional[LoadEvent]:
        """A demand admission found its adapter mid-prefetch: the in-flight
        upload joins the demand class (CLS_PROMOTED). A queued upload jumps
        ahead of the remaining speculative ones (priority/preempt reorder);
        a started one keeps its lane — only its class/telemetry change."""
        self._advance(now_ms)
        ev = self.pending_for(uid)
        if ev is None or ev.demand:
            return ev
        ev.cls = CLS_PROMOTED
        ev.demand = True
        self.stats["promoted"] += 1
        self._reschedule()
        return ev

    def cancel_queued_prefetch(self) -> List[LoadEvent]:
        """Drop every queued (not-yet-started) speculative upload — the
        `preempt` policy reclaims the link for demand traffic; the caller
        must release the canceled events' device-slot reservations."""
        out = [e for e in self._queued if e.cls == CLS_PREFETCH]
        for e in out:
            e.canceled = True
            self._queued.remove(e)
        self.stats["preempted"] += len(out)
        self._reschedule()
        return out

    def cancel_one_queued_prefetch(self) -> Optional[LoadEvent]:
        """Drop the *last-scheduled* queued speculative upload (the one the
        policy would run last) — the `priority` policy's minimal slot
        reclaim: earlier speculative work survives."""
        cands = [e for e in self._queued if e.cls == CLS_PREFETCH]
        if not cands:
            return None
        ev = max(cands, key=self._key)
        ev.canceled = True
        self._queued.remove(ev)
        self.stats["preempted"] += 1
        self._reschedule()
        return ev

    def _backoff_ms(self, ev: LoadEvent) -> float:
        """Exponential backoff with deterministic jitter: the jitter draw
        is a hash of (uid, attempt, retry_seed), so two same-seed runs
        back off identically regardless of event interleaving."""
        u = zlib.crc32(f"{ev.uid}:{ev.attempt}:{self.retry_seed}"
                       .encode()) / 2.0 ** 32
        return self.retry_base_ms * (2.0 ** ev.attempt) \
            * (1.0 + self.retry_jitter * u)

    def _upload_fails(self, ev: LoadEvent) -> bool:
        """Consult the fault plane's hook — but never for a demand-class
        upload that has exhausted its retry budget: the escalated final
        attempt is structurally infallible, so no request waiting on an
        adapter (or KV swap-in) can be stranded by a flaky link."""
        if self.fail_hook is None or ev.canceled:
            return False
        if ev.cls != CLS_PREFETCH and ev.attempt >= self.retry_budget:
            return False
        return bool(self.fail_hook(ev))

    def _handle_failure(self, ev: LoadEvent) -> bool:
        """A transfer reached its finish time and failed. Demand-class
        uploads requeue as a fresh LoadEvent (attempt + 1) requested at
        failure + backoff — still demand class, so the retry jumps queued
        prefetch under priority/preempt. Speculative prefetches are simply
        dropped (parked on `_gave_up` until the manager releases their slot
        reservation). Returns True when a retry was requeued."""
        self.stats["upload_failures"] += 1
        if self.san is not None:
            self.san.on_fail(ev)
        if ev.cls == CLS_PREFETCH:
            ev.canceled = True
            self.stats["prefetch_dropped"] += 1
            self._gave_up.append(ev)
            return False
        t_retry = ev.finish_ms + self._backoff_ms(ev)
        retry = LoadEvent(ev.uid, ev.slot, ev.nbytes, t_retry, t_retry,
                          t_retry, self._seq, demand=ev.demand, cls=ev.cls,
                          attempt=ev.attempt + 1)
        self._seq += 1
        self._queued.append(retry)
        self.stats["retries"] += 1
        if self.san is not None:
            self.san.on_retry(ev, retry)
        return True

    def complete_until(self, now_ms: float) -> List[LoadEvent]:
        """Retire uploads finished by `now_ms`, strictly one at a time in
        (finish, seq) order. With a fault plane attached a finishing
        transfer may fail instead of retiring — demand uploads requeue
        with backoff, prefetches drop — and a requeued retry whose backoff
        expires inside this same window can start, finish, and retire
        *before* a longer transfer already in flight; taking the global
        minimum each step keeps retirements monotone in finish time."""
        self._advance(now_ms)
        done: List[LoadEvent] = []
        while True:
            cands = [e for e in self._running if e.finish_ms <= now_ms]
            if not cands:
                break
            ev = min(cands, key=lambda e: (e.finish_ms, e.seq))
            self._running.remove(ev)
            if self._upload_fails(ev):
                if self._handle_failure(ev):
                    self._reschedule()
                self._dispatch()
            else:
                if self.san is not None:
                    self.san.on_retire(ev)
                done.append(ev)
        return done

    def drain_gave_up(self) -> List[LoadEvent]:
        """Prefetch uploads dropped by the fault plane since the last
        drain; the manager releases their device-slot reservations."""
        out, self._gave_up = self._gave_up, []
        return out

    def cancel_all(self) -> List[LoadEvent]:
        """Fail-stop crash of the device this link feeds: every upload —
        queued or mid-transfer — is aborted. Canceled events never retire
        (LinkSan enforces it); the caller owns the device-slot cleanup.
        Lanes reset to the link clock: the restarted device gets a fresh
        link."""
        out = sorted(self._running + self._queued, key=lambda e: e.seq)
        for e in out:
            e.canceled = True
        self._running = []
        self._queued = []
        self._lane_free_ms = [self._now] * len(self._lane_free_ms)
        self.stats["crash_canceled"] += len(out)
        if self.san is not None:
            self.san.on_cancel(out)
        return out

    def pending_for(self, uid: str) -> Optional[LoadEvent]:
        for e in self._running:
            if e.uid == uid:
                return e
        for e in self._queued:
            if e.uid == uid:
                return e
        return None

    def next_finish_ms(self) -> Optional[float]:
        """Earliest upload completion under the *current* schedule. Queued
        uploads contribute their provisional finish — a later insertion can
        move it, so event loops must re-derive at pop time, never cache."""
        return min((e.finish_ms for e in self._running + self._queued),
                   default=None)

    # -------------------------------------------------------- telemetry ----
    def link_busy_until_ms(self, cls: int = CLS_DEMAND) -> float:
        """Earliest time a NEW upload of class `cls` could start: when the
        first lane drains of its running upload plus every queued upload
        the policy schedules ahead of the newcomer (fifo: all of them;
        priority/preempt: only classes <= `cls`). 0.0 when the link is
        idle. This is the earliest-*free*-lane delay — with
        `load_concurrency > 1` an idle lane means no queueing at all (the
        old max-over-lanes answer overestimated it)."""
        if not self._running and not self._queued:
            return 0.0
        newcomer = (0, self._seq) if self.policy == "fifo" \
            else (cls, self._seq)
        free = list(self._lane_free_ms)
        for e in sorted(self._queued, key=self._key):
            if self._key(e) <= newcomer:   # else the newcomer jumps it
                self._take(free, e)
        return min(free)

    def class_busy_ms(self, now_ms: float) -> Dict[int, float]:
        """Remaining link occupancy per priority class: transfer-ms still
        to move past `now_ms` for started uploads, full duration for queued
        ones."""
        out = {CLS_DEMAND: 0.0, CLS_PROMOTED: 0.0, CLS_PREFETCH: 0.0}
        for e in self._running:
            out[e.cls] += max(0.0, e.finish_ms - max(now_ms, e.start_ms))
        for e in self._queued:
            out[e.cls] += self._xfer_ms(e.nbytes, e.start_ms)
        return out

    def demand_busy_ms(self, now_ms: float) -> float:
        cb = self.class_busy_ms(now_ms)
        return cb[CLS_DEMAND] + cb[CLS_PROMOTED]

    def prefetch_busy_ms(self, now_ms: float) -> float:
        return self.class_busy_ms(now_ms)[CLS_PREFETCH]


class ColdStartManager:
    def __init__(self, tm: TimingModel, store: HostLoRAStore,
                 pool: DevicePool, mode: str = "caraserve",
                 tracker: Optional[LoadTracker] = None,
                 link_policy: str = "fifo"):
        if mode not in MODES:
            raise ValueError(f"unknown cold-start mode {mode!r}")
        self.tm = tm
        self.store = store
        self.pool = pool
        self.mode = mode
        self.tracker = tracker if tracker is not None \
            else LoadTracker(tm, policy=link_policy)
        self._completed: List[LoadEvent] = []

    # ------------------------------------------------------ async plane ----
    def poll(self, now_ms: float) -> List[LoadEvent]:
        """Retire uploads finished by `now_ms`; their slots become ready
        (eviction-eligible, prefetch-visible). Returns the events; they are
        also queued for `drain_completions` so the engine can flip in-flight
        requests to the device LoRA path even when a retire happened inside
        `admit`."""
        done = self.tracker.complete_until(now_ms)
        if done:
            for ev in done:
                # KV swap-in uploads (preemption resume) ride the link with
                # no device-pool slot (slot < 0): nothing to commit
                if ev.slot >= 0:
                    self.pool.commit(ev.slot)
            self._completed.extend(done)
        # speculative prefetches the fault plane failed are dropped, not
        # retried: give their reserved slots back to the evictable set
        for ev in self.tracker.drain_gave_up():
            if ev.slot >= 0:
                self.pool.release(ev.slot)
        return done

    def drain_completions(self) -> List[LoadEvent]:
        done, self._completed = self._completed, []
        return done

    def pending_completions(self) -> int:
        """Completions retired by a poll but not yet drained by the engine
        (cluster telemetry: a wake with these pending is a load_done)."""
        return len(self._completed)

    def _cancel_queued_prefetch(self):
        """Preempt queued speculative uploads and release their reserved
        device slots (the reservation never landed; the slot returns to the
        evictable set)."""
        for ev in self.tracker.cancel_queued_prefetch():
            self.pool.release(ev.slot)

    def load_async(self, uid: str, now_ms: float, pinned=(),
                   demand: bool = True) -> Optional[LoadEvent]:
        """Reserve a slot and start an asynchronous upload (cold starts:
        demand=True; speculative prefetch: demand=False). Under the
        `preempt` link policy a demand upload first cancels every queued
        prefetch — reclaiming their link time and device slots. Returns
        None when every evictable slot is taken."""
        spec = self.store.specs[uid]
        nbytes = spec.nbytes(self.tm.cfg)
        w = self.store.weights(uid) if self.pool.materialize else None
        if demand and self.tracker.policy == "preempt":
            self._cancel_queued_prefetch()
        slot = self.pool.reserve(uid, w, spec.rank, pinned=pinned,
                                 nbytes=nbytes)
        if slot is None and demand and self.tracker.policy == "priority":
            # priority does not preempt eagerly: a demand admission blocked
            # only by queued speculative reservations cancels them one at a
            # time — last-scheduled first — until a slot frees up, so
            # earlier speculative work survives the reclaim
            while slot is None:
                ev = self.tracker.cancel_one_queued_prefetch()
                if ev is None:
                    break
                self.pool.release(ev.slot)
                slot = self.pool.reserve(uid, w, spec.rank, pinned=pinned,
                                         nbytes=nbytes)
        if slot is None:
            return None
        delayed_before = self.tracker.stats["demand_delayed_by_prefetch"]
        ev = self.tracker.begin(uid, slot, nbytes, now_ms, demand=demand)
        if demand and self.tracker.san is not None:
            self.tracker.san.on_demand_begin(self.tracker, ev,
                                             delayed_before)
        return ev

    def upload_kv(self, rid: int, nbytes: int, now_ms: float) -> LoadEvent:
        """Schedule a preempted request's KV swap-in on the host link. The
        payload competes for lanes as demand-class traffic (a request is
        waiting on it) but owns no device-pool slot — `poll` skips the
        commit for slot < 0. Under `preempt` it reclaims queued speculative
        link time exactly like an adapter cold start."""
        if self.tracker.policy == "preempt":
            self._cancel_queued_prefetch()
        delayed_before = self.tracker.stats["demand_delayed_by_prefetch"]
        ev = self.tracker.begin(f"kvswap:{rid}", -1, nbytes, now_ms,
                                demand=True)
        if self.tracker.san is not None:
            self.tracker.san.on_demand_begin(self.tracker, ev,
                                             delayed_before)
        return ev

    def _insert(self, uid: str, pinned=()) -> Optional[int]:
        """Synchronous insert (CACHED oracle: no upload modeled)."""
        spec = self.store.specs[uid]
        w = self.store.weights(uid) if self.pool.materialize else None
        return self.pool.insert(uid, w, spec.rank, pinned=pinned,
                                nbytes=spec.nbytes(self.tm.cfg))

    # ------------------------------------------------------- admission ----
    def admit(self, uid: str, now_ms: float, prompt_tokens: int,
              pinned=()) -> Optional[AdmitPlan]:
        self.poll(now_ms)        # uploads finished by now have landed
        spec = self.store.specs[uid]
        tm = self.tm
        base = tm.base_prefill_ms(prompt_tokens)
        gpu_lora = tm.lora_prefill_gpu_ms(prompt_tokens, spec.rank)
        slot = self.pool.lookup(uid)
        if slot is not None or self.mode == "cached":
            cold = slot is None
            if slot is None:
                slot = self._insert(uid, pinned)
                if slot is None:
                    return None          # no evictable slot: defer admission
            pre = base + gpu_lora
            if self.pool.is_ready(slot):
                return AdmitPlan(pre, now_ms + pre, 0.0, cold, False, slot)
            # resident but still uploading (admitted moments ago by another
            # request, or prefetched): no new transfer, but decode must wait
            # for the in-flight upload to land. A speculative prefetch hit
            # is *promoted* to demand class — a request now rides it, so
            # link policies and free-ride accounting must see a demand
            # upload (and under priority/preempt it jumps the queue).
            ev = self.tracker.pending_for(uid)
            if ev is not None and not ev.demand:
                ev = self.tracker.promote(uid, now_ms)
            finish = ev.finish_ms if ev else now_ms
            rem = max(0.0, finish - now_ms)
            if self.mode in ("ondemand", "slora"):
                # the blocking stall `rem` is charged into the iteration
                # *now*, from the schedule as of this admission. A queued
                # promoted upload can still be jumped by a later plain
                # demand (its finish moves), but a serial stall already
                # folded into the timeline cannot be retro-extended — the
                # engine's per-step re-derivation raises the row's decode
                # gate to the true landing, so only the stall accounting
                # (not decode correctness) is approximate under
                # priority/preempt. Exact under fifo.
                pre = rem + base + gpu_lora
                return AdmitPlan(pre, now_ms + pre, rem, False, False, slot,
                                 load_finish_ms=finish)
            cpu_lora = tm.cpu_lora_prefill_ms(prompt_tokens, spec.rank)
            pre = max(base, min(cpu_lora, rem + gpu_lora))
            ready = max(now_ms + pre, finish)
            return AdmitPlan(pre, ready, 0.0, False, rem > 0.0, slot,
                             load_finish_ms=finish)

        # true cold start: the upload queues on the shared host link — its
        # effective duration includes waiting behind concurrent uploads
        ev = self.load_async(uid, now_ms, pinned)
        if ev is None:
            return None                   # no evictable slot: defer admission
        slot = ev.slot
        t_load = ev.finish_ms - now_ms
        if self.mode in ("ondemand", "slora"):
            pre = t_load + base + gpu_lora
            return AdmitPlan(pre, now_ms + pre, t_load, True, False, slot,
                             load_finish_ms=ev.finish_ms)

        # caraserve: overlap upload with prefill; switch to device LoRA when
        # the upload finishes mid-prefill if that is faster than pure host.
        cpu_lora = tm.cpu_lora_prefill_ms(prompt_tokens, spec.rank)
        lora_path = min(cpu_lora, t_load + gpu_lora)
        pre = max(base, lora_path)
        ready = max(now_ms + pre, ev.finish_ms)
        return AdmitPlan(pre, ready, 0.0, True, True, slot,
                         load_finish_ms=ev.finish_ms)
