"""Cold-start handling: asynchronous adapter loading + CPU-assisted prefill
(paper sec 4).

Two pieces:

``LoadTracker`` — the asynchronous host→device upload state machine. The
host link is a serial resource (bandwidth `hw.load_bw`, `hw.load_concurrency`
parallel lanes): concurrent cold starts queue behind each other, so K
simultaneous uploads finish at t0 + K * load_ms rather than all at t0 +
load_ms as the old instantaneous model assumed. Uploads begun here complete
when the engine (or cluster event loop) polls past their finish time; the
completion event flips the request from the CPU-assist LoRA path to the
device pool mid-flight (paper Fig 1/7 semantics).

``ColdStartManager.admit`` — returns the admission timeline for a newly
admitted request under the engine's operating mode:

  CACHED     — oracle: adapter already on device, no load (paper sec 7.1).
  ONDMD      — on-demand blocking load: decode of in-flight requests stalls
               behind Load+Prefill (paper Fig 2).
  SLORA      — same loading behaviour as ONDMD (S-LoRA loads on demand); the
               kernel differs (MBGMV).
  CARASERVE  — CPU-assisted: host CPUs early-start the prefill's LoRA
               computation while the adapter uploads; the GPU/TPU runs the
               adapter-agnostic base prefill concurrently, switching the LoRA
               path to the device once the upload completes (paper Fig 1/7).

The numerics of the host-assist path are identical to the device path by
construction (same x·A·B, computed from the host copy of the weights); the
timeline model quantifies the overlap. Layer-wise coordination costs use the
sync-free-invocation and shared-memory constants (paper Figs 8, 16-18).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import TimingModel

MODES = ("cached", "ondemand", "slora", "caraserve")


@dataclasses.dataclass
class AdmitPlan:
    prefill_ms: float          # time to produce the first token (post queue)
    ready_decode_ms: float     # absolute clock when decode iterations may include this request
    blocking_ms: float         # serial stall imposed on the whole iteration (Fig 2 "Load")
    cold: bool
    assist: bool               # CPU-assist engaged
    slot: int                  # device pool slot assigned
    load_finish_ms: Optional[float] = None  # upload completion (None: resident)


@dataclasses.dataclass
class LoadEvent:
    """One host→device adapter upload occupying the shared link."""
    uid: str
    slot: int
    nbytes: int
    request_ms: float          # when the upload was requested
    start_ms: float            # when a link lane became free for it
    finish_ms: float
    seq: int                   # begin order; deterministic tie-break
    demand: bool = True        # False: speculative prefetch, no request yet


class LoadTracker:
    """Asynchronous upload state machine over the shared host→device link.

    `begin` enqueues an upload on the least-loaded link lane (FIFO per lane;
    `hw.load_concurrency` lanes, default 1 — a single PCIe/DMA stream), so
    simultaneous cold starts serialize and each one's finish time reflects
    the queueing delay. `complete_until` retires finished uploads in
    deterministic (finish, begin-seq) order.
    """

    def __init__(self, tm: TimingModel, concurrency: Optional[int] = None):
        self.tm = tm
        n = concurrency or getattr(tm.hw, "load_concurrency", 1)
        self._lane_free_ms = [0.0] * max(1, n)
        self._seq = 0
        self.inflight: List[LoadEvent] = []

    def begin(self, uid: str, slot: int, nbytes: int, now_ms: float,
              demand: bool = True) -> LoadEvent:
        lane = min(range(len(self._lane_free_ms)),
                   key=lambda i: self._lane_free_ms[i])
        start = max(now_ms, self._lane_free_ms[lane])
        finish = start + self.tm.load_ms(nbytes)
        self._lane_free_ms[lane] = finish
        ev = LoadEvent(uid, slot, nbytes, now_ms, start, finish, self._seq,
                       demand=demand)
        self._seq += 1
        self.inflight.append(ev)
        return ev

    def complete_until(self, now_ms: float) -> List[LoadEvent]:
        if not self.inflight:
            return []
        done = sorted((e for e in self.inflight if e.finish_ms <= now_ms),
                      key=lambda e: (e.finish_ms, e.seq))
        for e in done:
            self.inflight.remove(e)
        return done

    def pending_for(self, uid: str) -> Optional[LoadEvent]:
        for e in self.inflight:
            if e.uid == uid:
                return e
        return None

    def next_finish_ms(self) -> Optional[float]:
        return min((e.finish_ms for e in self.inflight), default=None)

    def link_busy_until_ms(self) -> float:
        """When every link lane drains (0 when idle)."""
        return max(self._lane_free_ms) if self.inflight else 0.0


class ColdStartManager:
    def __init__(self, tm: TimingModel, store: HostLoRAStore,
                 pool: DevicePool, mode: str = "caraserve",
                 tracker: Optional[LoadTracker] = None):
        assert mode in MODES, mode
        self.tm = tm
        self.store = store
        self.pool = pool
        self.mode = mode
        self.tracker = tracker if tracker is not None else LoadTracker(tm)
        self._completed: List[LoadEvent] = []

    # ------------------------------------------------------ async plane ----
    def poll(self, now_ms: float) -> List[LoadEvent]:
        """Retire uploads finished by `now_ms`; their slots become ready
        (eviction-eligible, prefetch-visible). Returns the events; they are
        also queued for `drain_completions` so the engine can flip in-flight
        requests to the device LoRA path even when a retire happened inside
        `admit`."""
        done = self.tracker.complete_until(now_ms)
        if done:
            for ev in done:
                self.pool.commit(ev.slot)
            self._completed.extend(done)
        return done

    def drain_completions(self) -> List[LoadEvent]:
        done, self._completed = self._completed, []
        return done

    def pending_completions(self) -> int:
        """Completions retired by a poll but not yet drained by the engine
        (cluster telemetry: a wake with these pending is a load_done)."""
        return len(self._completed)

    def load_async(self, uid: str, now_ms: float, pinned=(),
                   demand: bool = True) -> Optional[LoadEvent]:
        """Reserve a slot and start an asynchronous upload (cold starts:
        demand=True; speculative prefetch: demand=False). Returns None when
        every evictable slot is taken."""
        spec = self.store.specs[uid]
        w = self.store.weights(uid) if self.pool.materialize else None
        slot = self.pool.reserve(uid, w, spec.rank, pinned=pinned)
        if slot is None:
            return None
        return self.tracker.begin(uid, slot, spec.nbytes(self.tm.cfg),
                                  now_ms, demand=demand)

    def _insert(self, uid: str, pinned=()) -> Optional[int]:
        """Synchronous insert (CACHED oracle: no upload modeled)."""
        spec = self.store.specs[uid]
        w = self.store.weights(uid) if self.pool.materialize else None
        return self.pool.insert(uid, w, spec.rank, pinned=pinned)

    # ------------------------------------------------------- admission ----
    def admit(self, uid: str, now_ms: float, prompt_tokens: int,
              pinned=()) -> Optional[AdmitPlan]:
        self.poll(now_ms)        # uploads finished by now have landed
        spec = self.store.specs[uid]
        tm = self.tm
        base = tm.base_prefill_ms(prompt_tokens)
        gpu_lora = tm.lora_prefill_gpu_ms(prompt_tokens, spec.rank)
        slot = self.pool.lookup(uid)
        if slot is not None or self.mode == "cached":
            cold = slot is None
            if slot is None:
                slot = self._insert(uid, pinned)
                if slot is None:
                    return None          # no evictable slot: defer admission
            pre = base + gpu_lora
            if self.pool.is_ready(slot):
                return AdmitPlan(pre, now_ms + pre, 0.0, cold, False, slot)
            # resident but still uploading (admitted moments ago by another
            # request, or prefetched): no new transfer, but decode must wait
            # for the in-flight upload to land
            ev = self.tracker.pending_for(uid)
            finish = ev.finish_ms if ev else now_ms
            rem = max(0.0, finish - now_ms)
            if self.mode in ("ondemand", "slora"):
                pre = rem + base + gpu_lora
                return AdmitPlan(pre, now_ms + pre, rem, False, False, slot,
                                 load_finish_ms=finish)
            cpu_lora = tm.cpu_lora_prefill_ms(prompt_tokens, spec.rank)
            pre = max(base, min(cpu_lora, rem + gpu_lora))
            ready = max(now_ms + pre, finish)
            return AdmitPlan(pre, ready, 0.0, False, rem > 0.0, slot,
                             load_finish_ms=finish)

        # true cold start: the upload queues on the shared host link — its
        # effective duration includes waiting behind concurrent uploads
        ev = self.load_async(uid, now_ms, pinned)
        if ev is None:
            return None                   # no evictable slot: defer admission
        slot = ev.slot
        t_load = ev.finish_ms - now_ms
        if self.mode in ("ondemand", "slora"):
            pre = t_load + base + gpu_lora
            return AdmitPlan(pre, now_ms + pre, t_load, True, False, slot,
                             load_finish_ms=ev.finish_ms)

        # caraserve: overlap upload with prefill; switch to device LoRA when
        # the upload finishes mid-prefill if that is faster than pure host.
        cpu_lora = tm.cpu_lora_prefill_ms(prompt_tokens, spec.rank)
        lora_path = min(cpu_lora, t_load + gpu_lora)
        pre = max(base, lora_path)
        ready = max(now_ms + pre, ev.finish_ms)
        return AdmitPlan(pre, ready, 0.0, True, True, slot,
                         load_finish_ms=ev.finish_ms)
