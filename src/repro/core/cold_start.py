"""Cold-start handling: adapter loading + CPU-assisted prefill (paper sec 4).

`ColdStartManager.admit` returns the timeline for a newly admitted request
under the engine's operating mode:

  CACHED     — oracle: adapter already on device, no load (paper sec 7.1).
  ONDMD      — on-demand blocking load: decode of in-flight requests stalls
               behind Load+Prefill (paper Fig 2).
  SLORA      — same loading behaviour as ONDMD (S-LoRA loads on demand); the
               kernel differs (MBGMV).
  CARASERVE  — CPU-assisted: host CPUs early-start the prefill's LoRA
               computation while the adapter uploads; the GPU/TPU runs the
               adapter-agnostic base prefill concurrently, switching the LoRA
               path to the device once the upload completes (paper Fig 1/7).

The numerics of the host-assist path are identical to the device path by
construction (same x·A·B, computed from the host copy of the weights); the
timeline model quantifies the overlap. Layer-wise coordination costs use the
sync-free-invocation and shared-memory constants (paper Figs 8, 16-18).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.lora import AdapterSpec, DevicePool, HostLoRAStore
from repro.core.timing import TimingModel

MODES = ("cached", "ondemand", "slora", "caraserve")


@dataclasses.dataclass
class AdmitPlan:
    prefill_ms: float          # time to produce the first token (post queue)
    ready_decode_ms: float     # absolute clock when decode iterations may include this request
    blocking_ms: float         # serial stall imposed on the whole iteration (Fig 2 "Load")
    cold: bool
    assist: bool               # CPU-assist engaged
    slot: int                  # device pool slot assigned


class ColdStartManager:
    def __init__(self, tm: TimingModel, store: HostLoRAStore,
                 pool: DevicePool, mode: str = "caraserve"):
        assert mode in MODES, mode
        self.tm = tm
        self.store = store
        self.pool = pool
        self.mode = mode

    def _insert(self, uid: str, pinned=()) -> Optional[int]:
        spec = self.store.specs[uid]
        w = self.store.weights(uid) if self.pool.materialize else None
        return self.pool.insert(uid, w, spec.rank, pinned=pinned)

    def admit(self, uid: str, now_ms: float, prompt_tokens: int,
              pinned=()) -> AdmitPlan:
        spec = self.store.specs[uid]
        tm = self.tm
        base = tm.base_prefill_ms(prompt_tokens)
        gpu_lora = tm.lora_prefill_gpu_ms(prompt_tokens, spec.rank)
        slot = self.pool.lookup(uid)
        if slot is not None or self.mode == "cached":
            cold = slot is None
            if slot is None:
                slot = self._insert(uid, pinned)
                if slot is None:
                    return None          # no evictable slot: defer admission
            pre = base + gpu_lora
            return AdmitPlan(pre, now_ms + pre, 0.0, cold, False, slot)

        t_load = tm.load_ms(spec.nbytes(tm.cfg))
        slot = self._insert(uid, pinned)  # device copy valid at load-done
        if slot is None:
            return None                   # no evictable slot: defer admission
        if self.mode in ("ondemand", "slora"):
            pre = t_load + base + gpu_lora
            return AdmitPlan(pre, now_ms + pre, t_load, True, False, slot)

        # caraserve: overlap upload with prefill; switch to device LoRA when
        # the upload finishes mid-prefill if that is faster than pure host.
        cpu_lora = tm.cpu_lora_prefill_ms(prompt_tokens, spec.rank)
        lora_path = min(cpu_lora, t_load + gpu_lora)
        pre = max(base, lora_path)
        ready = max(now_ms + pre, now_ms + t_load)
        return AdmitPlan(pre, ready, 0.0, True, True, slot)
