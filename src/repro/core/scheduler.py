"""Rank-aware request scheduling (paper sec 5, Algorithm 1) + baselines.

Upon each arrival the scheduler gathers (running_batch, queue) from every
candidate server (base model + adapter + memory match), computes a cost score
from the performance models — the *additional* prefill time amortized over the
average response length plus the additional per-token decode time — adds a
large penalty if admitting would break the decode-latency SLO, weights by the
server's request count, and routes to the arg-min server.

Baselines (sec 7.5): MOSTIDLE (least workload), FIRSTFIT (first-fit bin
packing, Punica's policy), RANDOM.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.perf_model import ServerPerfModel

PENALTY = 1e6


@dataclasses.dataclass
class ServerStats:
    """Scheduler's view of one inference server."""
    running_ranks: List[int]
    queued_ranks: List[int]
    hosts_adapter: bool
    free_rows: int
    n_requests: int
    # async-load observability (LoadTracker): adapters mid-upload on the
    # host link, the link's remaining occupancy, and whether this request's
    # adapter is resident-and-ready on the device pool. link_busy_ms is the
    # *steering* term — the queueing delay a fresh demand upload would face,
    # i.e. the earliest-free-lane time after every upload the link policy
    # schedules ahead of it (fifo: all inflight uploads; priority/preempt:
    # demand class only, queued prefetch is jumped/canceled)
    loading_ranks: List[int] = dataclasses.field(default_factory=list)
    link_busy_ms: float = 0.0
    adapter_ready: bool = True    # resident AND upload landed
    adapter_loading: bool = False  # resident, upload still on the link
    # per-class link occupancy (link scheduler telemetry): remaining
    # transfer-ms owned by demand-class (demand + promoted-prefetch) vs
    # speculative prefetch uploads
    demand_link_ms: float = 0.0
    prefetch_link_ms: float = 0.0
    # the server's host-link scheduling policy (fifo | priority | preempt):
    # under `preempt` a demand upload reclaims speculative link occupancy,
    # so calc_cost discounts prefetch_link_ms from the queueing term
    link_policy: str = "fifo"
    # placement plane: routing here requires installing the adapter into the
    # server's host store first (register-on-miss); the one-time install cost
    # is charged like the prefill terms
    miss_install_ms: float = 0.0
    # paged memory plane: free pages in the server's unified KV/LoRA pool
    # (None = dense layout, not page-gated) and the pages this request
    # would claim there at admission (prompt KV, plus the adapter's pages
    # if it is not yet resident) — admission defers when demand exceeds
    # supply, so routing treats it like an SLO break
    free_pages: Optional[int] = None
    req_pages: int = 0
    # KV over-subscription telemetry: cumulative preemption counters plus
    # the *pressure* term routing steers by — recent preemptions per
    # second of simulated time (windowed rate, not the lifetime counter,
    # so a server that thrashed an hour ago is not penalized forever)
    preemptions: int = 0
    swapped_kv_pages: int = 0
    recompute_tokens: int = 0
    # admitted lifetime KV demand / pool capacity; > 1.0 means the server
    # is running over-subscribed and mid-decode exhaustion is possible
    oversub_ratio: float = 0.0
    preempt_pressure: float = 0.0
    # prefill plane: output tokens the resident batch is still committed
    # to produce (decode commitment depth — how much decode work a routed
    # prefill would stall), the server's chunk budget (0 = monolithic
    # prefill; the spike a long prompt injects is one chunk, not the whole
    # prompt), and observed inter-token-latency percentiles
    decode_commit_tokens: int = 0
    chunk_budget: int = 0
    itl_p50_ms: float = 0.0
    itl_p99_ms: float = 0.0
    # failure plane (core/faults.py): the link's current brownout factor
    # (1.0 = healthy; calc_cost scales the cold-start link terms by it so
    # arrivals steer away from degraded links), plus fault/retry/failover
    # telemetry surfaced into BENCH_*.json via benchmarks/common.py
    link_slowdown: float = 1.0
    crashes: int = 0
    restarts: int = 0
    upload_retries: int = 0
    shed_requests: int = 0
    adopted_requests: int = 0

# ms of routing cost charged per unit of preempt_pressure (preemptions/s):
# a server preempting once per second looks this much slower per token,
# steering arrivals away from thrashing pools before they join the thrash
PREEMPT_PRESSURE_MS = 25.0


def calc_cost(req_rank: int, stats: ServerStats, perf: ServerPerfModel,
              slo_ms: Optional[float], avg_resp_len: float,
              penalty: float = PENALTY, prefill_tokens: int = 0) -> float:
    """CalcCost of Algorithm 1 (lines 13-23), extended with the async-load
    terms: adapters mid-upload will join the decode batch as soon as their
    load lands (count them in DecPerf), and a cold start on a server whose
    host link is already saturated additionally waits out the queue before
    its own upload can start (amortized like the prefill term). The queue
    term is per-class: `link_busy_ms` is what a *demand* upload actually
    waits under the server's link policy, so under priority/preempt a
    server whose link is saturated with cancellable speculative prefetch
    (`prefetch_link_ms` high, `demand_link_ms` low) is correctly not
    penalized for it. On a `preempt`-policy server the routing score goes
    further and discounts `prefetch_link_ms` from the queueing term
    outright: queued speculative occupancy will be canceled by the demand
    upload this routing decision creates. This is deliberately optimistic
    — a speculative upload already *started* on a lane runs to completion
    (preempt never aborts mid-transfer), so the score can understate the
    wait by up to one in-flight prefetch per lane; the bias steers demand
    toward servers whose occupancy is reclaimable, which is the intent of
    the per-class split at cluster scale."""
    exists = stats.running_ranks + stats.queued_ranks + stats.loading_ranks
    d_prefill = perf.pre_perf(stats.queued_ranks + [req_rank]) \
        - perf.pre_perf(stats.queued_ranks)
    if not stats.adapter_ready and not stats.adapter_loading:
        # fresh upload: queues behind the link, then pays its own transfer.
        # A server already uploading this adapter (adapter_loading) gives the
        # request a free ride on the in-flight transfer — no extra charge.
        link_wait = stats.link_busy_ms
        if stats.link_policy == "preempt":
            link_wait = max(0.0, link_wait - stats.prefetch_link_ms)
        # a browned-out link (failure plane) pays the slowdown factor on
        # both the queue drain and this request's own transfer, steering
        # cold starts toward healthy links while the brownout lasts
        d_prefill += (link_wait + perf.load_perf(req_rank)) \
            * stats.link_slowdown
    # register-on-miss: the host-store install precedes the upload
    d_prefill += stats.miss_install_ms
    d_decode = perf.dec_perf(exists + [req_rank]) - perf.dec_perf(exists)
    cost = d_prefill / max(avg_resp_len, 1.0) + d_decode
    if slo_ms is not None and perf.dec_perf(exists + [req_rank]) > slo_ms:
        cost += penalty
    if stats.free_pages is not None and stats.req_pages > stats.free_pages:
        # page-gated server cannot admit this request right now: it would
        # queue behind retirements/reclaim, so penalize like an SLO break
        cost += penalty
    # preemption pressure: an over-subscribed pool that is actively
    # swapping/recomputing will also preempt *this* request's KV — charge
    # the recent preemption rate as extra per-token cost so routing drains
    # thrashing servers instead of piling on
    cost += stats.preempt_pressure * PREEMPT_PRESSURE_MS
    # prefill/decode interference (decode commitment depth): every prefill
    # iteration this prompt needs stalls the whole resident decode batch
    # for one spike — the whole prompt at once on a monolithic server, one
    # chunk per iteration on a chunking one. The stall is felt by at most
    # one committed token per resident row per spike, so long prompts are
    # steered away from servers with deep resident decode batches, and a
    # chunking server's many-small-spikes profile is charged accordingly.
    if prefill_tokens > 0 and stats.running_ranks:
        cb = stats.chunk_budget
        spike = perf.prefill_spike_ms(prefill_tokens, cb)
        n_spikes = -(-prefill_tokens // cb) if 0 < cb < prefill_tokens else 1
        exposed = min(stats.decode_commit_tokens,
                      n_spikes * len(stats.running_ranks))
        cost += spike * exposed / max(avg_resp_len, 1.0)
    return cost


def select_victim(states, exclude=()):
    """Victim policy for mid-decode page exhaustion: among the running
    rows, preempt the least-recently-advanced request (LRU by last token
    time — the row that has waited longest is the one whose batch slot is
    cheapest to take, matching S-LoRA's preemptive scheduling), breaking
    ties SLO-aware: prefer victims without a time-per-token SLO, then the
    loosest SLO (most slack), then the lowest rid for determinism.
    `states` are candidate RequestStates; `exclude` are states that must
    not be chosen (e.g. the row whose growth triggered the hunt). Returns
    None when no candidate remains."""
    skip = set(id(s) for s in exclude)
    cands = [s for s in states if s is not None and id(s) not in skip]
    if not cands:
        return None

    def key(st):
        last = st.token_times_ms[-1] if st.token_times_ms else (
            st.first_token_ms if st.first_token_ms is not None
            else st.req.arrival_ms)
        slack = st.req.slo_tpt_ms if st.req.slo_tpt_ms is not None \
            else float("inf")
        return (last, -slack, st.req.rid)

    return min(cands, key=key)


class RankAwareScheduler:
    """Algorithm 1."""
    name = "rank_aware"

    def __init__(self, perf: ServerPerfModel, slo_ms: Optional[float] = None,
                 avg_resp_len: float = 64.0, penalty: float = PENALTY):
        self.perf = perf
        self.slo_ms = slo_ms
        self.avg_resp_len = avg_resp_len
        self.penalty = penalty

    def route(self, req_rank: int, stats: Sequence[ServerStats],
              prefill_tokens: int = 0) -> int:
        cands = [i for i, s in enumerate(stats) if s.hosts_adapter]
        if not cands:
            raise LookupError("no server hosts the adapter")
        best, best_cost = cands[0], float("inf")
        for i in cands:
            cost = calc_cost(req_rank, stats[i], self.perf, self.slo_ms,
                             self.avg_resp_len, self.penalty,
                             prefill_tokens=prefill_tokens)
            total = cost * stats[i].n_requests   # Algo 1 line 8 (idle -> 0)
            if total < best_cost:
                best, best_cost = i, total
        return best

    def saturated(self, req_rank: int, stats: Sequence[ServerStats],
                  prefill_tokens: int = 0) -> bool:
        """True when *every* given server would break the decode SLO by
        admitting this request — the cluster's trigger for opening the
        candidate set to non-hosting servers (register-on-miss)."""
        if self.slo_ms is None or not stats:
            return False
        return all(calc_cost(req_rank, s, self.perf, self.slo_ms,
                             self.avg_resp_len, self.penalty,
                             prefill_tokens=prefill_tokens) >= self.penalty
                   for s in stats)


class MostIdleScheduler:
    name = "most_idle"

    def route(self, req_rank, stats, prefill_tokens=0):
        cands = [i for i, s in enumerate(stats) if s.hosts_adapter]
        if not cands:
            raise LookupError("no server hosts the adapter")
        return min(cands, key=lambda i: stats[i].n_requests)


class FirstFitScheduler:
    """First-fit bin packing (Punica): first candidate with a free slot,
    else the first candidate."""
    name = "first_fit"

    def route(self, req_rank, stats, prefill_tokens=0):
        cands = [i for i, s in enumerate(stats) if s.hosts_adapter]
        if not cands:
            raise LookupError("no server hosts the adapter")
        for i in cands:
            if stats[i].free_rows > 0:
                return i
        return cands[0]


class RandomScheduler:
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def route(self, req_rank, stats, prefill_tokens=0):
        cands = [i for i, s in enumerate(stats) if s.hosts_adapter]
        if not cands:
            raise LookupError("no server hosts the adapter")
        return int(self.rng.choice(cands))


def make_scheduler(name: str, perf: ServerPerfModel = None, **kw):
    if name == "rank_aware":
        return RankAwareScheduler(perf, **kw)
    if name == "most_idle":
        return MostIdleScheduler()
    if name == "first_fit":
        return FirstFitScheduler()
    if name == "random":
        return RandomScheduler(kw.get("seed", 0))
    raise ValueError(name)
