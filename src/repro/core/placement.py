"""Adapter placement plane: which servers host which adapters.

The paper's rank-aware scheduler (sec 5, Algorithm 1) filters candidate
servers by "hosts the adapter" — a filter that is vacuous when every server
registers every adapter (the seed cluster's setting). This module makes the
fleet actually sharded: a ``PlacementPolicy`` assigns each registered adapter
to a *subset* of servers, and the ``Placement`` runtime map is the routing
source of truth that the ``Cluster`` consults, mutates on register-on-miss,
and rebalances from the admission plane's popularity EWMA over simulated
time (S-LoRA-style multi-replica serving, arXiv 2311.03285; replication of
hot adapters per the heterogeneous-LoRA placement line of work).

Policies:

* ``full``        — every adapter on every server (the seed behaviour; the
                    memory-unconstrained oracle baseline).
* ``hash``        — stable uid hash -> ``replication`` consecutive servers.
                    Popularity-blind: a hot adapter's single replica
                    concentrates its traffic on one server.
* ``rank_balanced`` — greedy bin packing by adapter rank: each replica goes
                    to the server with the least accumulated rank mass, so
                    the per-server device-pool/link burden is even even when
                    ranks are heterogeneous.
* ``popularity``  — popularity-aware k-way replication: every adapter gets a
                    base replica (rank-balanced), and hot adapters get extra
                    replicas proportional to their share of traffic, so the
                    scheduler can spread a hot adapter's load across servers.
"""
from __future__ import annotations

import math
import zlib
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set

from repro.core.lora import AdapterSpec


def _stable_hash(uid: str) -> int:
    """Deterministic across processes (unlike builtin hash of str)."""
    return zlib.crc32(uid.encode("utf-8"))


def replica_target(share: float, n_servers: int, spread: float,
                   cap: Optional[int] = None) -> int:
    """Replica count for an adapter carrying `share` of the traffic:
    ``ceil(share * n_servers * spread)``, at least 1, capped. The single
    replica-target law — PopularityPlacement's initial assignment and the
    cluster's runtime rebalance both use it, so they target the same
    counts."""
    cap = min(cap or n_servers, n_servers)
    return max(1, min(cap, math.ceil(share * n_servers * spread)))


class Placement:
    """Runtime adapter->servers map. Mutable: the cluster adds replicas on
    register-on-miss and the rebalance pass adds/drops replicas over time."""

    def __init__(self, assignment: Mapping[str, Iterable[int]],
                 n_servers: int):
        self.n_servers = n_servers
        self._hosts: Dict[str, Set[int]] = {
            uid: set(srvs) for uid, srvs in assignment.items()}
        for uid, srvs in self._hosts.items():
            if not all(0 <= i < n_servers for i in srvs):
                raise ValueError(
                    f"placement of {uid!r} names out-of-range servers "
                    f"{srvs} (n_servers={n_servers})")

    def hosts(self, uid: str) -> List[int]:
        return sorted(self._hosts.get(uid, ()))

    def n_replicas(self, uid: str) -> int:
        return len(self._hosts.get(uid, ()))

    def add_replica(self, uid: str, server: int) -> bool:
        s = self._hosts.setdefault(uid, set())
        if server in s:
            return False
        s.add(server)
        return True

    def drop_replica(self, uid: str, server: int) -> bool:
        """Remove a replica from the routing map (never below one). The host
        store keeps the weights — dropping only stops new routes."""
        s = self._hosts.get(uid)
        if s is None or server not in s or len(s) <= 1:
            return False
        s.discard(server)
        return True

    def server_adapters(self, server: int) -> List[str]:
        return sorted(u for u, s in self._hosts.items() if server in s)

    def total_replicas(self) -> int:
        return sum(len(s) for s in self._hosts.values())


# ------------------------------------------------------------ policies ----

class PlacementPolicy:
    name = "base"

    def assign(self, specs: Sequence[AdapterSpec], n_servers: int,
               popularity: Optional[Mapping[str, float]] = None,
               ) -> Placement:
        raise NotImplementedError


class FullReplication(PlacementPolicy):
    name = "full"

    def assign(self, specs, n_servers, popularity=None) -> Placement:
        return Placement({sp.uid: range(n_servers) for sp in specs},
                         n_servers)


class HashPlacement(PlacementPolicy):
    name = "hash"

    def __init__(self, replication: int = 1):
        if replication < 1:
            raise ValueError(f"replication must be >= 1, got {replication}")
        self.replication = replication

    def assign(self, specs, n_servers, popularity=None) -> Placement:
        r = min(self.replication, n_servers)
        out = {}
        for sp in specs:
            start = _stable_hash(sp.uid) % n_servers
            out[sp.uid] = {(start + k) % n_servers for k in range(r)}
        return Placement(out, n_servers)


class RankBalancedPlacement(PlacementPolicy):
    """Greedy bin packing: heaviest (highest-rank) adapters first, each
    replica onto the server with the least accumulated rank mass."""
    name = "rank_balanced"

    def __init__(self, replication: int = 1):
        if replication < 1:
            raise ValueError(f"replication must be >= 1, got {replication}")
        self.replication = replication

    def assign(self, specs, n_servers, popularity=None) -> Placement:
        r = min(self.replication, n_servers)
        load = [0.0] * n_servers
        out: Dict[str, Set[int]] = {}
        # sort by rank desc, uid-hash tiebreak for determinism
        for sp in sorted(specs, key=lambda s: (-s.rank, _stable_hash(s.uid))):
            chosen: Set[int] = set()
            for _ in range(r):
                i = min((j for j in range(n_servers) if j not in chosen),
                        key=lambda j: load[j])
                chosen.add(i)
                load[i] += sp.rank
            out[sp.uid] = chosen
        return Placement(out, n_servers)


class PopularityPlacement(PlacementPolicy):
    """Popularity-aware k-way replication. Every adapter gets one replica
    (rank-balanced); an adapter carrying share ``p`` of the traffic gets
    ``ceil(p * n_servers * spread)`` replicas, capped at ``max_replicas``
    (default: the whole fleet) — so the handful of MAF-hot adapters are
    spread while the long tail stays single-replica."""
    name = "popularity"

    def __init__(self, spread: float = 1.0,
                 max_replicas: Optional[int] = None):
        self.spread = spread
        self.max_replicas = max_replicas

    def assign(self, specs, n_servers, popularity=None) -> Placement:
        popularity = popularity or {}
        total = sum(popularity.values()) or 1.0
        cap = min(self.max_replicas or n_servers, n_servers)
        # expected load a replica of this adapter puts on its server:
        # traffic share (split across replicas) weighted by rank, floored
        # by the uniform share so adapters absent from the prior still
        # spread rank-balanced instead of piling onto one server
        floor = 1.0 / max(len(specs), 1)
        load = [0.0] * n_servers
        out: Dict[str, Set[int]] = {}
        order = sorted(specs, key=lambda s: (-popularity.get(s.uid, 0.0),
                                             -s.rank, _stable_hash(s.uid)))
        for sp in order:
            share = popularity.get(sp.uid, 0.0) / total
            k = replica_target(share, n_servers, self.spread, cap)
            chosen: Set[int] = set()
            per_replica = (share / k + floor) * max(sp.rank, 1)
            for _ in range(k):
                i = min((j for j in range(n_servers) if j not in chosen),
                        key=lambda j: load[j])
                chosen.add(i)
                load[i] += per_replica
            out[sp.uid] = chosen
        return Placement(out, n_servers)


def make_placement_policy(name: str, **kw) -> PlacementPolicy:
    if name == "full":
        return FullReplication()
    if name == "hash":
        return HashPlacement(**kw)
    if name == "rank_balanced":
        return RankBalancedPlacement(**kw)
    if name == "popularity":
        return PopularityPlacement(**kw)
    raise ValueError(name)
