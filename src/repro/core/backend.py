"""Numerics plane of the inference server: real JAX computation.

Owns the base-model params, the batched KV-cache pool, the jit caches, and
LoRA argument construction. Two entry points:

  * `prefill_admitted` — **batched multi-request prefill**: every request
    admitted in one iteration is packed into a single padded (N, L) call
    (per-request host-copy LoRA weights stacked along the slot dim), instead
    of one jit call per request. Causal masking makes the packed logits
    bitwise-identical to the per-request calls; shapes are bucketed (batch
    and length both power-of-two) to bound compilation.
  * `decode` — one decode iteration over the ready rows against the device
    slot pool (BGMV padding / MBGMV rank-block semantics via the kernel
    mode).

The timeline plane (InferenceServer) never touches arrays; the admission
plane never touches jit. Timing-only simulations simply do not construct a
backend.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import DevicePool, HostLoRAStore
from repro.models import model as model_lib
from repro.models.param import split
from repro.serving import cache as cache_lib
from repro.serving.request import RequestState
from repro.serving.sampling import sample


def bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class NumericsBackend:
    def __init__(self, cfg: ModelConfig, *, kernel: str, max_batch: int,
                 cache_slots: int, store: HostLoRAStore, pool: DevicePool,
                 params=None, seed: int = 0):
        self.cfg = cfg
        self.kernel = kernel
        self.max_batch = max_batch
        self.cache_slots = cache_slots
        self.store = store
        self.pool = pool
        if params is None:
            params, _ = split(model_lib.init_params(
                cfg, jax.random.PRNGKey(seed)))
        self.params = params
        row_cache = model_lib.cache_abstract(cfg, 1, cache_slots)
        self.cache = cache_lib.zeros_like_batched(row_cache, max_batch)
        self._decode_jit = jax.jit(functools.partial(
            self._decode_fn, cfg, self._mode_str()), donate_argnums=(1,))
        self._prefill_jit = {}

    def _mode_str(self):
        return "bgmv" if self.kernel == "bgmv" else "mbgmv"

    # ---------------------------------------------------------- prefill ----
    def _lora_arg_stacked(self, uids: List[str]):
        """Batch-N lora arg from host weights (CPU-assist path numerics):
        request i reads pseudo-slot i of a pool stacked from the host copies."""
        ws = [self.store.weights(u) for u in uids]
        targets = ws[0].keys()
        pool = {t: {"a": jnp.stack([jnp.asarray(w[t]["a"]) for w in ws], 1),
                    "b": jnp.stack([jnp.asarray(w[t]["b"]) for w in ws], 1)}
                for t in targets}
        ranks = [min(self.store.specs[u].rank, self.cfg.lora.max_rank)
                 for u in uids]
        pool["ranks"] = jnp.asarray(ranks, jnp.int32)
        return {"pool": pool, "idx": jnp.arange(len(uids), dtype=jnp.int32)}

    def prefill_admitted(self, states: List[RequestState]):
        """One padded prefill call for all requests admitted this iteration;
        scatters each row cache into the pool and records the first token."""
        if not states:
            return
        lens = np.array([st.req.prompt_len for st in states])
        if int(lens.max()) > self.cache_slots:
            bad = [st.req.rid for st in states
                   if st.req.prompt_len > self.cache_slots]
            raise ValueError(
                f"requests {bad}: prompt exceeds the {self.cache_slots} "
                "KV-cache slots per row — the engine must reject these at "
                "submit time (raise cache_slots or truncate the prompt)")
        Lp = min(bucket(int(lens.max())), self.cache_slots)
        Nb = bucket(len(states), lo=1)
        toks = np.zeros((Nb, Lp), np.int32)
        for i, st in enumerate(states):
            toks[i, :lens[i]] = st.req.prompt
        uids = [st.req.adapter_uid for st in states]
        # pad the lora arg to Nb rows (repeat row 0; idx -1 would also work
        # but a valid slot keeps the gather in-bounds without a select)
        uids_p = uids + [uids[0]] * (Nb - len(uids))
        lora = self._lora_arg_stacked(uids_p)
        key = (Nb, Lp)
        if key not in self._prefill_jit:
            self._prefill_jit[key] = jax.jit(functools.partial(
                self._prefill_fn, self.cfg, self._mode_str(),
                self.cache_slots))
        logits, row_caches = self._prefill_jit[key](
            self.params, jnp.asarray(toks), lora)
        row_caches = self._mask_pad_slots(row_caches, lens, Nb)
        last = np.asarray(logits)[np.arange(len(states)), lens - 1]
        toks_out = np.asarray(sample(jnp.asarray(last)))
        for i, st in enumerate(states):
            self.cache = cache_lib.scatter_row(
                self.cache, cache_lib.gather_row(row_caches, i), st.row)
            tok = int(toks_out[i])
            st.generated.append(tok)
            st.token_times_ms.append(st.first_token_ms)
            st._last_token = tok

    @staticmethod
    def _prefill_fn(cfg, mode, cache_slots, params, toks, lora):
        lora = dict(lora, mode=mode)
        return model_lib.prefill(cfg, params, {"tokens": toks}, lora=lora,
                                 cache_slots=cache_slots)

    def _mask_pad_slots(self, row_caches, lens, Nb):
        """Invalidate cache slots beyond each request's true prompt length
        (padding rows of the packed call never become attendable)."""
        lens_b = np.zeros(Nb, np.int64)
        lens_b[: len(lens)] = lens
        lens_j = jnp.asarray(lens_b)

        def fix(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "pos":
                slots = x.shape[-1]
                live = jnp.arange(slots)[None] < lens_j[:, None]
                while live.ndim < x.ndim:      # stacked: (L, B, slots)
                    live = live[None]
                return jnp.where(live, x, -1)
            return x
        return jax.tree_util.tree_map_with_path(fix, row_caches)

    # ----------------------------------------------------------- decode ----
    def decode(self, ready: List[RequestState], row_slot, row_pos):
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        live = np.zeros((self.max_batch,), bool)
        idx = np.asarray(row_slot).copy()
        for st in ready:
            toks[st.row, 0] = getattr(st, "_last_token", 0)
            pos[st.row] = row_pos[st.row]
            live[st.row] = True
        idx[~live] = -1
        lora = {"pool": self.pool.pool, "idx": jnp.asarray(idx, jnp.int32)}
        logits, self.cache = self._decode_jit(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            lora)
        new = np.asarray(sample(logits[:, -1]))
        for st in ready:
            tok = int(new[st.row])
            st.generated.append(tok)
            st._last_token = tok

    @staticmethod
    def _decode_fn(cfg, mode, params, cache, toks, pos, lora):
        lora = dict(lora, mode=mode)
        return model_lib.decode(cfg, params, cache, toks, pos, lora=lora)
