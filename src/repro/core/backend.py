"""Numerics plane of the inference server: real JAX computation.

Owns the base-model params, the batched KV-cache pool, the jit caches, and
LoRA argument construction, organized as a **device-resident decode
pipeline** (`DecodePipeline`): sampling is fused into the jitted step
functions, per-row last-token / position / stop-target state lives in
device buffers donated across steps, and the host reads tokens back
asynchronously (the previous step's tokens are fetched while the current
step executes). Three entry points:

  * `prefill_admitted` — **batched multi-request prefill**: every request
    admitted in one iteration is packed into a single padded (N, L) call
    (per-request LoRA weights come from a small device `StagingCache`,
    stacked along the slot dim), instead of one jit call per request. The
    jit gathers each row's last-position hidden state *before* the
    unembed, samples on device, scatters every row cache into the pool
    with ONE vectorized scatter, and seeds the pipeline buffers — the
    (N, L, vocab) logits tensor never exists, on device or host. Causal
    masking makes the packed result bitwise-identical to per-request
    calls; shapes are bucketed (batch and length both power-of-two) to
    bound compilation.
  * `decode` — one decode iteration over the ready rows against the device
    slot pool (BGMV padding / MBGMV rank-block semantics via the kernel
    mode). In the default `fused` pipeline the jit consumes and returns
    the device buffers: **zero host→device transfers in steady state**
    (the active-row mask and LoRA slot map are re-uploaded only when the
    batch composition changes — an admission, flip, or retirement).
  * `megastep` — K decode iterations in one `lax.scan`-based jit call
    (the engine chooses K from its event horizon). Per-row stop targets
    freeze finished rows: their KV writes are dropped via the cache
    scatter's out-of-bounds mode, so the result — tokens and KV cache —
    is bitwise-identical to K single steps under greedy sampling.

`pipeline="perstep"` keeps the pre-pipeline behaviour (host sampling off
full logits, per-step host→device token/position uploads, synchronous
readback) as the benchmark baseline; `transfer_stats` counts host-link
crossings on both paths so `benchmarks/bench_pipeline.py` can assert the
reduction.

The timeline plane (InferenceServer) never touches arrays; the admission
plane never touches jit. Timing-only simulations simply do not construct a
backend.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import retrace, sanitizers
from repro.configs.base import ModelConfig
from repro.core.lora import DevicePool, HostLoRAStore, StagingCache
from repro.models import model as model_lib
from repro.models.param import split
from repro.serving import cache as cache_lib
from repro.serving.request import RequestState
from repro.serving.sampling import sample, split_key

PIPELINES = ("fused", "perstep")
MEGASTEP_MAX = 8          # default cap on iterations fused into one scan


def bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _select_rows(new_tree, old_tree, active):
    """Per-row select between two cache trees (batch axis from the tree
    layout) — the write-mask fallback for families whose state update
    cannot drop a row's write (see model.supports_write_mask)."""
    ax = cache_lib._batch_axis(new_tree)

    def sel(n, o):
        shape = [1] * n.ndim
        shape[ax] = -1
        return jnp.where(active.reshape(shape), n, o)

    return jax.tree.map(sel, new_tree, old_tree)


class DecodePipeline:
    """Device-resident per-row decode state + the async readback queue.

    Buffers (all (max_batch,), device-resident, donated through the jitted
    step functions):

      last_tok — last sampled token per row (next step's input)
      pos      — next decode position per row
      target   — stop position: the row freezes once pos reaches it
                 (seeded at prefill from prompt_len + max_new_tokens - 1)
      active   — host-owned mask of rows in the current decode batch
      idx      — host-owned LoRA pool slot per row (-1: none)
      rng      — threaded sampling key (unused under greedy, advanced
                 identically either way so megastep stays reproducible)

    `active`/`idx` change only on events (admission / retirement / batch
    recomposition); `refresh` re-uploads them only when their host
    signature changes, so a steady-state decode iteration performs zero
    host→device transfers.

    Readback: `stash` queues the step's token array (a device future) with
    its (state, column, n_tokens) entries; the queue is drained one step
    behind — `jax.device_get` on step k-1's tokens runs while step k
    executes. `flush` drains everything (end of run / perstep mode)."""

    def __init__(self, max_batch: int, seed: int, stats: Dict[str, int],
                 bt_width: int = 0):
        self.max_batch = max_batch
        self.stats = stats
        i32 = jnp.int32
        self.last_tok = jnp.zeros((max_batch,), i32)
        self.pos = jnp.zeros((max_batch,), i32)
        self.target = jnp.zeros((max_batch,), i32)
        self.active = jnp.zeros((max_batch,), bool)
        self.idx = jnp.full((max_batch,), -1, i32)
        # paged memory plane: per-row block table (logical page -> physical
        # page, -1 unclaimed). Device-resident like active/idx: re-uploaded
        # only on events — an admission, retirement, or a lazy growth claim
        # appending a page to a row's table (the signature covers the table
        # bytes, so a boundary-claim re-uploads exactly once).
        self.bt_width = bt_width
        self.block_table = jnp.full((max_batch, bt_width), -1, i32) \
            if bt_width else None
        self.rng = jax.random.PRNGKey(seed)
        self._sig: Optional[bytes] = None
        self._pending: List[Tuple[jax.Array,
                                  List[Tuple[RequestState, int, int]]]] = []
        self.readback_depth = 1

    # ------------------------------------------------------- row state ----
    def refresh(self, ready: List[RequestState], row_slot, row_pages=None):
        """Sync the active mask, LoRA slot map, and (paged) block table
        with the engine's ready set; uploads only when the composition
        changed (an event)."""
        active = np.zeros((self.max_batch,), bool)
        for st in ready:
            active[st.row] = True
        # lint: allow-host-sync — row_slot is host-resident batch metadata,
        # not a device array; no transfer happens here
        idx = np.asarray(row_slot, np.int64).copy()
        idx[~active] = -1
        sig = active.tobytes() + idx.tobytes()
        bt = None
        if self.bt_width:
            bt = np.full((self.max_batch, self.bt_width), -1, np.int32)
            for st in ready:
                pg = row_pages[st.row]
                bt[st.row, :len(pg)] = pg
            sig += bt.tobytes()
        if sig != self._sig:
            self.active = jnp.asarray(active)
            self.idx = jnp.asarray(idx, jnp.int32)
            self._sig = sig
            self.stats["h2d"] += 2
            self.stats["h2d_bytes"] += active.nbytes + 4 * self.max_batch
            if bt is not None:
                self.block_table = jnp.asarray(bt)
                self.stats["h2d"] += 1
                self.stats["h2d_bytes"] += bt.nbytes
        return self.active, self.idx

    # -------------------------------------------------------- readback ----
    def stash(self, toks, entries: List[Tuple[RequestState, int, int]]):
        """Queue a step's device token array; each entry (st, col, n)
        drains n tokens for `st` from column `col` (prefill: batch index,
        decode/megastep: engine row)."""
        for st, _, n in entries:
            st.pending_tokens += n
        self._pending.append((toks, entries))
        while len(self._pending) > self.readback_depth:
            self._drain_one()

    def _drain_one(self):
        toks, entries = self._pending.pop(0)
        # lint: allow-host-sync — the drain IS the designed d2h point: it
        # lands `readback_depth` megasteps behind dispatch, off the hot path
        arr = np.asarray(jax.device_get(toks))
        self.stats["d2h"] += 1
        self.stats["d2h_bytes"] += arr.nbytes
        for st, col, n in entries:
            vals = [int(arr[col])] if arr.ndim == 1 \
                else [int(v) for v in arr[:n, col]]
            st.generated.extend(vals)
            st.pending_tokens -= n

    def flush(self):
        while self._pending:
            self._drain_one()


class NumericsBackend:
    def __init__(self, cfg: ModelConfig, *, kernel: str, max_batch: int,
                 cache_slots: int, store: HostLoRAStore, pool: DevicePool,
                 params=None, seed: int = 0, pipeline: str = "fused",
                 megastep: int = MEGASTEP_MAX, temperature: float = 0.0,
                 staging_slots: int = 16, memory: str = "dense",
                 page_size: int = 32, allocator=None):
        if pipeline not in PIPELINES:
            raise ValueError(f"unknown pipeline {pipeline!r}")
        if memory not in ("dense", "paged"):
            raise ValueError(f"unknown memory plane {memory!r}")
        if pipeline == "perstep" and temperature > 0.0:
            raise ValueError(
                "pipeline='perstep' is the greedy-only legacy baseline; "
                "temperature sampling needs the fused pipeline (its rng "
                "is threaded through the device-resident step state)")
        self.cfg = cfg
        self.kernel = kernel
        self.max_batch = max_batch
        self.cache_slots = cache_slots
        self.store = store
        self.pool = pool
        self.pipeline = pipeline
        self.megastep_max = megastep if pipeline == "fused" else 0
        self.temperature = temperature
        self.paged = memory == "paged"
        self.page_size = page_size
        if self.paged:
            if pipeline != "fused":
                raise ValueError(
                    "the paged memory plane rides the fused pipeline")
            if not model_lib.supports_paged(cfg):
                raise ValueError(
                    f"{cfg.name}: family does not support the paged cache")
            if not model_lib.supports_write_mask(cfg):
                raise ValueError(
                    f"{cfg.name}: family does not support write masks")
            if cache_slots % page_size:
                raise ValueError(
                    f"cache_slots ({cache_slots}) must be a multiple of "
                    f"page_size ({page_size}) so a row's block table tiles "
                    "its ring exactly (paged decode stays bitwise-equal to "
                    "the dense row layout)")
            if allocator is None:
                raise ValueError("memory='paged' requires a PageAllocator")
        self.allocator = allocator
        self.bt_width = cache_slots // page_size if self.paged else 0
        if params is None:
            params, _ = split(model_lib.init_params(
                cfg, jax.random.PRNGKey(seed)))
        self.params = params
        row_cache = model_lib.cache_abstract(cfg, 1, cache_slots)
        self.cache = cache_lib.zeros_paged(
            row_cache, allocator.n_pages, page_size) if self.paged \
            else cache_lib.zeros_like_batched(row_cache, max_batch)
        self.transfer_stats: Dict[str, int] = {
            "h2d": 0, "h2d_bytes": 0, "d2h": 0, "d2h_bytes": 0,
            "decode_steps": 0, "megasteps": 0, "megastep_iters": 0,
            "prefills": 0, "prefill_chunks": 0}
        self.pipe = DecodePipeline(max_batch, seed + 1, self.transfer_stats,
                                   bt_width=self.bt_width)
        self.staging = StagingCache(staging_slots,
                                    on_upload=self._count_upload)
        # donation: real on accelerators; skipped on CPU (unsupported there)
        self._donate = jax.default_backend() != "cpu"
        mask_ok = model_lib.supports_write_mask(cfg)
        self._decode_legacy_jit = jax.jit(
            functools.partial(self._decode_legacy_fn, cfg, self._mode_str()),
            donate_argnums=(1,) if self._donate else ())
        self._decode_jit = jax.jit(
            functools.partial(self._decode_fused_fn, cfg, self._mode_str(),
                              temperature, mask_ok),
            donate_argnums=(1, 2, 3, 7) if self._donate else ())
        self._megastep_jits = {}
        self._prefill_jit = {}
        self._chunk_jit = {}
        # RetraceSan (REPRO_SANITIZE=1): per-dispatch trace-cache watch on
        # every hot jit. Tests call mark_steady()/assert_clean(); a retrace
        # after steady state means a shape-unstable decode step.
        self.retrace_san = (retrace.RetraceSan()
                            if sanitizers.enabled() else None)

    def _observe_trace(self, name: str, fn) -> None:
        if self.retrace_san is not None:
            self.retrace_san.observe(name, fn)

    def _san_check(self, ids, prefix: str, op: str) -> None:
        """PageSan access check for host-known page id lists (no device
        sync: every id list here is host-built)."""
        san = getattr(self.allocator, "san", None) \
            if self.allocator is not None else None
        if san is not None:
            san.check_access(ids, prefix, op)

    def _mode_str(self):
        return "bgmv" if self.kernel == "bgmv" else "mbgmv"

    def _count_upload(self, nbytes: int):
        self.transfer_stats["h2d"] += 1
        self.transfer_stats["h2d_bytes"] += nbytes

    def flush_readback(self):
        """Drain every queued async token readback (end of run, or before
        host code that needs `st.generated` current)."""
        self.pipe.flush()

    # ------------------------------------------- preemption (paged plane) ----
    def swap_out(self, pages: List[int]):
        """Copy a preemption victim's KV pages to host memory. Returns the
        host-side payload `swap_in` restores from; the timeline plane
        charges the re-upload through the link scheduler, the d2h copy is
        counted here."""
        self._san_check(pages, "kv:", "swap-out extract")
        payload = cache_lib.extract_pages(self.cache, pages)
        self.transfer_stats["d2h"] += 1
        self.transfer_stats["d2h_bytes"] += cache_lib.tree_nbytes(payload)
        return payload

    def swap_in(self, states: List[RequestState], row_pages):
        """Restore swap-preempted rows: insert each saved payload into the
        freshly claimed pages and re-seed the pipeline's per-row buffers.
        The page contents (including the pos leaves the attention mask
        trusts) come back exactly as extracted, so the row continues
        decoding bitwise-identically — no prefill, no re-sampling."""
        pipe = self.pipe
        for st in states:
            payload, st.swap_payload = st.swap_payload, None
            self._san_check(st.kv_pages, "kv:", "swap-in insert")
            self.cache = cache_lib.insert_pages(self.cache, payload,
                                                st.kv_pages)
            self.transfer_stats["h2d"] += 1
            self.transfer_stats["h2d_bytes"] += \
                cache_lib.tree_nbytes(payload)
            r = st.row
            pipe.last_tok = pipe.last_tok.at[r].set(int(st.generated[-1]))
            pipe.pos = pipe.pos.at[r].set(int(st.resume_pos))
            pipe.target = pipe.target.at[r].set(
                st.req.prompt_len + st.req.max_new_tokens - 1)

    def clear_pages(self, ids: List[int]):
        """Scrub freshly grown pages (pos = -1): a page claimed mid-decode
        may carry a previous tenant's positions, which would become
        attendable the moment the growing row's clock passes them."""
        self._san_check(ids, "kv:", "page scrub")
        self.cache = cache_lib.clear_pages(self.cache, ids)

    def restore_pages(self, st: RequestState):
        """Swap-in for a half-prefilled (chunk-phase) row: reinsert the
        saved page payload only. Unlike `swap_in` there is no pipeline
        re-seed — the row has no sampled token yet; its next chunk simply
        continues from st.prefill_pos against the restored pages."""
        payload, st.swap_payload = st.swap_payload, None
        self._san_check(st.kv_pages, "kv:", "chunk swap-in insert")
        self.cache = cache_lib.insert_pages(self.cache, payload,
                                            st.kv_pages)
        self.transfer_stats["h2d"] += 1
        self.transfer_stats["h2d_bytes"] += cache_lib.tree_nbytes(payload)

    # ---------------------------------------------------------- prefill ----
    def _lora_arg_stacked(self, uids: List[str]):
        """Batch-N lora arg (CPU-assist path numerics): request i reads
        pseudo-slot i of a pool stacked from the staged device copies —
        repeated prefills of a hot adapter hit the `StagingCache` instead
        of re-crossing the host link."""
        ws = [self.staging.get(u, self.store) for u in uids]
        targets = ws[0].keys()
        pool = {t: {"a": jnp.stack([w[t]["a"] for w in ws], 1),
                    "b": jnp.stack([w[t]["b"] for w in ws], 1)}
                for t in targets}
        ranks = [min(self.store.specs[u].rank, self.cfg.lora.max_rank)
                 for u in uids]
        pool["ranks"] = jnp.asarray(ranks, jnp.int32)
        return {"pool": pool, "idx": jnp.arange(len(uids), dtype=jnp.int32)}

    def prefill_admitted(self, states: List[RequestState]):
        """One padded prefill call for all requests admitted this
        iteration. The jit samples each row's first token on device,
        scatters every row cache into the pool in one vectorized write,
        and seeds the decode pipeline's last-token/position/stop-target
        buffers; tokens reach `st.generated` through the async readback
        queue.

        Recompute resumes (`st.preempted`, drop-and-recompute preemption)
        ride the same packed call: the row prefills prompt + generated[:-1]
        — every KV slot it had written — and under greedy the re-sampled
        "first token" is exactly generated[-1] (the prefix replayed
        predicts what it predicted before), which re-seeds last_tok for
        bitwise continuation. No token is emitted and no timestamp is
        appended for resumed rows: their token already reached the client
        before preemption."""
        if not states:
            return
        # lint: allow-host-sync — built from host ints, no device transfer
        lens = np.array([min(st.resume_pos, self.cache_slots)
                         if st.preempted else st.req.prompt_len
                         for st in states])
        if int(lens.max()) > self.cache_slots:
            bad = [st.req.rid for st in states
                   if st.req.prompt_len > self.cache_slots]
            unit = (f"{self.bt_width}-page block table "
                    f"(page_size {self.page_size})" if self.paged
                    else f"{self.cache_slots} KV-cache slots") + " per row"
            raise ValueError(
                f"requests {bad}: prompt exceeds the {unit} — the engine "
                "must reject these at submit time (raise cache_slots or "
                "truncate the prompt)")
        Lp = min(bucket(int(lens.max())), self.cache_slots)
        Nb = bucket(len(states), lo=1)
        N = len(states)
        toks = np.zeros((Nb, Lp), np.int32)
        lens_b = np.ones((Nb,), np.int32)
        rows = np.full((Nb,), self.max_batch, np.int32)   # pad rows: dropped
        tgts = np.zeros((Nb,), np.int32)
        for i, st in enumerate(states):
            if st.preempted:
                # lint: allow-host-sync — prompt/generated are host lists
                seq = np.asarray(
                    list(st.req.prompt) + list(st.generated[:-1]), np.int32)
                if len(seq) != lens[i]:
                    raise RuntimeError(
                        f"resume length mismatch for {st.req.rid}: "
                        f"{len(seq)} != {lens[i]}")
                toks[i, :lens[i]] = seq
            else:
                toks[i, :lens[i]] = st.req.prompt
            lens_b[i] = lens[i]
            rows[i] = st.row
            # the stop target is the request's original one — a resumed
            # row owes the remaining tokens, not max_new more
            tgts[i] = st.req.prompt_len + st.req.max_new_tokens - 1
        uids = [st.req.adapter_uid for st in states]
        # pad the lora arg to Nb rows (repeat row 0; idx -1 would also work
        # but a valid slot keeps the gather in-bounds without a select)
        uids_p = uids + [uids[0]] * (Nb - N)
        lora = self._lora_arg_stacked(uids_p)
        pipe = self.pipe
        self.transfer_stats["h2d"] += 4          # toks, lens, rows, targets
        self.transfer_stats["h2d_bytes"] += (toks.nbytes + lens_b.nbytes
                                             + rows.nbytes + tgts.nbytes)
        self.transfer_stats["prefills"] += 1
        args = (self.params, jnp.asarray(toks), jnp.asarray(lens_b),
                jnp.asarray(rows), jnp.asarray(tgts), self.cache,
                pipe.last_tok, pipe.pos, pipe.target, pipe.rng, lora)
        if self.paged:
            ps = self.page_size
            Sp = -(-Lp // ps) * ps          # prefill cache depth, page-tiled
            npr = Sp // ps
            page_ids = np.full((Nb, npr), -1, np.int32)
            claimed = []
            for i, st in enumerate(states):
                page_ids[i, :min(len(st.kv_pages), npr)] = \
                    st.kv_pages[:npr]
                claimed.extend(st.kv_pages)
            self._san_check(claimed, "kv:", "prefill scatter")
            # every claimed page gets its pos slots invalidated before the
            # prompt scatter lands: pages reclaimed from a retired row
            # carry stale positions the attention mask would trust
            C = bucket(len(claimed), lo=1)
            clear_ids = np.full((C,), -1, np.int32)
            clear_ids[:len(claimed)] = claimed
            key = (Nb, Lp, C)
            if key not in self._prefill_jit:
                donate = (5, 6, 7, 8, 9) if self._donate else ()
                self._prefill_jit[key] = jax.jit(functools.partial(
                    self._prefill_paged_fn, self.cfg, self._mode_str(),
                    Sp, self.temperature), donate_argnums=donate)
            self.transfer_stats["h2d"] += 2      # page ids, clear list
            self.transfer_stats["h2d_bytes"] += (page_ids.nbytes
                                                 + clear_ids.nbytes)
            (toks_out, self.cache, pipe.last_tok, pipe.pos, pipe.target,
             pipe.rng) = self._prefill_jit[key](
                *args, jnp.asarray(page_ids), jnp.asarray(clear_ids))
        else:
            key = (Nb, Lp)
            if key not in self._prefill_jit:
                donate = (5, 6, 7, 8, 9) if self._donate else ()
                self._prefill_jit[key] = jax.jit(functools.partial(
                    self._prefill_fn, self.cfg, self._mode_str(),
                    self.cache_slots, self.temperature,
                    model_lib.supports_last_pos(self.cfg)),
                    donate_argnums=donate)
            (toks_out, self.cache, pipe.last_tok, pipe.pos, pipe.target,
             pipe.rng) = self._prefill_jit[key](*args)
        for st in states:
            if not st.preempted:
                st.token_times_ms.append(st.first_token_ms)
        # resumed rows re-sample a token they already emitted — exclude
        # them from the stash so the readback never appends it again
        pipe.stash(toks_out, [(st, i, 1) for i, st in enumerate(states)
                              if not st.preempted])
        if self.pipeline == "perstep":
            pipe.flush()       # legacy path: synchronous readback

    @staticmethod
    def _prefill_fn(cfg, mode, cache_slots, temperature, use_last_pos,
                    params, toks, lens, rows, tgts, cache, last_tok, pos,
                    target, rng, lora):
        lora = dict(lora, mode=mode)
        gather = lens - 1
        if use_last_pos:
            logits, row_caches = model_lib.prefill(
                cfg, params, {"tokens": toks}, lora=lora,
                cache_slots=cache_slots, last_pos=gather)
            last = logits[:, 0]
        else:   # encdec: full logits stay on device; gather post-unembed
            logits, row_caches = model_lib.prefill(
                cfg, params, {"tokens": toks}, lora=lora,
                cache_slots=cache_slots)
            last = logits[jnp.arange(toks.shape[0]), gather]
        rng, sub = split_key(rng)
        toks_out = sample(last, temperature=temperature, rng=sub)
        row_caches = NumericsBackend._mask_pad_slots(row_caches, lens)
        cache = cache_lib.scatter_rows(cache, row_caches, rows)
        last_tok = last_tok.at[rows].set(toks_out, mode="drop")
        pos = pos.at[rows].set(lens, mode="drop")
        target = target.at[rows].set(tgts, mode="drop")
        return toks_out, cache, last_tok, pos, target, rng

    @staticmethod
    def _prefill_paged_fn(cfg, mode, slots, temperature, params, toks, lens,
                          rows, tgts, cache, last_tok, pos, target, rng,
                          lora, page_ids, clear_ids):
        """Paged prefill: identical compute to `_prefill_fn` (the logits —
        and therefore the first sampled token — never see the cache
        layout), but the row caches land in freshly claimed pages via one
        page scatter instead of one row scatter. `slots` is the padded
        prompt length rounded up to whole pages, so each row cache tiles
        exactly into `slots/page_size` pages."""
        lora = dict(lora, mode=mode)
        gather = lens - 1
        logits, row_caches = model_lib.prefill(
            cfg, params, {"tokens": toks}, lora=lora,
            cache_slots=slots, last_pos=gather)
        last = logits[:, 0]
        rng, sub = split_key(rng)
        toks_out = sample(last, temperature=temperature, rng=sub)
        row_caches = NumericsBackend._mask_pad_slots(row_caches, lens)
        n_pages = cache["pos"].shape[1]
        cids = jnp.where(clear_ids >= 0, clear_ids, n_pages)
        cache = dict(cache)
        cache["pos"] = cache["pos"].at[:, cids].set(-1, mode="drop")
        cache = cache_lib.scatter_pages(cache, row_caches, page_ids)
        last_tok = last_tok.at[rows].set(toks_out, mode="drop")
        pos = pos.at[rows].set(lens, mode="drop")
        target = target.at[rows].set(tgts, mode="drop")
        return toks_out, cache, last_tok, pos, target, rng

    @staticmethod
    def _mask_pad_slots(row_caches, lens_j):
        """Invalidate cache slots beyond each request's true prompt length
        (padding rows of the packed call never become attendable)."""
        def fix(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "pos":
                slots = x.shape[-1]
                live = jnp.arange(slots)[None] < lens_j[:, None]
                while live.ndim < x.ndim:      # stacked: (L, B, slots)
                    live = live[None]
                return jnp.where(live, x, -1)
            return x
        return jax.tree_util.tree_map_with_path(fix, row_caches)

    # --------------------------------------------------- chunked prefill ----
    def prefill_chunk(self, st: RequestState, row_pages: List[int],
                      start: int, n_tokens: int, final: bool):
        """One chunk of an incremental prefill for a single row: consume
        prompt[start : start+n_tokens], gather the row's claimed pages into
        a dense view, run the chunk through the stack (attention masked by
        cached absolute positions), and scatter the updated view back via
        `scatter_pages`. Only the final chunk samples — through the same
        last-position gather / sample / pipeline-seed sequence as
        `prefill_admitted`, so the first token is bitwise identical to a
        monolithic prefill. The chunk width is bucketed so a fixed
        chunk_budget compiles at most two variants (mid + final)."""
        if not self.paged:
            raise RuntimeError("chunked prefill rides the paged memory "
                               "plane (memory='paged')")
        if start + n_tokens > self.cache_slots:
            raise ValueError(
                f"request {st.req.rid}: chunk [{start}, {start + n_tokens})"
                f" exceeds the {self.cache_slots}-slot block table")
        W = self.bt_width
        Cb = min(bucket(n_tokens), self.cache_slots)
        toks = np.zeros((1, Cb), np.int32)
        # lint: allow-host-sync — prompt is a host array, no device sync
        toks[0, :n_tokens] = np.asarray(st.req.prompt[start:start + n_tokens])
        ids = np.full((W,), -1, np.int32)
        ids[:len(row_pages)] = row_pages
        self._san_check(list(row_pages), "kv:", "chunk scatter")
        lora = self._lora_arg_stacked([st.req.adapter_uid])
        self.transfer_stats["h2d"] += 2            # tokens, page ids
        self.transfer_stats["h2d_bytes"] += toks.nbytes + ids.nbytes
        self.transfer_stats["prefill_chunks"] += 1
        pipe = self.pipe
        key = (Cb, bool(final))
        if key not in self._chunk_jit:
            if final:
                donate = (7, 8, 9, 10, 11) if self._donate else ()
                self._chunk_jit[key] = jax.jit(functools.partial(
                    self._prefill_chunk_final_fn, self.cfg,
                    self._mode_str(), self.temperature),
                    donate_argnums=donate)
            else:
                donate = (4,) if self._donate else ()
                self._chunk_jit[key] = jax.jit(functools.partial(
                    self._prefill_chunk_fn, self.cfg, self._mode_str()),
                    donate_argnums=donate)
        start_j = jnp.asarray(start, jnp.int32)
        clen_j = jnp.asarray(n_tokens, jnp.int32)
        if final:
            row = jnp.asarray([st.row], jnp.int32)
            plen = jnp.asarray([st.req.prompt_len], jnp.int32)
            tgt = jnp.asarray(
                [st.req.prompt_len + st.req.max_new_tokens - 1], jnp.int32)
            (toks_out, self.cache, pipe.last_tok, pipe.pos, pipe.target,
             pipe.rng) = self._chunk_jit[key](
                self.params, jnp.asarray(toks), start_j, clen_j, row, plen,
                tgt, self.cache, pipe.last_tok, pipe.pos, pipe.target,
                pipe.rng, lora, jnp.asarray(ids))
            self._observe_trace("prefill_chunk_final", self._chunk_jit[key])
            pipe.stash(toks_out, [(st, 0, 1)])
            if self.pipeline == "perstep":
                pipe.flush()
        else:
            self.cache = self._chunk_jit[key](
                self.params, jnp.asarray(toks), start_j, clen_j,
                self.cache, lora, jnp.asarray(ids))
            self._observe_trace("prefill_chunk", self._chunk_jit[key])

    @staticmethod
    def _prefill_chunk_fn(cfg, mode, params, toks, start, clen, cache,
                          lora, page_ids):
        lora = dict(lora, mode=mode)
        view = cache_lib.gather_pages(cache, page_ids)
        _, new_view = model_lib.prefill_chunk(
            cfg, params, toks, start, clen, view, lora=lora, last=False)
        return cache_lib.scatter_pages(cache, new_view, page_ids[None])

    @staticmethod
    def _prefill_chunk_final_fn(cfg, mode, temperature, params, toks, start,
                                clen, row, plen, tgt, cache, last_tok, pos,
                                target, rng, lora, page_ids):
        lora = dict(lora, mode=mode)
        view = cache_lib.gather_pages(cache, page_ids)
        logits, new_view = model_lib.prefill_chunk(
            cfg, params, toks, start, clen, view, lora=lora, last=True)
        cache = cache_lib.scatter_pages(cache, new_view, page_ids[None])
        last = logits[:, 0]
        rng, sub = split_key(rng)
        toks_out = sample(last, temperature=temperature, rng=sub)
        last_tok = last_tok.at[row].set(toks_out, mode="drop")
        pos = pos.at[row].set(plen, mode="drop")
        target = target.at[row].set(tgt, mode="drop")
        return toks_out, cache, last_tok, pos, target, rng

    # ----------------------------------------------------------- decode ----
    def decode(self, ready: List[RequestState], row_slot, row_pos,
               row_pages=None):
        """One decode iteration over the ready rows."""
        self.transfer_stats["decode_steps"] += 1
        if self.pipeline == "perstep":
            return self._decode_perstep(ready, row_slot, row_pos)
        pipe = self.pipe
        if self.paged and row_pages is not None:
            self._san_check([p for st in ready for p in row_pages[st.row]],
                            "kv:", "decode block table")
        active, idx = pipe.refresh(ready, row_slot, row_pages)
        lora = {"pool": self.pool.pool, "idx": idx}
        toks, self.cache, pipe.last_tok, pipe.pos, pipe.rng = \
            self._decode_jit(self.params, self.cache, pipe.last_tok,
                             pipe.pos, active, pipe.target, lora, pipe.rng,
                             pipe.block_table)
        self._observe_trace("decode", self._decode_jit)
        pipe.stash(toks, [(st, st.row, 1) for st in ready])

    @staticmethod
    def _fused_step(cfg, mode, temperature, mask_ok, params, lora, cache,
                    last_tok, pos, act, rng, block_table=None):
        """Shared single-iteration body of the fused and megastep paths —
        one implementation, so K fused iterations are bitwise-identical
        to K single calls. Frozen/inactive rows: KV write dropped (or
        row-selected), token and position frozen. With a block table the
        cache is the shared page pool — frozen rows MUST drop their write
        (pages are per-request, a post-hoc row select cannot undo a write
        into the shared pool), hence paged requires supports_write_mask."""
        rng, sub = split_key(rng)
        wm = act if mask_ok else None
        logits, new_cache = model_lib.decode(
            cfg, params, cache, last_tok[:, None], pos, lora=lora,
            write_mask=wm, block_table=block_table)
        if not mask_ok:
            new_cache = _select_rows(new_cache, cache, act)
        toks = sample(logits[:, -1], temperature=temperature, rng=sub)
        last_tok = jnp.where(act, toks, last_tok)
        pos = jnp.where(act, pos + 1, pos)
        return new_cache, last_tok, pos, toks, rng

    @staticmethod
    def _decode_fused_fn(cfg, mode, temperature, mask_ok, params, cache,
                         last_tok, pos, active, target, lora, rng,
                         block_table):
        lora = dict(lora, mode=mode)
        act = active & (pos < target)
        cache, last_tok, pos, toks, rng = NumericsBackend._fused_step(
            cfg, mode, temperature, mask_ok, params, lora, cache, last_tok,
            pos, act, rng, block_table)
        return toks, cache, last_tok, pos, rng

    # --------------------------------------------------------- megastep ----
    def megastep(self, ready: List[RequestState], nsteps: List[int], K: int,
                 row_slot, row_pages=None):
        """K decode iterations in one jit call (`lax.scan`); per-row stop
        targets freeze rows that reach max_new_tokens mid-window. The
        engine guarantees no admission/arrival/load event lands inside
        the window. `nsteps[i]` = tokens request i actually produces
        (= min(steps left, K)); the (K, B) token block drains through the
        async readback queue like any other step."""
        if self.pipeline != "fused" or K < 2:
            raise RuntimeError(
                "megastep needs the fused pipeline and K >= 2 "
                f"(pipeline={self.pipeline!r}, K={K})")
        self.transfer_stats["decode_steps"] += K
        self.transfer_stats["megasteps"] += 1
        self.transfer_stats["megastep_iters"] += K
        pipe = self.pipe
        if self.paged and row_pages is not None:
            self._san_check([p for st in ready for p in row_pages[st.row]],
                            "kv:", "megastep block table")
        pipe.refresh(ready, row_slot, row_pages)
        if K not in self._megastep_jits:
            donate = (1, 2, 3, 7) if self._donate else ()
            self._megastep_jits[K] = jax.jit(functools.partial(
                self._megastep_fn, self.cfg, self._mode_str(),
                self.temperature, model_lib.supports_write_mask(self.cfg),
                K), donate_argnums=donate)
        lora = {"pool": self.pool.pool, "idx": pipe.idx}
        ys, self.cache, pipe.last_tok, pipe.pos, pipe.rng = \
            self._megastep_jits[K](
                self.params, self.cache, pipe.last_tok, pipe.pos,
                pipe.active, pipe.target, lora, pipe.rng, pipe.block_table)
        self._observe_trace(f"megastep[K={K}]", self._megastep_jits[K])
        pipe.stash(ys, [(st, st.row, n) for st, n in zip(ready, nsteps)])

    @staticmethod
    def _megastep_fn(cfg, mode, temperature, mask_ok, K, params, cache,
                     last_tok, pos, active, target, lora, rng, block_table):
        lora = dict(lora, mode=mode)

        def body(carry, _):
            cache, last_tok, pos, rng = carry
            act = active & (pos < target)
            cache, last_tok, pos, toks, rng = NumericsBackend._fused_step(
                cfg, mode, temperature, mask_ok, params, lora, cache,
                last_tok, pos, act, rng, block_table)
            return (cache, last_tok, pos, rng), toks

        (cache, last_tok, pos, rng), ys = jax.lax.scan(
            body, (cache, last_tok, pos, rng), None, length=K)
        return ys, cache, last_tok, pos, rng

    # ------------------------------------------------ legacy (perstep) ----
    def _decode_perstep(self, ready, row_slot, row_pos):
        """Pre-pipeline baseline: host-built token/position arrays each
        step, sampling off the full logits tensor, synchronous readback."""
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        live = np.zeros((self.max_batch,), bool)
        # lint: allow-host-sync — row_slot is host metadata, no transfer
        idx = np.asarray(row_slot).copy()
        for st in ready:
            toks[st.row, 0] = st.generated[-1] if st.generated else 0
            pos[st.row] = row_pos[st.row]
            live[st.row] = True
        idx[~live] = -1
        lora = {"pool": self.pool.pool, "idx": jnp.asarray(idx, jnp.int32)}
        self.transfer_stats["h2d"] += 3
        self.transfer_stats["h2d_bytes"] += (toks.nbytes + pos.nbytes
                                             + idx.nbytes)
        logits, self.cache = self._decode_legacy_jit(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            lora)
        # lint: allow-host-sync — the perstep pipeline is the synchronous
        # legacy baseline; blocking readback each step is its defining cost
        new = np.asarray(sample(logits[:, -1]))
        self.transfer_stats["d2h"] += 1
        self.transfer_stats["d2h_bytes"] += new.nbytes
        for st in ready:
            st.generated.append(int(new[st.row]))

    @staticmethod
    def _decode_legacy_fn(cfg, mode, params, cache, toks, pos, lora):
        lora = dict(lora, mode=mode)
        return model_lib.decode(cfg, params, cache, toks, pos, lora=lora)
