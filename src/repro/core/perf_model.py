"""Rank-aware performance models (paper sec 5, Fig 9).

    Perf_BGMV(S)  = alpha_B * |S| * max_{i in S} rank(i) + beta_B
    Perf_MBGMV(S) = alpha_M * sum_{i in S} rank(i)       + beta_M

Fitted by lightweight serving-performance profiling over varying batch sizes
and heterogeneous rank mixes; the profiler here is the analytic TimingModel
(same methodology as the paper's simulator, sec 7.5). The fit quality (R^2)
reproduces Fig 9's ~0.96 when profiling noise is enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.timing import Hardware, TimingModel, V5E


def batch_feature(ranks: Sequence[int], kernel: str) -> float:
    if not ranks:
        return 0.0
    if kernel == "bgmv":
        return len(ranks) * max(ranks)
    return float(sum(ranks))


@dataclasses.dataclass
class LinearPerfModel:
    alpha: float
    beta: float
    kernel: str               # bgmv | mbgmv
    r2: float = 1.0

    def predict(self, ranks: Sequence[int]) -> float:
        """Predicted iteration latency (ms) for a batch of adapter ranks."""
        if not ranks:
            return 0.0
        return self.alpha * batch_feature(ranks, self.kernel) + self.beta


def fit_linear(xs, ys, kernel: str) -> LinearPerfModel:
    xs = np.asarray(xs, float)
    ys = np.asarray(ys, float)
    A = np.stack([xs, np.ones_like(xs)], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, ys, rcond=None)
    pred = alpha * xs + beta
    ss_res = float(((ys - pred) ** 2).sum())
    ss_tot = float(((ys - ys.mean()) ** 2).sum()) or 1.0
    return LinearPerfModel(float(alpha), float(beta), kernel,
                           r2=1.0 - ss_res / ss_tot)


def profile_and_fit(cfg: ModelConfig, kernel: str, hw: Hardware = V5E,
                    noise: float = 0.02, seed: int = 0,
                    rank_choices=(8, 16, 32, 64), batch_sizes=None,
                    n_samples: int = 200, avg_ctx: int = 512):
    """Profile decode iterations over random heterogeneous batches and fit
    the linear law (reproduces Fig 9)."""
    tm = TimingModel(cfg, hw)
    rng = np.random.default_rng(seed)
    batch_sizes = batch_sizes or [1, 2, 4, 8, 16, 24, 32, 48, 64]
    xs, ys = [], []
    for _ in range(n_samples):
        bs = int(rng.choice(batch_sizes))
        ranks = [int(rng.choice(rank_choices)) for _ in range(bs)]
        lat = tm.base_decode_ms(bs, avg_ctx) + tm.lora_decode_ms(ranks, kernel)
        lat *= float(1.0 + rng.normal(0, noise))
        xs.append(batch_feature(ranks, kernel))
        ys.append(lat)
    return fit_linear(xs, ys, kernel), (xs, ys)


@dataclasses.dataclass
class ServerPerfModel:
    """PrePerf / DecPerf pair used by Algorithm 1."""
    cfg: ModelConfig
    kernel: str = "bgmv"
    hw: Hardware = V5E
    decode: Optional[LinearPerfModel] = None
    avg_prompt: int = 128

    def __post_init__(self):
        if self.decode is None:
            self.decode, _ = profile_and_fit(self.cfg, self.kernel, self.hw)
        self._tm = TimingModel(self.cfg, self.hw)

    def dec_perf(self, ranks: Sequence[int]) -> float:
        """Decode-iteration latency (ms) for a batch of ranks."""
        return self.decode.predict(ranks)

    def pre_perf(self, ranks: Sequence[int], tokens_each: int = None) -> float:
        """Prefill latency (ms) for queued requests (sequential prefills)."""
        if not ranks:
            return 0.0
        t = tokens_each or self.avg_prompt
        total = 0.0
        for r in ranks:
            total += self._tm.base_prefill_ms(t) \
                + self._tm.lora_prefill_gpu_ms(t, r)
        return total

    def prefill_spike_ms(self, tokens: int, chunk_budget: int = 0) -> float:
        """Worst single-iteration stall this prompt's prefill injects into
        a resident decode batch: the whole prompt at once on a monolithic
        server, one chunk (at its deepest context, where the quadratic
        attention term peaks) on a chunking server."""
        if tokens <= 0:
            return 0.0
        if 0 < chunk_budget < tokens:
            return self._tm.chunk_prefill_ms(chunk_budget,
                                             tokens - chunk_budget)
        return self._tm.base_prefill_ms(tokens)

    def load_perf(self, rank: int) -> float:
        """Host->device upload latency (ms) of a rank-`rank` adapter — the
        marginal link occupancy a cold start adds (Algorithm 1 extension for
        the async LoadTracker)."""
        from repro.core.lora import AdapterSpec
        spec = AdapterSpec("_probe", rank, self.cfg.name)
        return self._tm.load_ms(spec.nbytes(self.cfg))
