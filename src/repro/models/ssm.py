"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Prefill uses the chunked SSD algorithm (intra-chunk quadratic + inter-chunk
state recurrence via lax.scan); decode is the O(1) state update. LoRA targets
in_proj/out_proj (DESIGN.md sec Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lora import lora_apply
from repro.models.param import Box, dense_apply, dense_init, norm_apply, norm_init


def ssm_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    in_total = 2 * d_in + 2 * s.n_groups * s.state_dim + H
    return d_in, H, conv_dim, in_total


def ssm_block_init(cfg, key):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, conv_dim, in_total = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "norm": norm_init(d, cfg.jdtype, cfg.norm),
        "in_proj": dense_init(ks[0], d, in_total, ("embed", "mlp"), cfg.jdtype),
        "conv_w": Box(jax.random.normal(ks[1], (s.conv_width, conv_dim),
                                        cfg.jdtype) * 0.3, (None, "mlp")),
        "conv_b": Box(jnp.zeros((conv_dim,), cfg.jdtype), ("mlp",)),
        "a_log": Box(jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
                     (None,)),
        "dt_bias": Box(jnp.zeros((H,), jnp.float32), (None,)),
        "d_skip": Box(jnp.ones((H,), jnp.float32), (None,)),
        "gate_norm": norm_init(d_in, cfg.jdtype, "rmsnorm"),
        "out_proj": dense_init(ks[2], d_in, d, ("mlp", "embed"), cfg.jdtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,L,C), w: (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) cumulative: out[i,j] = sum_{j<t<=i} a_t
    for i >= j, -inf otherwise."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]          # sum_{j<t<=i}
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk):
    """SSD scan. x: (b,l,h,p); dt: (b,l,h) (post-softplus); A: (h,) negative;
    B, C: (b,l,g,n); D: (h,). Returns y: (b,l,h,p), final state (b,h,p,n)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Q = min(chunk, l)
    pad = (-l) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // Q
    xc = x.reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, g, n)
    Cc = C.reshape(b, nc, Q, g, n)
    a = (dtc * A).astype(jnp.float32)                     # (b,nc,Q,h) log-decay
    a_h = a.transpose(0, 1, 3, 2)                         # (b,nc,h,Q)
    cum = jnp.cumsum(a_h, axis=-1)                        # (b,nc,h,Q)

    # intra-chunk (quadratic, "attention-like")
    Lmat = jnp.exp(_segsum(a_h))                          # (b,nc,h,Q,Q)
    Bh = jnp.repeat(Bc, rep, axis=3)                      # (b,nc,Q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh).astype(jnp.float32)
    xdt = xc * dtc[..., None]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp",
                         (scores * Lmat).astype(x.dtype), xdt)

    # per-chunk final states
    decay_to_end = jnp.exp(cum[..., -1:] - cum)           # (b,nc,h,Q)
    states = jnp.einsum("bchq,bcqhn,bcqhp->bchpn",
                        decay_to_end.astype(x.dtype), Bh, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[..., -1])                   # (b,nc,h)

    def scan_fn(S, inp):
        st, dec = inp
        S_new = S * dec[..., None, None].astype(S.dtype) + st
        return S_new, S

    S0 = jnp.zeros((b, h, p, n), x.dtype)
    S_final, S_prev = jax.lax.scan(
        scan_fn, S0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)              # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Ch, S_prev,
                         jnp.exp(cum).astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, -1, h, p)[:, :l]
    y = y + x[:, :l] * D[None, None, :, None].astype(x.dtype)
    return y, S_final


def ssd_step(x_t, dt_t, A, B_t, C_t, D, state):
    """Decode step. x_t: (b,h,p); dt_t: (b,h); B_t,C_t: (b,g,n);
    state: (b,h,p,n) -> (y_t, new_state)."""
    h = x_t.shape[1]
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1)                     # (b,h,n)
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp((dt_t * A).astype(jnp.float32)).astype(state.dtype)
    upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], Bh)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + x_t * D[None, :, None].astype(x_t.dtype)
    return y, state


def _split_in_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_in, H, conv_dim, _ = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    return (zxbcdt[..., :d_in],
            zxbcdt[..., d_in:d_in + conv_dim],
            zxbcdt[..., d_in + conv_dim:])


def ssm_block_apply(cfg, p, x, *, lora_layer=None, lora_idx=None,
                    lora_ranks=None, lora_mode="bgmv", cache=None):
    """Full-sequence (prefill/train) pass. Returns (y, cache_out)."""
    s = cfg.ssm
    B_, L, d = x.shape
    d_in, H, conv_dim, _ = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    xn = norm_apply(p["norm"], x, cfg.norm)
    zxbcdt = dense_apply(p["in_proj"], xn)
    zxbcdt = zxbcdt + lora_apply(xn, lora_layer, "in_proj", lora_idx,
                                 lora_ranks, lora_mode, cfg.lora.rank_block)
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(B_, L, H, s.head_dim)
    Bm = xbc[..., d_in:d_in + gn].reshape(B_, L, s.n_groups, s.state_dim)
    Cm = xbc[..., d_in + gn:].reshape(B_, L, s.n_groups, s.state_dim)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, S_final = ssd_chunked(xs, dt_f.astype(x.dtype), A, Bm, Cm,
                             p["d_skip"], s.chunk)
    y = y.reshape(B_, L, d_in)
    y = norm_apply(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = dense_apply(p["out_proj"], y)
    out = out + lora_apply(y, lora_layer, "out_proj", lora_idx,
                           lora_ranks, lora_mode, cfg.lora.rank_block)
    cache_out = {
        "state": S_final,
        "conv": _pre_conv_tail(cfg, p, xn, zxbcdt, L),
    }
    return x + out, cache_out


def _pre_conv_tail(cfg, p, xn, zxbcdt, L):
    """Last conv_width-1 *pre-conv* xbc inputs, for the decode conv state."""
    s = cfg.ssm
    d_in, H, conv_dim, _ = ssm_dims(cfg)
    xbc_pre = zxbcdt[..., d_in:d_in + conv_dim]
    W = s.conv_width - 1
    if L >= W:
        return xbc_pre[:, L - W:L]
    pad = jnp.zeros((xbc_pre.shape[0], W - L, conv_dim), xbc_pre.dtype)
    return jnp.concatenate([pad, xbc_pre], axis=1)


def ssm_block_step(cfg, p, x_t, cache, *, lora_layer=None, lora_idx=None,
                   lora_ranks=None, lora_mode="bgmv"):
    """Decode step. x_t: (B,1,d); cache: {state:(B,H,P,N), conv:(B,W-1,conv_dim)}."""
    s = cfg.ssm
    B_, _, d = x_t.shape
    d_in, H, conv_dim, _ = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    xn = norm_apply(p["norm"], x_t, cfg.norm)
    zxbcdt = dense_apply(p["in_proj"], xn)
    zxbcdt = zxbcdt + lora_apply(xn, lora_layer, "in_proj", lora_idx,
                                 lora_ranks, lora_mode, cfg.lora.rank_block)
    z, xbc_pre, dt = _split_in_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([cache["conv"], xbc_pre], axis=1)  # (B,W,conv)
    xbc = sum(conv_in[:, i] * p["conv_w"][i] for i in range(s.conv_width))
    xbc = jax.nn.silu(xbc + p["conv_b"])                  # (B,conv_dim)
    xs = xbc[..., :d_in].reshape(B_, H, s.head_dim)
    Bm = xbc[..., d_in:d_in + gn].reshape(B_, s.n_groups, s.state_dim)
    Cm = xbc[..., d_in + gn:].reshape(B_, s.n_groups, s.state_dim)
    dt_f = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y_t, state = ssd_step(xs, dt_f.astype(x_t.dtype), A, Bm, Cm,
                          p["d_skip"], cache["state"])
    y = y_t.reshape(B_, 1, d_in)
    y = norm_apply(p["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = dense_apply(p["out_proj"], y)
    out = out + lora_apply(y, lora_layer, "out_proj", lora_idx,
                           lora_ranks, lora_mode, cfg.lora.rank_block)
    new_cache = {"state": state, "conv": conv_in[:, 1:]}
    return x_t + out, new_cache


def ssm_cache_init(cfg, batch):
    s = cfg.ssm
    d_in, H, conv_dim, _ = ssm_dims(cfg)
    return {
        "state": jnp.zeros((batch, H, s.head_dim, s.state_dim), cfg.jdtype),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), cfg.jdtype),
    }
