"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv frontend is a stub per the carve-out:
callers provide (B, enc_seq, d_model) frame embeddings. Learned positions;
pre-LN; decoder has self-attention (causal, cached, LoRA q/k/v) and
cross-attention (encoder K/V computed once at prefill and cached).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (attn_decode, cache_init, cache_write_prefill,
                                 emb_w, mlp_apply, mlp_init)
from repro.models.param import Box, dense_init, norm_apply, norm_init
from repro.models.transformer import attn_apply, attn_init, _proj


def enc_block_init(cfg, key):
    ks = jax.random.split(key, 2)
    return {
        "norm1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "attn": attn_init(cfg, ks[0]),
        "norm2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "mlp": mlp_init(cfg, ks[1]),
    }


def dec_block_init(cfg, key):
    ks = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "attn": attn_init(cfg, ks[0]),
        "norm_x": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "xattn": attn_init(cfg, ks[1], cross=True),
        "norm2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "mlp": mlp_init(cfg, ks[2]),
    }


def init_params(cfg, rng):
    ks = jax.random.split(rng, 6)
    dt = cfg.jdtype
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_pos": Box(jax.random.normal(ks[2], (cfg.enc_seq, cfg.d_model),
                                         dt) * 0.02, ("seq", "embed")),
        "enc_blocks": [enc_block_init(cfg, k) for k in enc_keys],
        "enc_norm": norm_init(cfg.d_model, dt, cfg.norm),
        "embed": Box(jax.random.normal(ks[3], (cfg.vocab, cfg.d_model), dt)
                     * 0.02, ("vocab", "embed")),
        "dec_pos": Box(jax.random.normal(ks[4], (cfg.max_ctx, cfg.d_model),
                                         dt) * 0.02, ("seq", "embed")),
        "dec_blocks": [dec_block_init(cfg, k) for k in dec_keys],
        "final_norm": norm_init(cfg.d_model, dt, cfg.norm),
        "lm_head": dense_init(ks[5], cfg.d_model, cfg.vocab,
                              (emb_w(cfg), "vocab"), dt),
    }


def encode(cfg, params, enc_embeds):
    """enc_embeds: (B, enc_seq, d) stubbed frontend output."""
    x = enc_embeds.astype(cfg.jdtype) + params["enc_pos"][None]
    B, L = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    for p_l in params["enc_blocks"]:
        xn = norm_apply(p_l["norm1"], x, cfg.norm)
        a, _ = attn_apply(cfg, p_l["attn"], xn, pos, causal=False)
        h = x + a
        hn = norm_apply(p_l["norm2"], h, cfg.norm)
        x = h + mlp_apply(cfg, p_l["mlp"], hn)
    return norm_apply(params["enc_norm"], x, cfg.norm)


def _dec_block(cfg, p_l, x, positions, enc_out, *, lora_layer, lora_idx,
               lora_ranks, lora_mode, cache, decode):
    """One decoder block. cache: {self: kv-cache, cross: {k,v,pos}}."""
    xn = norm_apply(p_l["norm1"], x, cfg.norm)
    a, self_cache = attn_apply(
        cfg, p_l["attn"], xn, positions, lora_layer=lora_layer,
        lora_idx=lora_idx, lora_ranks=lora_ranks, lora_mode=lora_mode,
        cache=cache["self"] if cache else None, decode=decode)
    x = x + a
    xn = norm_apply(p_l["norm_x"], x, cfg.norm)
    if decode:
        a, _ = attn_apply(cfg, p_l["xattn"], xn, positions,
                          cache=cache["cross"], decode=True,
                          kv_override=(None, None))
        cross_cache = cache["cross"]
    else:
        k = _proj(p_l["xattn"]["wk"], enc_out)
        v = _proj(p_l["xattn"]["wv"], enc_out)
        a, _ = attn_apply(cfg, p_l["xattn"], xn, positions, causal=False,
                          kv_override=(k, v))
        ep = jnp.broadcast_to(jnp.arange(enc_out.shape[1], dtype=jnp.int32),
                              (enc_out.shape[0], enc_out.shape[1]))
        cross_cache = {"k": k.transpose(0, 2, 1, 3),
                       "v": v.transpose(0, 2, 1, 3), "pos": ep}
    x = x + a
    xn = norm_apply(p_l["norm2"], x, cfg.norm)
    x = x + mlp_apply(cfg, p_l["mlp"], xn)
    return x, {"self": self_cache, "cross": cross_cache}


def prefill(cfg, params, tokens, enc_embeds, *, lora=None, cache_slots=None,
            last_only=False):
    """Returns (logits, cache). cache entries per decoder layer."""
    from repro.models.transformer import _lora_slice
    enc_out = encode(cfg, params, enc_embeds)
    B, L = tokens.shape
    x = params["embed"][tokens].astype(cfg.jdtype)
    idxs = jnp.minimum(jnp.arange(L), cfg.max_ctx - 1)
    x = x + params["dec_pos"][idxs][None]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    lora_stk, lora_idx, lora_ranks, lora_mode = _lora_slice(lora)
    caches = []
    for i, p_l in enumerate(params["dec_blocks"]):
        ll = ({t: {"a": lora_stk[t]["a"][i], "b": lora_stk[t]["b"][i]}
               for t in lora_stk} if lora_stk else None)
        c0 = {"self": cache_init(B, cfg.n_kv_heads, cache_slots, cfg.hd,
                                 cfg.jdtype), "cross": None} \
            if cache_slots else None
        x, c = _dec_block(cfg, p_l, x, positions, enc_out, lora_layer=ll,
                          lora_idx=lora_idx, lora_ranks=lora_ranks,
                          lora_mode=lora_mode, cache=c0, decode=False)
        caches.append(c)
    if last_only:
        x = x[:, -1:]
    xn = norm_apply(params["final_norm"], x, cfg.norm)
    return xn @ params["lm_head"]["w"], (caches if cache_slots else None)


def decode_step(cfg, params, cache, tokens_t, pos, *, lora=None, window=None):
    from repro.models.transformer import _lora_slice
    B = tokens_t.shape[0]
    x = params["embed"][tokens_t].astype(cfg.jdtype)
    pidx = jnp.minimum(pos, cfg.max_ctx - 1)
    x = x + params["dec_pos"][pidx][:, None]
    lora_stk, lora_idx, lora_ranks, lora_mode = _lora_slice(lora)
    new_caches = []
    for i, (p_l, c_l) in enumerate(zip(params["dec_blocks"], cache)):
        ll = ({t: {"a": lora_stk[t]["a"][i], "b": lora_stk[t]["b"][i]}
               for t in lora_stk} if lora_stk else None)
        x, c = _dec_block(cfg, p_l, x, pos, None, lora_layer=ll,
                          lora_idx=lora_idx, lora_ranks=lora_ranks,
                          lora_mode=lora_mode, cache=c_l, decode=True)
        new_caches.append(c)
    xn = norm_apply(params["final_norm"], x, cfg.norm)
    return xn @ params["lm_head"]["w"], new_caches
