"""Expert-parallel MoE via shard_map all-to-all — the beyond-paper fix for
the GSPMD-einsum MoE's pathological collectives (EXPERIMENTS.md sec Perf B).

Experts are owned by shards of the `data` axis; tokens travel to their
experts and back with two all-to-alls (token-proportional bytes), instead of
the einsum formulation's activation-sized all-reduces against FSDP-sharded
expert weights.

Shard layout over n_data = |data axis| (built by `shard_expert_weights`):
  * n_data >= E (production: grok 8 on 16, dbrx 16 on 16): each expert's
    d_ff is split into s = n_data/E slices; shard j owns slice j%s of
    expert j//s. Tokens are duplicated to all s slices of their expert and
    the partial outputs (w2 contracts over the f-slice) sum on return.
  * n_data < E (smoke tests): each shard owns E/n_data whole experts.

Within a shard the f-slice is further TP-sharded over `model` (partial
outputs psum over "model"). Differentiable end-to-end (all_to_all/psum have
transposes); numerics match moe_apply when capacity is not binding
(tests/test_moe_ep.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.models.param import Box


def ep_factors(E: int, n_data: int):
    """(s_factor, e_per_shard): f-slices per expert, experts per shard."""
    if n_data >= E:
        if n_data % E:
            raise ValueError(f"n_data ({n_data}) not a multiple of E ({E})")
        return n_data // E, 1
    if E % n_data:
        raise ValueError(f"E ({E}) not a multiple of n_data ({n_data})")
    return 1, E // n_data


def shard_expert_weights(cfg, p, n_data: int):
    """Global expert weights (E,d,f)/(E,f,d) -> EP layout with leading dim
    n_shards*e_per (sharded over data) and the f slice dim. No-op when the
    weights are already stored EP-native (cfg.moe_ep at init)."""
    E = cfg.moe.n_experts
    s, e_per = ep_factors(E, n_data)
    f = cfg.d_ff
    fs = f // s
    if p["w1"]["w"].shape[0] == E * s and p["w1"]["w"].shape[2] == fs:
        return p                        # already EP-native

    def win(w):                       # (E, d, f) -> (E*s, d, f/s)
        E_, d_, f_ = w.shape
        return w.reshape(E_, d_, s, fs).transpose(0, 2, 1, 3) \
                .reshape(E_ * s, d_, fs)

    def wout(w):                      # (E, f, d) -> (E*s, f/s, d)
        E_, f_, d_ = w.shape
        return w.reshape(E_, s, fs, d_).reshape(E_ * s, fs, d_)

    out = {"router": p["router"], "w1": {"w": win(p["w1"]["w"])},
           "w2": {"w": wout(p["w2"]["w"])}}
    if "w3" in p:
        out["w3"] = {"w": win(p["w3"]["w"])}
    return out


def moe_apply_ep(cfg, p, x, mesh, *, data_axes=("data",)):
    """x: (B, T, d) -> (y, aux). p: standard moe params (global layout);
    resharded to the EP layout on the fly (a reshape/transpose GSPMD handles
    once per step, amortized across the layer scan by XLA CSE)."""
    B, T, d = x.shape
    E, top_k = cfg.moe.n_experts, cfg.moe.top_k
    cf = cfg.moe.capacity_factor
    axis_sizes = getattr(mesh, "axis_sizes", None)
    if axis_sizes is None:
        axis_sizes = mesh.devices.shape
    sizes = dict(zip(mesh.axis_names, axis_sizes))
    n_data = 1
    for a in data_axes:
        n_data *= sizes.get(a, 1)
    s_factor, e_per = ep_factors(E, n_data)
    n_shards = n_data
    tokens_global = B * T
    if tokens_global % n_data:
        raise ValueError(
            f"B*T ({tokens_global}) must divide over the data axis "
            f"({n_data} shards)")
    t_loc = tokens_global // n_data
    cap = max(-(-t_loc * top_k * int(cf * 4) // (4 * E)), top_k)
    cap = -(-cap // 4) * 4

    pe = shard_expert_weights(cfg, p, n_data)
    P = jax.sharding.PartitionSpec
    da = data_axes if len(data_axes) > 1 else data_axes[0]

    def local(x_loc, wr, w1, w2, w3):
        # x_loc: (t_loc, d); w1: (e_per, d, f_loc); w2: (e_per, f_loc, d)
        logits = (x_loc @ wr).astype(jnp.float32)           # (t, E)
        probs = jax.nn.softmax(logits, -1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)   # (t, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)
        oh = onehot.reshape(t_loc * top_k, E)
        pos = (jnp.cumsum(oh, 0) - oh)                      # (t*k, E)
        pos = (pos * oh).sum(-1).reshape(t_loc, top_k)
        keep = pos < cap

        # scatter into (n_shards, e_per, cap, d); under s_factor>1 each
        # assignment is duplicated to the s f-slices of its expert
        buf = jnp.zeros((n_shards * e_per * cap, d), x_loc.dtype)
        x_rep = jnp.repeat(x_loc[:, None], top_k, 1).reshape(-1, d)
        e_flat = gate_idx.reshape(-1)
        p_flat = jnp.where(keep, pos, cap).reshape(-1)      # cap -> dropped
        for r in range(s_factor):
            shard = e_flat * s_factor + r if e_per == 1 \
                else e_flat // e_per
            ew = jnp.zeros_like(e_flat) if e_per == 1 else e_flat % e_per
            flat_idx = (shard * e_per + ew) * cap + p_flat
            oob = jnp.where(p_flat >= cap, buf.shape[0], flat_idx)
            buf = buf.at[oob].add(x_rep, mode="drop")
        buf = buf.reshape(n_shards, e_per * cap, d)

        recv = jax.lax.all_to_all(buf, da, 0, 0, tiled=True)
        # recv: (n_shards, e_per*cap, d) — row j: tokens from source j
        xin = recv.reshape(n_shards, e_per, cap, d)
        h1 = jnp.einsum("jecd,edf->jecf", xin, w1)
        if w3 is not None:
            act = jax.nn.silu(h1) if cfg.mlp_act == "silu" \
                else jax.nn.gelu(h1)
            h = act * jnp.einsum("jecd,edf->jecf", xin, w3)
        else:
            h = jax.nn.gelu(h1)
        out = jnp.einsum("jecf,efd->jecd", h, w2)           # f-slice partial
        if "model" in sizes:
            out = jax.lax.psum(out, "model")
        back = jax.lax.all_to_all(
            out.reshape(n_shards, e_per * cap, d), da, 0, 0, tiled=True)
        back = back.reshape(n_shards, e_per, cap, d)

        # combine: sum the s_factor f-slice partials + gate weights
        y = jnp.zeros((t_loc, d), x_loc.dtype)
        safe_p = jnp.minimum(p_flat, cap - 1)
        contrib = jnp.zeros((t_loc * top_k, d), x_loc.dtype)
        for r in range(s_factor):
            shard = e_flat * s_factor + r if e_per == 1 \
                else e_flat // e_per
            ew = jnp.zeros_like(e_flat) if e_per == 1 else e_flat % e_per
            contrib = contrib + back[shard, ew, safe_p]
        contrib = contrib.reshape(t_loc, top_k, d)
        w = (keep * gate_vals).astype(contrib.dtype)[..., None]
        y = (contrib * w).sum(1)

        # load-balance aux (local estimate, averaged over data shards)
        frac = onehot.sum((0, 1)).astype(jnp.float32) / (t_loc * top_k)
        aux = E * (frac * probs.mean(0)).sum()
        aux = jax.lax.pmean(aux, da)
        if "model" in sizes:
            aux = jax.lax.pmean(aux, "model")
        return y, aux

    fn = jax_compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(da, None), P(None, None), P(da, None, "model"),
                  P(da, "model", None), P(da, None, "model")),
        out_specs=(P(da, None), P()),
    )
    w3 = pe["w3"]["w"] if "w3" in pe else jnp.zeros(
        (pe["w1"]["w"].shape[0], d, pe["w1"]["w"].shape[2]), x.dtype)
    y, aux = fn(x.reshape(tokens_global, d), pe["router"]["w"],
                pe["w1"]["w"], pe["w2"]["w"], w3)
    return y.reshape(B, T, d), aux
