"""Family dispatch: one uniform interface over dense/moe/vlm, ssm, hybrid and
encoder-decoder models.

  init_params(cfg, rng)            -> Box tree (values + logical axes)
  prefill(cfg, params, batch, ...) -> (logits, cache)
  decode(cfg, params, cache, ...)  -> (logits, cache)
  loss(cfg, params, batch, ...)    -> (scalar, aux)
  input_specs(cfg, shape)          -> ShapeDtypeStruct stand-ins (dry-run)
  cache_abstract(cfg, batch, ...)  -> cache ShapeDtypeStructs (decode dry-run)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core import lora as lora_lib
from repro.models import encdec, rglru, ssm as ssm_mod, transformer
from repro.models.param import Box, dense_init, norm_init, split, stack_boxes


# ----------------------------------------------------------------- init ----

def init_params(cfg: ModelConfig, rng):
    if cfg.family in ("audio", "encdec"):
        return encdec.init_params(cfg, rng)
    if cfg.family == "ssm":
        k_emb, k_blocks = jax.random.split(rng)
        keys = jax.random.split(k_blocks, cfg.n_layers)
        return {
            "embed": Box(jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                           cfg.jdtype) * 0.02,
                         ("vocab", "embed")),
            "blocks": stack_boxes(
                functools.partial(ssm_mod.ssm_block_init, cfg), keys),
            "final_norm": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        }
    return transformer.init_params(cfg, rng)


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """(ShapeDtypeStruct value tree, logical axes tree) without allocation."""
    box = jax.eval_shape(lambda k: init_params(cfg, k),
                         jax.ShapeDtypeStruct((2,), jnp.uint32))
    return split(box)


# -------------------------------------------------------------- prefill ----

def supports_last_pos(cfg: ModelConfig) -> bool:
    """True when prefill() accepts `last_pos` (per-row pre-unembed gather:
    the vocab projection runs on one position per row). Other families
    gather post-logits instead — still on device, just paying the full
    unembed."""
    return cfg.family not in ("audio", "encdec")


def supports_write_mask(cfg: ModelConfig) -> bool:
    """True when decode() accepts `write_mask` (per-row cache-write drop:
    frozen rows' cache stays bitwise-untouched with no full-cache select).
    The serving pipeline falls back to a per-row tree select otherwise."""
    return cfg.family not in ("audio", "encdec")


def supports_paged(cfg: ModelConfig) -> bool:
    """True when decode() accepts the paged (block-table) cache layout:
    the uniform layered GQA KV cache. Recurrent state (ssm/hybrid),
    enc-dec list caches, and int8-quantized KV (per-slot scales would need
    their own pages) stay on the dense per-row layout."""
    return (cfg.family not in ("audio", "encdec", "ssm")
            and not cfg.hybrid and cfg.kv_cache_dtype != "int8")


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """True when the serving backend may split this model's prefill into
    fixed-token chunks (backend.prefill_chunk): needs the paged layered
    GQA cache plus per-position-independent blocks. MoE capacity routing
    depends on how many tokens share the batch, so chunk-vs-monolithic
    bitwise parity cannot hold there."""
    return supports_paged(cfg) and not cfg.moe


def prefill_chunk(cfg, params, tokens_c, start, clen, view, *, lora=None,
                  last=False):
    """One chunk of an incremental prefill over a gathered paged-cache
    view; see transformer.prefill_chunk and supports_chunked_prefill."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"chunked prefill unsupported for {cfg.name}")
    return transformer.prefill_chunk(cfg, params, tokens_c, start, clen,
                                     view, lora=lora, last=last)


def prefill(cfg, params, batch, *, lora=None, cache_slots=None, window=None,
            last_only=False, last_pos=None):
    """batch: {tokens, [enc_embeds], [prefix_embeds]}. -> (logits, cache).
    last_only=True returns logits only for the final position (serving);
    last_pos: (B,) per-row positions gathered before the unembed (batched
    serving prefill of ragged prompts — see supports_last_pos)."""
    if cfg.family in ("audio", "encdec"):
        if last_pos is not None:
            raise ValueError("last_pos unsupported for encdec families")
        return encdec.prefill(cfg, params, batch["tokens"],
                              batch["enc_embeds"], lora=lora,
                              cache_slots=cache_slots, last_only=last_only)
    if cfg.family == "ssm":
        return _ssm_prefill(cfg, params, batch["tokens"], lora=lora,
                            need_cache=cache_slots is not None,
                            last_only=last_only, last_pos=last_pos)
    return transformer.prefill(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"), lora=lora,
        cache_slots=cache_slots, window=window, last_only=last_only,
        last_pos=last_pos)


def _ssm_prefill(cfg, params, tokens, *, lora=None, need_cache=False,
                 last_only=False, last_pos=None):
    x = params["embed"][tokens].astype(cfg.jdtype)
    lora_stk, lora_idx, lora_ranks, lora_mode = transformer._lora_slice(lora)

    def body(carry, xs):
        x = carry
        p_l, lora_l = xs
        y, c = ssm_mod.ssm_block_apply(
            cfg, p_l, x, lora_layer=lora_l, lora_idx=lora_idx,
            lora_ranks=lora_ranks, lora_mode=lora_mode)
        return y, c

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll_layers:
        caches = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda v: v[i], (params["blocks"], lora_stk))
            x, c = body_fn(x, xs_i)
            caches.append(c)
        caches = jax.tree.map(lambda *cs: jnp.stack(cs), *caches) \
            if need_cache else None
    else:
        x, caches = jax.lax.scan(body_fn, x, (params["blocks"], lora_stk))
    if last_pos is not None:
        x = x[jnp.arange(x.shape[0]), last_pos][:, None]
    elif last_only:
        x = x[:, -1:]
    logits = transformer.unembed(cfg, params, x)
    return logits, (caches if need_cache else None)


# --------------------------------------------------------------- decode ----

def decode(cfg, params, cache, tokens_t, pos, *, lora=None, window=None,
           write_mask=None, block_table=None):
    """write_mask: (B,) bool — rows with False skip the cache/state write,
    leaving their row bitwise-untouched (see supports_write_mask).
    block_table: (B, W) — the cache is the paged page-pool layout (see
    supports_paged)."""
    if cfg.family in ("audio", "encdec"):
        if write_mask is not None:
            raise ValueError("write_mask unsupported for encdec")
        if block_table is not None:
            raise ValueError("paged cache unsupported for encdec")
        return encdec.decode_step(cfg, params, cache, tokens_t, pos,
                                  lora=lora)
    if cfg.family == "ssm":
        if block_table is not None:
            raise ValueError("paged cache unsupported for ssm")
        return _ssm_decode(cfg, params, cache, tokens_t, pos, lora=lora,
                           write_mask=write_mask)
    return transformer.decode_step(cfg, params, cache, tokens_t, pos,
                                   lora=lora, window=window,
                                   write_mask=write_mask,
                                   block_table=block_table)


def _ssm_decode(cfg, params, cache, tokens_t, pos, *, lora=None,
                write_mask=None):
    x = params["embed"][tokens_t].astype(cfg.jdtype)
    lora_stk, lora_idx, lora_ranks, lora_mode = transformer._lora_slice(lora)

    def body(x, xs):
        p_l, c_l, lora_l = xs
        y, c = ssm_mod.ssm_block_step(
            cfg, p_l, x, c_l, lora_layer=lora_l, lora_idx=lora_idx,
            lora_ranks=lora_ranks, lora_mode=lora_mode)
        return y, c

    if cfg.unroll_layers:
        new_caches = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda v: v[i],
                                (params["blocks"], cache, lora_stk))
            x, c = body(x, xs_i)
            new_caches.append(c)
        new_cache = jax.tree.map(lambda *cs: jnp.stack(cs), *new_caches)
    else:
        x, new_cache = jax.lax.scan(body, x,
                                    (params["blocks"], cache, lora_stk))
    if write_mask is not None:
        # recurrent state has no slot to drop a write into: per-row select
        # keeps frozen rows' state untouched (batch is axis 1, layer-leading)
        new_cache = jax.tree.map(
            lambda new, old: jnp.where(
                write_mask.reshape((1, -1) + (1,) * (new.ndim - 2)),
                new, old), new_cache, cache)
    return transformer.unembed(cfg, params, x), new_cache


# ----------------------------------------------------------------- loss ----

def loss(cfg, params, batch, *, lora=None, aux_weight=0.01):
    """Next-token cross-entropy (+ MoE aux). batch: tokens, loss_mask."""
    logits, _ = prefill(cfg, params, batch, lora=lora)
    if cfg.family == "vlm" and cfg.n_prefix_tokens:
        logits = logits[:, cfg.n_prefix_tokens:]
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    # one-hot contraction instead of take_along_axis: reduces over the
    # (model-sharded) vocab dim without an all-gather of the logits
    m = jax.lax.stop_gradient(lg.max(-1, keepdims=True))
    shifted = lg - m
    lse = jnp.log(jnp.exp(shifted).sum(-1))
    onehot = jax.nn.one_hot(targets, lg.shape[-1], dtype=lg.dtype)
    label_logit = (shifted * onehot).sum(-1)
    nll = lse - label_logit
    mask = batch.get("loss_mask")
    mask = mask[:, 1:].astype(jnp.float32) if mask is not None \
        else jnp.ones_like(nll)
    ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    aux = getattr(transformer.prefill, "last_aux", 0.0) if cfg.moe else 0.0
    return ce + aux_weight * aux, {"ce": ce}


# ---------------------------------------------------- dry-run input specs ----

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape
    (weak-type-correct, shardable, no device allocation)."""
    B, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sd((B, L), i32)}
        if shape.kind == "train":
            batch["loss_mask"] = sd((B, L), i32)
        if cfg.family in ("audio", "encdec"):
            batch["enc_embeds"] = sd((B, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        if cfg.family == "vlm" and cfg.n_prefix_tokens:
            batch["prefix_embeds"] = sd((B, cfg.n_prefix_tokens, cfg.d_model),
                                        cfg.jdtype)
        return {"batch": batch}
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens_t": sd((B, 1), i32),
        "pos": sd((B,), i32),
        "cache": cache_abstract(cfg, B, L),
    }


def decode_cache_slots(cfg: ModelConfig, seq_len: int) -> Optional[int]:
    """Cache depth for a decode shape: full-depth unless the sliding-window
    variant is in force (long_500k on windowed archs)."""
    if cfg.sliding_window and seq_len > 65536:
        return cfg.sliding_window
    return seq_len


def decode_window(cfg: ModelConfig, seq_len: int):
    return cfg.sliding_window if (cfg.sliding_window and seq_len > 65536) \
        else None


def cache_abstract(cfg: ModelConfig, batch: int, seq_len: int):
    """ShapeDtypeStruct tree matching the decode cache layout."""
    sd = jax.ShapeDtypeStruct
    i32 = jnp.int32
    dt = cfg.jdtype
    L = cfg.n_layers

    quant = cfg.kv_cache_dtype == "int8"

    def kv(slots, layered=True, kv_heads=None, allow_quant=True):
        kvh = kv_heads or cfg.n_kv_heads
        lead = (L,) if layered else ()
        q = quant and allow_quant
        out = {"k": sd(lead + (batch, kvh, slots, cfg.hd),
                       jnp.int8 if q else dt),
               "v": sd(lead + (batch, kvh, slots, cfg.hd),
                       jnp.int8 if q else dt),
               "pos": sd(lead + (batch, slots), i32)}
        if q:
            out["k_scale"] = sd(lead + (batch, kvh, slots), jnp.float32)
            out["v_scale"] = sd(lead + (batch, kvh, slots), jnp.float32)
        return out

    if cfg.family == "ssm":
        s = cfg.ssm
        d_in, H, conv_dim, _ = ssm_mod.ssm_dims(cfg)
        return {
            "state": sd((L, batch, H, s.head_dim, s.state_dim), dt),
            "conv": sd((L, batch, s.conv_width - 1, conv_dim), dt),
        }
    if cfg.hybrid:
        kinds = transformer.hybrid_layer_kinds(cfg)
        w = cfg.hybrid.lru_width or cfg.d_model
        out = []
        for kind in kinds:
            if kind == "rglru":
                out.append({"h": sd((batch, w), dt),
                            "conv": sd((batch, 3, w), dt)})
            else:
                out.append(kv(min(seq_len, cfg.hybrid.window), layered=False))
        return out
    if cfg.family in ("audio", "encdec"):
        slots = min(seq_len, cfg.max_ctx)
        return [{"self": kv(slots, layered=False, allow_quant=False),
                 "cross": kv(cfg.enc_seq, layered=False, allow_quant=False)}
                for _ in range(cfg.n_layers)]
    slots = decode_cache_slots(cfg, seq_len)
    return kv(slots, layered=True)


def cache_logical_axes(cfg: ModelConfig, cache_tree):
    """Logical axes for every cache leaf (for dry-run in_shardings)."""
    def axes_of(path, leaf):
        nd = len(leaf.shape)
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            # (.., B, KV, S, hd)
            base = ("batch", "kv_heads", "cache_seq", None)
            return ("layers",) * (nd - 4) + base
        if name in ("k_scale", "v_scale"):
            return ("layers",) * (nd - 3) + ("batch", "kv_heads", "cache_seq")
        if name == "pos":
            return ("layers",) * (nd - 2) + ("batch", "cache_seq")
        if name == "state":
            return ("layers",) * (nd - 4) + ("batch", "heads", None, None)
        if name == "conv":
            return ("layers",) * (nd - 3) + ("batch", None, "mlp")
        if name == "h":
            return ("batch", "mlp")
        return ("batch",) + (None,) * (nd - 1)

    return jax.tree_util.tree_map_with_path(axes_of, cache_tree)


def batch_logical_axes(batch_tree):
    """Batch inputs: shard dim0 over ("pod","data")."""
    return jax.tree.map(
        lambda leaf: ("batch",) + (None,) * (len(leaf.shape) - 1), batch_tree)
