"""Parameter boxes: every param leaf is created as Box(value, logical_axes);
``split`` separates the value tree from the axes tree (same structure) so the
launcher can derive shardings without a second, hand-maintained spec tree.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Box:
    """Param leaf wrapper: array value + static logical-axes tuple."""

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Box({getattr(self.value, 'shape', self.value)}, {self.axes})"


jax.tree_util.register_pytree_node(
    Box,
    lambda b: ((b.value,), b.axes),
    lambda axes, children: Box(children[0], axes),
)


def is_box(x) -> bool:
    return isinstance(x, Box)


def split(tree):
    """Box tree -> (value tree, axes tree)."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)
    return values, axes


def stack_boxes(fn, keys):
    """Stack per-layer Box trees: fn(key) -> Box tree; returns one Box tree
    whose leaves have a leading 'layers' dim (for lax.scan over layers)."""
    abox = jax.eval_shape(fn, keys[0])
    leaves, treedef = jax.tree.flatten(abox, is_leaf=is_box)

    def values_only(k):
        return [b.value for b in
                jax.tree.flatten(fn(k), is_leaf=is_box)[0]]

    stacked = jax.vmap(values_only)(keys)
    new = [Box(v, ("layers",) + tuple(b.axes))
           for v, b in zip(stacked, leaves)]
    return jax.tree.unflatten(treedef, new)


def dense_init(key, d_in, d_out, axes, dtype, bias=False, scale=None,
               bias_axes=None):
    """Linear layer params as Boxes. axes = logical axes of the weight."""
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": Box(jax.random.normal(key, (d_in, d_out), dtype) * scale, axes)}
    if bias:
        p["b"] = Box(jnp.zeros((d_out,), dtype), bias_axes or (axes[-1],))
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d, dtype, kind="rmsnorm"):
    p = {"scale": Box(jnp.ones((d,), dtype), ("embed",))}
    if kind == "layernorm":
        p["bias"] = Box(jnp.zeros((d,), dtype), ("embed",))
    return p


def norm_apply(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        xf = xf - xf.mean(-1, keepdims=True)
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    if kind == "layernorm" and "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
