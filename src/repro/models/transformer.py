"""Decoder-only transformer stack (dense / MoE / VLM / hybrid), scan-over-
layers, GQA KV cache, LoRA hooks on W_q/W_k/W_v (paper sec 7.1).

QKV projections are stored 3-D — (d_model, heads, head_dim) — so head
sharding is decided by head-count divisibility, never splitting a head
across the model axis (DESIGN.md sec 5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.core.lora import lora_apply
from repro.models import rglru
from repro.models.layers import (attn_decode, attn_direct, attn_prefill,
                                 cache_init,
                                 cache_kv_for_attn, cache_write_prefill,
                                 cache_write_token, cache_write_token_paged,
                                 emb_w, mlp_apply, mlp_init,
                                 paged_attn_decode, rope)
from repro.models.moe import moe_apply, moe_init
from repro.models.param import (Box, dense_init, norm_apply, norm_init,
                                split, stack_boxes)


# ------------------------------------------------------------ attention ----

def attn_init(cfg, key, cross=False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    ew = emb_w(cfg)
    dt = cfg.jdtype

    def proj(k, nh):
        p = {"w": Box(jax.random.normal(k, (d, nh, hd), dt) * d ** -0.5,
                      (ew, "kv_heads" if nh == KV and nh != H else "heads",
                       None))}
        if cfg.qkv_bias:
            p["b"] = Box(jnp.zeros((nh, hd), dt), ("heads", None))
        return p

    return {
        "wq": proj(ks[0], H),
        "wk": proj(ks[1], KV),
        "wv": proj(ks[2], KV),
        "wo": {"w": Box(jax.random.normal(ks[3], (H, hd, d), dt)
                        * (H * hd) ** -0.5, ("heads", None, ew))},
    }


def _proj(p, x):
    y = jnp.einsum("bld,dnh->blnh", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def _lora_heads(xn, lora_layer, tgt, idx, ranks, mode, rank_block, nh, hd):
    delta = lora_apply(xn, lora_layer, tgt, idx, ranks, mode, rank_block)
    if isinstance(delta, float):
        return 0.0
    return delta.reshape(*delta.shape[:-1], nh, hd)


def attn_apply(cfg, p, x, positions, *, lora_layer=None, lora_idx=None,
               lora_ranks=None, lora_mode="bgmv", window=None, causal=True,
               cache=None, decode=False, kv_override=None, write_mask=None,
               block_table=None):
    """Returns (out, new_cache). positions: (B,L) prefill / (B,) decode.
    kv_override: (k, v) precomputed (whisper cross-attention).
    write_mask: (B,) bool — decode rows excluded from the KV write (their
    cache row stays bitwise-untouched; the serving pipeline's frozen/dead
    rows). block_table: (B, W) — decode against the paged cache layout
    (cache leaves are page pools; see layers.cache_write_token_paged)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rb = cfg.lora.rank_block
    q = _proj(p["wq"], x) + _lora_heads(x, lora_layer, "q", lora_idx,
                                        lora_ranks, lora_mode, rb, H, hd)
    if kv_override is None:
        k = _proj(p["wk"], x) + _lora_heads(x, lora_layer, "k", lora_idx,
                                            lora_ranks, lora_mode, rb, KV, hd)
        v = _proj(p["wv"], x) + _lora_heads(x, lora_layer, "v", lora_idx,
                                            lora_ranks, lora_mode, rb, KV, hd)
    else:
        k, v = kv_override
    if cfg.pos == "rope" and kv_override is None:
        pos2d = positions if positions.ndim == 2 else positions[:, None]
        q = rope(q, pos2d, cfg.rope_theta)
        k = rope(k, pos2d, cfg.rope_theta)
    elif cfg.pos == "rope":
        pos2d = positions if positions.ndim == 2 else positions[:, None]
        q = rope(q, pos2d, cfg.rope_theta)

    new_cache = cache
    if decode:
        if kv_override is None and block_table is not None:
            new_cache = cache_write_token_paged(cache, k, v, positions,
                                                block_table,
                                                write_mask=write_mask)
            out = paged_attn_decode(q, new_cache, block_table, positions,
                                    window=window)
        elif kv_override is None:
            new_cache = cache_write_token(cache, k, v, positions,
                                          write_mask=write_mask)
            ck, cv = cache_kv_for_attn(new_cache, cfg.jdtype)
            out = attn_decode(q, ck, cv, new_cache["pos"], positions,
                              window=window)
        else:
            ck, cv = cache_kv_for_attn(cache, cfg.jdtype)
            out = attn_decode(q, ck, cv, cache["pos"],
                              jnp.full((B,), 2 ** 30, jnp.int32))
    else:
        out = attn_prefill(q, k, v, causal=causal, window=window)
        if cache is not None:
            new_cache = cache_write_prefill(cache, k, v, positions)
    y = jnp.einsum("blnh,nhd->bld", out, p["wo"]["w"])
    return y, new_cache


# ---------------------------------------------------------------- blocks ----

def block_init(cfg, key):
    ks = jax.random.split(key, 2)
    p = {
        "norm1": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
        "attn": attn_init(cfg, ks[0]),
        "norm2": norm_init(cfg.d_model, cfg.jdtype, cfg.norm),
    }
    p["moe" if cfg.moe else "mlp"] = (
        moe_init(cfg, ks[1]) if cfg.moe else mlp_init(cfg, ks[1]))
    return p


def block_apply(cfg, p, x, positions, *, lora_layer, lora_idx, lora_ranks,
                lora_mode, window, cache, decode, group_by_sequence=True,
                write_mask=None, block_table=None):
    """Returns (y, new_cache, aux)."""
    xn = norm_apply(p["norm1"], x, cfg.norm)
    a, new_cache = attn_apply(
        cfg, p["attn"], xn, positions, lora_layer=lora_layer,
        lora_idx=lora_idx, lora_ranks=lora_ranks, lora_mode=lora_mode,
        window=window, cache=cache, decode=decode, write_mask=write_mask,
        block_table=block_table)
    h = x + a
    hn = norm_apply(p["norm2"], h, cfg.norm)
    if cfg.moe:
        amesh = jax_compat.get_abstract_mesh()
        if cfg.moe_ep and "data" in amesh.axis_names:
            from repro.models.moe_ep import moe_apply_ep
            data_axes = tuple(a for a in ("pod", "data")
                              if a in amesh.axis_names)
            m, aux = moe_apply_ep(cfg, p["moe"], hn, amesh,
                                  data_axes=data_axes)
        else:
            m, aux = moe_apply(cfg, p["moe"], hn,
                               group_by_sequence=group_by_sequence)
    else:
        m, aux = mlp_apply(cfg, p["mlp"], hn), 0.0
    return h + m, new_cache, aux


# ------------------------------------------------------------- top level ----

def init_params(cfg, rng):
    """Box tree for dense/moe/vlm/hybrid decoder-only models."""
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    dt = cfg.jdtype
    params = {
        "embed": Box(jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), dt)
                     * 0.02, ("vocab", "embed")),
        "final_norm": norm_init(cfg.d_model, dt, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab,
                                       (emb_w(cfg), "vocab"), dt)
    if cfg.hybrid:
        pat = cfg.hybrid.pattern
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = [
            rglru.rglru_block_init(cfg, keys[i])
            if pat[i % len(pat)] == "rglru" else block_init(cfg, keys[i])
            for i in range(cfg.n_layers)
        ]
    else:
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = stack_boxes(
            functools.partial(block_init, cfg), keys)
    return params


def hybrid_layer_kinds(cfg):
    pat = cfg.hybrid.pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def embed_tokens(cfg, params, tokens, prefix_embeds=None):
    x = params["embed"][tokens].astype(cfg.jdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def unembed(cfg, params, x):
    xn = norm_apply(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        return jnp.einsum("bld,vd->blv", xn, params["embed"])
    return xn @ params["lm_head"]["w"]


def _lora_slice(lora, i=None):
    """Per-layer slice of the lora pool; None-safe. i=None keeps the stacked
    pool (used as scan xs)."""
    if lora is None:
        return None, None, None, "none"
    pool, idx, mode = lora["pool"], lora["idx"], lora.get("mode", "bgmv")
    ranks = pool["ranks"]
    per_layer = {t: ({"a": pool[t]["a"][i], "b": pool[t]["b"][i]}
                     if i is not None else pool[t]) for t in pool
                 if t != "ranks"}
    return per_layer, idx, ranks, mode


def prefill(cfg, params, tokens, *, prefix_embeds=None, lora=None,
            cache_slots=None, window=None, positions=None, last_only=False,
            last_pos=None):
    """Returns (logits, cache). cache_slots=None -> no cache (training).
    last_pos: optional (B,) int32 of per-row positions — the residual
    stream is gathered to those positions *before* the unembed, so a
    padded serving prefill pays the vocab projection for one position per
    row and the (B, L, vocab) logits tensor is never materialized."""
    x = embed_tokens(cfg, params, tokens, prefix_embeds)
    B, L = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    make_cache = cache_slots is not None
    slots = cache_slots or 0
    lora_stk, lora_idx, lora_ranks, lora_mode = _lora_slice(lora)

    if cfg.hybrid:
        kinds = hybrid_layer_kinds(cfg)
        caches, aux = [], 0.0
        for i, (kind, p_l) in enumerate(zip(kinds, params["blocks"])):
            if kind == "rglru":
                x, c = rglru.rglru_block_apply(cfg, p_l, x)
                caches.append(c)
            else:
                ll = ({t: {"a": lora_stk[t]["a"][i], "b": lora_stk[t]["b"][i]}
                       for t in lora_stk} if lora_stk else None)
                c0 = cache_init(B, cfg.n_kv_heads,
                                min(slots, cfg.hybrid.window) or cfg.hybrid.window,
                                cfg.hd, cfg.jdtype) if make_cache else None
                x, c, a = block_apply(
                    cfg, p_l, x, positions, lora_layer=ll, lora_idx=lora_idx,
                    lora_ranks=lora_ranks, lora_mode=lora_mode,
                    window=cfg.hybrid.window, cache=c0, decode=False)
                caches.append(c)
                aux += a
        if last_pos is not None:
            x = x[jnp.arange(B), last_pos][:, None]
        elif last_only:
            x = x[:, -1:]
        return unembed(cfg, params, x), (caches if make_cache else None)

    def body(carry, xs):
        x, aux = carry
        if cfg.seq_parallel and \
                "model" in jax_compat.current_axis_names():
            # sequence parallelism: the residual stream lives L-sharded over
            # the model axis; GSPMD turns the TP all-reduces into
            # reduce-scatter + all-gather pairs (half the bytes) and the
            # norms run on 1/16th of the tokens (EXPERIMENTS.md sec Perf)
            U = jax.sharding.PartitionSpec.UNCONSTRAINED
            x = jax.lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec(U, "model", U))
        p_l, lora_l = xs
        ll = ({t: lora_l[t] for t in lora_l} if lora_l else None)
        c0 = cache_init(B, cfg.n_kv_heads, slots, cfg.hd, cfg.jdtype,
                        quantized=cfg.kv_cache_dtype == "int8") \
            if make_cache else None
        y, c, a = block_apply(
            cfg, p_l, x, positions, lora_layer=ll, lora_idx=lora_idx,
            lora_ranks=lora_ranks, lora_mode=lora_mode, window=window,
            cache=c0, decode=False)
        return (y, aux + a), c

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll_layers:
        carry = (x, jnp.zeros((), jnp.float32))
        caches = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda v: v[i], (params["blocks"], lora_stk))
            carry, c = body_fn(carry, xs_i)
            caches.append(c)
        (x, aux) = carry
        caches = jax.tree.map(lambda *cs: jnp.stack(cs), *caches) \
            if make_cache else None
    else:
        (x, aux), caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], lora_stk))
    if last_pos is not None:
        x = x[jnp.arange(B), last_pos][:, None]
    elif last_only:
        x = x[:, -1:]
    logits = unembed(cfg, params, x)
    prefill.last_aux = aux  # inspected by the loss; scan-safe scalar
    return logits, (caches if make_cache else None)


def prefill_with_aux(cfg, params, tokens, **kw):
    logits, _ = prefill(cfg, params, tokens, **kw)
    return logits, prefill.last_aux


def prefill_chunk(cfg, params, tokens_c, start, clen, view, *, lora=None,
                  last=False):
    """One chunk of an incremental prefill against a gathered dense cache
    view (serving's chunked-prefill plane; see backend.prefill_chunk).

    tokens_c: (B, C) token slice padded to C; start: traced scalar — the
    absolute position of the chunk's first token; clen: traced scalar —
    real tokens in the chunk (pad writes are dropped via an OOB scatter,
    so pad slots keep pos -1). view: {"k","v": (L, B, KV, S, hd), "pos":
    (L, B, S)} — the row's claimed pages gathered dense, with unclaimed
    slots at pos -1. Returns (logits | None, new_view): logits (B, 1, V)
    for the chunk's last real token when `last`, via the same pre-unembed
    gather as prefill(last_pos=...).

    Every per-position op (projection + LoRA, RoPE, norms, MLP, residuals)
    is the exact sequence of attn_apply/block_apply, and attention masks
    by cached absolute positions, so valid entries occupy the same
    contiguous softmax prefix as a monolithic prefill — the chunked KV and
    sampled token are bitwise identical to prefill() (asserted in
    test_decode_consistency.py). MoE capacity routing is batch-shape-
    dependent, hence the model.supports_chunked_prefill gate.
    """
    x = embed_tokens(cfg, params, tokens_c)
    B, C = x.shape[0], x.shape[1]
    S = view["pos"].shape[-1]
    offs = jnp.arange(C, dtype=jnp.int32)
    positions = jnp.broadcast_to(start + offs, (B, C))
    sl = jnp.where(offs < clen, start + offs, S)       # pads -> OOB, dropped
    lora_stk, lora_idx, lora_ranks, lora_mode = _lora_slice(lora)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rb = cfg.lora.rank_block

    def body(x, xs):
        p_l, lora_l, view_l = xs
        ll = ({t: lora_l[t] for t in lora_l} if lora_l else None)
        pa = p_l["attn"]
        xn = norm_apply(p_l["norm1"], x, cfg.norm)
        q = _proj(pa["wq"], xn) + _lora_heads(xn, ll, "q", lora_idx,
                                              lora_ranks, lora_mode, rb, H, hd)
        k = _proj(pa["wk"], xn) + _lora_heads(xn, ll, "k", lora_idx,
                                              lora_ranks, lora_mode, rb, KV,
                                              hd)
        v = _proj(pa["wv"], xn) + _lora_heads(xn, ll, "v", lora_idx,
                                              lora_ranks, lora_mode, rb, KV,
                                              hd)
        if cfg.pos == "rope":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        vk = view_l["k"].at[:, :, sl, :].set(k.transpose(0, 2, 1, 3),
                                             mode="drop")
        vv = view_l["v"].at[:, :, sl, :].set(v.transpose(0, 2, 1, 3),
                                             mode="drop")
        vpos = view_l["pos"].at[:, sl].set(positions, mode="drop")
        valid = (vpos[:, None, :] >= 0) \
            & (vpos[:, None, :] <= positions[..., None])
        out = attn_direct(q, vk.transpose(0, 2, 1, 3),
                          vv.transpose(0, 2, 1, 3), valid[:, None, None])
        a = jnp.einsum("blnh,nhd->bld", out, pa["wo"]["w"])
        h = x + a
        hn = norm_apply(p_l["norm2"], h, cfg.norm)
        return h + mlp_apply(cfg, p_l["mlp"], hn), \
            {"k": vk, "v": vv, "pos": vpos}

    if cfg.unroll_layers:
        views = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda t: t[i],
                                (params["blocks"], lora_stk, view))
            x, v_l = body(x, xs_i)
            views.append(v_l)
        new_view = jax.tree.map(lambda *vs: jnp.stack(vs), *views)
    else:
        x, new_view = jax.lax.scan(body, x,
                                   (params["blocks"], lora_stk, view))
    if not last:
        return None, new_view
    x = x[jnp.arange(B), jnp.maximum(clen - 1, 0)][:, None]
    return unembed(cfg, params, x), new_view


def decode_step(cfg, params, cache, tokens_t, pos, *, lora=None, window=None,
                write_mask=None, block_table=None):
    """tokens_t: (B,1); pos: (B,) current absolute position.
    Returns (logits, new_cache). write_mask: (B,) bool — rows with False
    skip the KV write (cache row bitwise-untouched; serving's frozen
    rows). block_table: (B, W) — the cache is the paged page-pool layout
    (uniform layered stacks only; see model.supports_paged)."""
    x = embed_tokens(cfg, params, tokens_t)
    B = x.shape[0]
    lora_stk, lora_idx, lora_ranks, lora_mode = _lora_slice(lora)

    if cfg.hybrid:
        if block_table is not None:
            raise ValueError("paged cache unsupported for hybrid")
        kinds = hybrid_layer_kinds(cfg)
        new_caches = []
        for i, (kind, p_l, c_l) in enumerate(
                zip(kinds, params["blocks"], cache)):
            if kind == "rglru":
                x, c = rglru.rglru_block_step(cfg, p_l, x, c_l)
                if write_mask is not None:
                    # recurrent state has no slot to drop a write into:
                    # per-row select keeps frozen rows' state untouched
                    c = jax.tree.map(
                        lambda new, old: jnp.where(
                            write_mask.reshape((B,) + (1,) * (new.ndim - 1)),
                            new, old), c, c_l)
            else:
                ll = ({t: {"a": lora_stk[t]["a"][i], "b": lora_stk[t]["b"][i]}
                       for t in lora_stk} if lora_stk else None)
                x, c, _ = block_apply(
                    cfg, p_l, x, pos, lora_layer=ll, lora_idx=lora_idx,
                    lora_ranks=lora_ranks, lora_mode=lora_mode,
                    window=cfg.hybrid.window, cache=c_l, decode=True,
                    write_mask=write_mask)
            new_caches.append(c)
        return unembed(cfg, params, x), new_caches

    def body(x, xs):
        p_l, c_l, lora_l = xs
        y, c, _ = block_apply(
            cfg, p_l, x, pos, lora_layer=lora_l, lora_idx=lora_idx,
            lora_ranks=lora_ranks, lora_mode=lora_mode, window=window,
            cache=c_l, decode=True, write_mask=write_mask,
            block_table=block_table)
        return y, c

    if cfg.unroll_layers:
        new_caches = []
        for i in range(cfg.n_layers):
            xs_i = jax.tree.map(lambda v: v[i],
                                (params["blocks"], cache, lora_stk))
            x, c = body(x, xs_i)
            new_caches.append(c)
        new_cache = jax.tree.map(lambda *cs: jnp.stack(cs), *new_caches)
    else:
        x, new_cache = jax.lax.scan(body, x,
                                    (params["blocks"], cache, lora_stk))
    return unembed(cfg, params, x), new_cache
