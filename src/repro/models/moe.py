"""Mixture-of-Experts layer (DBRX 16e/top-4, Grok-1 8e/top-2).

Dispatch is scatter/gather based (MegaBlocks-style adapted to static-shape
JAX): tokens are scattered into per-expert capacity buffers (O(T*k*d) data
movement, no O(T*E*C) one-hot einsum), experts run as one batched einsum over
(E, C, d) buffers, results gathered back. Group size is a knob: prefill
groups = sequences (bounds capacity skew), decode = one global group
(minimizes capacity slack) — see EXPERIMENTS.md sec Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.models.layers import emb_w
from repro.models.param import Box, dense_init


def moe_init(cfg, key):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    if cfg.moe_ep:
        # EP-native layout: (E*s, d, f/s) sharded over data on dim 0 — the
        # all-to-all dispatch path reads weights in place, no resharding
        from repro.models.moe_ep import ep_factors
        s, _ = ep_factors(E, cfg.moe_ep_shards)
        fs = f // s
        p = {"router": dense_init(ks[0], d, E, ("embed", None), cfg.jdtype),
             "w1": {"w": Box(jax.random.normal(ks[1], (E * s, d, fs),
                                               cfg.jdtype) * d ** -0.5,
                             ("experts_ep", None, "mlp"))},
             "w2": {"w": Box(jax.random.normal(ks[2], (E * s, fs, d),
                                               cfg.jdtype) * f ** -0.5,
                             ("experts_ep", "mlp", None))}}
        if cfg.mlp_act in ("silu", "geglu"):
            p["w3"] = {"w": Box(jax.random.normal(ks[3], (E * s, d, fs),
                                                  cfg.jdtype) * d ** -0.5,
                                ("experts_ep", None, "mlp"))}
        return p
    if cfg.moe_2d_ff:
        # both mesh axes on d_ff: the (tokens, d)x(d, f) contraction stays
        # unsharded on d -> no per-layer activation all-reduce from w1/w3;
        # only w2's output (tokens, d) reduces (EXPERIMENTS.md sec Perf)
        ax_w1 = ("experts", None, "mlp_fsdp")
        ax_w2 = ("experts", "mlp_fsdp", None)
    else:
        ew = emb_w(cfg)
        ax_w1 = ("experts", ew, "mlp")
        ax_w2 = ("experts", "mlp", ew)
    p = {
        "router": dense_init(ks[0], d, E, ("embed", None), cfg.jdtype),
        "w1": {"w": Box(jax.random.normal(ks[1], (E, d, f), cfg.jdtype) * d ** -0.5,
                        ax_w1)},
        "w2": {"w": Box(jax.random.normal(ks[2], (E, f, d), cfg.jdtype) * f ** -0.5,
                        ax_w2)},
    }
    if cfg.mlp_act in ("silu", "geglu"):
        p["w3"] = {"w": Box(jax.random.normal(ks[3], (E, d, f), cfg.jdtype)
                            * d ** -0.5, ax_w1)}
    return p


def _dispatch_group(x, eidx, pos, keep, gates, n_experts, capacity):
    """One group. x: (S,d); eidx/pos/keep/gates: (S,k). Returns (y, buf_in)."""
    S, d = x.shape
    k = eidx.shape[-1]
    e_flat = eidx.reshape(-1)
    p_flat = jnp.where(keep, pos, capacity).reshape(-1)     # OOB -> dropped
    x_rep = jnp.repeat(x[:, None], k, axis=1).reshape(-1, d)
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    buf = buf.at[e_flat, p_flat].add(x_rep, mode="drop")
    return buf, (e_flat, p_flat)


def moe_apply(cfg, p, x, *, group_by_sequence=True):
    """x: (B, T, d) -> (y, aux_loss). Router in fp32."""
    B, T, d = x.shape
    E, top_k = cfg.moe.n_experts, cfg.moe.top_k
    cf = cfg.moe.capacity_factor
    if group_by_sequence and T > 1:
        G, S = B, T
    else:
        G, S = 1, B * T
    xg = x.reshape(G, S, d)

    logits = (xg @ p["router"]["w"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(S * top_k * cf / E + 0.999), top_k)
    capacity = -(-capacity // 4) * 4                         # align 4

    # position of each (token, k) assignment within its expert, per group
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (G,S,k,E)
    oh_flat = onehot.reshape(G, S * top_k, E)
    pos_all = jnp.cumsum(oh_flat, axis=1) - oh_flat          # (G,S*k,E)
    pos = (pos_all * oh_flat).sum(-1).reshape(G, S, top_k)
    keep = pos < capacity

    def _act(a, b3=None):
        if cfg.mlp_act == "silu":
            return jax.nn.silu(a) * b3
        if cfg.mlp_act == "geglu":
            return jax.nn.gelu(a) * b3
        return jax.nn.gelu(a)

    if cfg.moe_gather_weights:
        # batched einsum over (G,E,C,d) with output pinned to the dispatch
        # sharding; measured WORSE than the vmapped path on grok train
        # (387s vs 266s collective term) — kept for the sec Perf record
        buf, e_flat, p_flat = jax.vmap(lambda xg_, ei, po, ke: (
            lambda r: (r[0], r[1][0], r[1][1]))(_dispatch_group(
                xg_, ei, po, ke, None, E, capacity)))(
                    xg, gate_idx, pos, keep)
        U = jax.sharding.PartitionSpec.UNCONSTRAINED

        def _c(t):
            if "model" not in jax_compat.current_axis_names():
                return t          # single-device (tests): no-op
            spec = jax.sharding.PartitionSpec(*([U] * (t.ndim - 1)), "model")
            return jax.lax.with_sharding_constraint(t, spec)

        h = _act(_c(jnp.einsum("gecd,edf->gecf", buf, p["w1"]["w"])),
                 _c(jnp.einsum("gecd,edf->gecf", buf, p["w3"]["w"]))
                 if "w3" in p else None)
        out_all = jnp.einsum("gecf,efd->gecd", h, p["w2"]["w"])

        def gather_group(out_g, e_flat_g, p_flat_g, ke, gv):
            g = out_g[e_flat_g, jnp.minimum(p_flat_g, capacity - 1)]
            g = g.reshape(S, top_k, d)
            return (g * (ke * gv).astype(g.dtype)[..., None]).sum(1)

        y = jax.vmap(gather_group)(out_all, e_flat, p_flat, keep, gate_vals)
    else:
        def per_group(xg_, ei, po, ke, gv):
            buf, (e_flat, p_flat) = _dispatch_group(
                xg_, ei, po, ke, gv, E, capacity)
            h = _act(jnp.einsum("ecd,edf->ecf", buf, p["w1"]["w"]),
                     jnp.einsum("ecd,edf->ecf", buf, p["w3"]["w"])
                     if "w3" in p else None)
            out = jnp.einsum("ecf,efd->ecd", h, p["w2"]["w"])    # (E,C,d)
            g = out[e_flat, jnp.minimum(p_flat, capacity - 1)]
            g = g.reshape(S, top_k, d)
            return (g * (ke * gv).astype(g.dtype)[..., None]).sum(1)

        y = jax.vmap(per_group)(xg, gate_idx, pos, keep, gate_vals)
    y = y.reshape(B, T, d)

    # Switch-style load-balance aux loss
    frac = onehot.reshape(G, S, top_k, E).sum((1, 2)) / (S * top_k)  # (G,E)
    mean_prob = probs.mean(1)                                        # (G,E)
    aux = E * (frac.astype(jnp.float32) * mean_prob).sum(-1).mean()
    return y, aux
