"""Shared neural building blocks: RoPE, GQA attention (direct / chunked
online-softmax / decode-with-cache / sliding window), MLPs.

Conventions:
  activations x: (B, L, D)
  q: (B, L, H, hd); k/v: (B, L, KV, hd)
  KV cache: k/v (B, KV, S, hd) + pos (B, S) absolute positions (-1 = empty).
  RoPE is applied at write time, so cached k never needs re-rotation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.param import Box, dense_apply, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------- RoPE ----

def rope(x, positions, theta=10000.0):
    """x: (B, L, H, hd), positions: (B, L) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # (B,L,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- attention ----

def _gqa_scores(q, k):
    """q: (B,Lq,H,hd), k: (B,Lk,KV,hd) -> (B,KV,G,Lq,Lk) with G=H//KV."""
    b, lq, h, hd = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, lq, kv, h // kv, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / (hd ** 0.5)


def _gqa_out(probs, v):
    """probs: (B,KV,G,Lq,Lk), v: (B,Lk,KV,hd) -> (B,Lq,H,hd)."""
    b, kv, g, lq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, lq, kv * g, v.shape[-1])


def attn_direct(q, k, v, mask):
    """Materialized-logits attention. mask: broadcastable to (B,KV,G,Lq,Lk)."""
    s = _gqa_scores(q, k).astype(jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p.astype(v.dtype), v)


def causal_mask(lq, lk, q_offset=0, window=None):
    """(1,1,1,Lq,Lk) boolean mask; q position i attends k position j iff
    j <= i+q_offset and (window is None or i+q_offset - j < window)."""
    qpos = jnp.arange(lq)[:, None] + q_offset
    kpos = jnp.arange(lk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= (qpos - kpos) < window
    return m[None, None, None]


def attn_chunked(q, k, v, *, causal=True, window=None, block=512):
    """Online-softmax attention over KV blocks (flash-style, pure jnp +
    lax.scan): never materializes the (Lq, Lk) logits. This is the pure-JAX
    reference path; the Pallas flash kernel (kernels/flash.py) is the TPU
    target and is validated against attn_direct.
    """
    b, lq, h, hd = q.shape
    lk = k.shape[1]
    kv = k.shape[2]
    g = h // kv
    nblk = -(-lk // block)
    pad = nblk * block - lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block, kv, hd).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(b, lq, kv, g, hd)
    qpos = jnp.arange(lq)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, blk_i = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kblk) / (hd ** 0.5)
        s = s.astype(jnp.float32)
        kpos = blk_i * block + jnp.arange(block)
        valid = kpos[None, :] < lk
        if causal:
            valid &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            valid &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, lq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, lq, hd), jnp.float32)
    # checkpoint per KV block: backward recomputes the (Lq, BK) probs instead
    # of storing them — the flash-attention memory property under autodiff
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, lq, h, hd)
    return out.astype(q.dtype)


def attn_prefill(q, k, v, *, causal=True, window=None, block=512,
                 direct_threshold=2048):
    """Pick direct vs chunked by sequence length (static)."""
    if k.shape[1] <= direct_threshold:
        if causal:
            mask = causal_mask(q.shape[1], k.shape[1], window=window)
        else:
            mask = jnp.ones((1, 1, 1, q.shape[1], k.shape[1]), bool)
        return attn_direct(q, k, v, mask)
    return attn_chunked(q, k, v, causal=causal, window=window, block=block)


def attn_decode(q, cache_k, cache_v, cache_pos, pos, window=None):
    """One-token attention over cache. q: (B,1,H,hd); cache_k/v: (B,KV,S,hd);
    cache_pos: (B,S) abs positions (-1 empty); pos: (B,) current position."""
    b, _, h, hd = q.shape
    kv = cache_k.shape[1]
    qg = q.reshape(b, kv, h // kv, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, cache_k) / (hd ** 0.5)
    s = s.astype(jnp.float32)
    valid = (cache_pos >= 0) & (cache_pos <= pos[:, None])           # (B,S)
    if window is not None:
        valid &= (pos[:, None] - cache_pos) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", p, cache_v)
    return out.reshape(b, 1, h, hd)


# -------------------------------------------------------------- KV cache ----
#
# Optional int8 quantization (symmetric, per (head, position) scale): halves
# the decode HBM traffic — the dominant roofline term of long-context decode
# (EXPERIMENTS.md sec Perf). Scales live alongside the int8 payload.

def cache_init(batch, kv_heads, slots, hd, dtype, quantized=False):
    c = {
        "k": jnp.zeros((batch, kv_heads, slots, hd),
                       jnp.int8 if quantized else dtype),
        "v": jnp.zeros((batch, kv_heads, slots, hd),
                       jnp.int8 if quantized else dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }
    if quantized:
        c["k_scale"] = jnp.zeros((batch, kv_heads, slots), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, kv_heads, slots), jnp.float32)
    return c


def _quantize(x):
    """x: (..., hd) -> (int8, scale(...,))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-9)[..., None]).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_write_prefill(cache, k, v, positions):
    """Write a full prefill (B,L,KV,hd) into the cache (ring if L>slots)."""
    quant = cache["k"].dtype == jnp.int8
    slots = cache["k"].shape[2]
    L = k.shape[1]
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    ks = vs = None
    if quant:
        kT, ks = _quantize(kT)
        vT, vs = _quantize(vT)
    if L <= slots:
        out = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kT, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vT, (0, 0, 0, 0)),
            "pos": jax.lax.dynamic_update_slice(cache["pos"], positions,
                                                (0, 0)),
        }
        if quant:
            out["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, 0))
            out["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, 0))
        return out
    # keep last `slots` tokens, laid out by the ring invariant
    # slot(p) = p % slots so subsequent decode writes evict correctly
    shift = L % slots
    out = {"k": jnp.roll(kT[:, :, -slots:], shift, axis=2),
           "v": jnp.roll(vT[:, :, -slots:], shift, axis=2),
           "pos": jnp.roll(positions[:, -slots:], shift, axis=1)}
    if quant:
        out["k_scale"] = jnp.roll(ks[:, :, -slots:], shift, axis=2)
        out["v_scale"] = jnp.roll(vs[:, :, -slots:], shift, axis=2)
    return out


def cache_write_token(cache, k_t, v_t, pos, write_mask=None):
    """Write one token at ring slot pos % slots. k_t: (B,1,KV,hd), pos: (B,).

    write_mask: optional (B,) bool — rows with False are excluded from the
    write entirely (their slot index is pushed out of bounds and the
    scatter drops it), leaving every cache leaf bitwise-untouched for that
    row. The batched decode pipeline uses this to freeze finished/inactive
    rows without paying a full-cache select."""
    quant = cache["k"].dtype == jnp.int8
    slots = cache["k"].shape[2]
    slot = pos % slots
    if write_mask is not None:
        slot = jnp.where(write_mask, slot, slots)   # OOB -> scatter drops
    b = k_t.shape[0]
    bidx = jnp.arange(b)
    kt, vt = k_t[:, 0], v_t[:, 0]                      # (B,KV,hd)
    out = dict(cache)
    if quant:
        kt, ks = _quantize(kt)
        vt, vs = _quantize(vt)
        out["k_scale"] = cache["k_scale"].at[bidx, :, slot].set(
            ks, mode="drop")
        out["v_scale"] = cache["v_scale"].at[bidx, :, slot].set(
            vs, mode="drop")
    out["k"] = cache["k"].at[bidx, :, slot].set(kt, mode="drop")
    out["v"] = cache["v"].at[bidx, :, slot].set(vt, mode="drop")
    out["pos"] = cache["pos"].at[bidx, slot].set(pos, mode="drop")
    return out


def cache_kv_for_attn(cache, dtype):
    """Dequantized views for attention."""
    if cache["k"].dtype == jnp.int8:
        return (_dequantize(cache["k"], cache["k_scale"], dtype),
                _dequantize(cache["v"], cache["v_scale"], dtype))
    return cache["k"], cache["v"]


# ------------------------------------------------------- paged KV cache ----
#
# Per-layer paged layout (serving's block-table memory plane): the cache is
# a pool of pages shared by every row — k/v (P, KV, page_size, hd) + pos
# (P, page_size) — and each row owns the pages its block table (B, W) points
# at (-1 = unclaimed logical page). Rows never share a physical page, so a
# frozen row's write can be dropped without a select and the pool update
# stays one scatter.

def cache_write_token_paged(cache, k_t, v_t, pos, block_table,
                            write_mask=None):
    """Write one token at ring slot pos % (W * page_size) through the block
    table. k_t/v_t: (B, 1, KV, hd); pos: (B,). Rows masked out by
    `write_mask` (and rows whose logical page is unclaimed) have their
    physical page index pushed out of bounds so the scatter drops the
    write — every pool leaf stays bitwise-untouched for them, exactly like
    the dense path's OOB slot trick."""
    n_pages, _, ps, _ = cache["k"].shape
    w = block_table.shape[1]
    slot = pos % (w * ps)
    page, off = slot // ps, slot % ps
    bidx = jnp.arange(block_table.shape[0])
    phys = block_table[bidx, page]
    ok = phys >= 0
    if write_mask is not None:
        ok = ok & write_mask
    phys = jnp.where(ok, phys, n_pages)          # OOB -> scatter drops
    kt, vt = k_t[:, 0], v_t[:, 0]                # (B, KV, hd)
    return {
        "k": cache["k"].at[phys, :, off].set(kt, mode="drop"),
        "v": cache["v"].at[phys, :, off].set(vt, mode="drop"),
        "pos": cache["pos"].at[phys, off].set(pos, mode="drop"),
    }


# Decode-attention implementation over the paged layout. "auto" picks the
# Pallas paged-attention kernel (kernels/paged.py) on TPU backends — the
# DMA engine pulls K/V page tiles through the scalar-prefetched block
# table, so the dense gathered view below never materializes — and the
# pure-jnp gather path elsewhere (it is also the bitwise reference the
# kernel is validated against). Tests/benches override the module global
# to force one side of the equivalence.
PAGED_ATTN_IMPL = "auto"          # auto | pallas | gather


def paged_attn_impl() -> str:
    if PAGED_ATTN_IMPL != "auto":
        return PAGED_ATTN_IMPL
    return "pallas" if jax.default_backend() == "tpu" else "gather"


def paged_attn_decode(q, cache, block_table, pos, window=None):
    """One-token decode attention straight off the paged cache.
    q: (B, 1, H, hd); cache leaves are the page pools; block_table (B, W);
    pos: (B,). Routes per `paged_attn_impl()`; windowed attention always
    takes the gather path (the kernel has no sliding-window mask)."""
    if window is None and paged_attn_impl() == "pallas":
        from repro.kernels.paged import paged_attention
        out = paged_attention(q[:, 0], cache["k"], cache["v"],
                              cache["pos"], block_table, pos)
        return out[:, None]
    ck, cv, cpos = paged_kv_for_attn(cache, block_table)
    return attn_decode(q, ck, cv, cpos, pos, window=window)


def paged_kv_for_attn(cache, block_table):
    """Gather a per-layer paged cache into dense (B, KV, S, hd) k/v views
    plus their (B, S) absolute positions, S = W * page_size in block-table
    order (logical slot j*ps+o of a row lands at index j*ps+o, matching the
    dense row layout element-for-element). Slots behind unclaimed logical
    pages get pos -1, so attention masks them exactly like empty dense
    slots; whatever page-0 payload the gather pulled for them is weighted
    by an exact softmax zero."""
    safe = jnp.maximum(block_table, 0)
    k = cache["k"][safe]                         # (B, W, KV, ps, hd)
    v = cache["v"][safe]
    b, w, kvh, ps, hd = k.shape
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, kvh, w * ps, hd)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, kvh, w * ps, hd)
    kpos = jnp.where(block_table[:, :, None] >= 0, cache["pos"][safe], -1)
    return k, v, kpos.reshape(b, w * ps)


# ------------------------------------------------------------------ MLP ----

def emb_w(cfg):
    """Logical axis for the d_model dim of weight matrices."""
    return "embed_fsdp" if cfg.fsdp_weights else "embed"


def mlp_init(cfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    ew = emb_w(cfg)
    p = {"w1": dense_init(ks[0], d, f, (ew, "mlp"), cfg.jdtype),
         "w2": dense_init(ks[1], f, d, ("mlp", ew), cfg.jdtype)}
    if cfg.mlp_act in ("silu", "geglu"):
        p["w3"] = dense_init(ks[2], d, f, (ew, "mlp"), cfg.jdtype)
    return p


def mlp_apply(cfg, p, x):
    if cfg.mlp_act == "silu":
        h = jax.nn.silu(dense_apply(p["w1"], x)) * dense_apply(p["w3"], x)
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(dense_apply(p["w1"], x)) * dense_apply(p["w3"], x)
    else:
        h = jax.nn.gelu(dense_apply(p["w1"], x))
    return dense_apply(p["w2"], h)
