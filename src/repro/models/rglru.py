"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU with GeLU gate
branch (arXiv:2402.19427). Prefill uses jax.lax.associative_scan; decode is a
single gated-recurrence step. LoRA (DESIGN.md): adapters attach to the block's
in/out projections on recurrent layers and to q/k/v on local-attention layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import Box, dense_apply, dense_init, norm_apply, norm_init

_C = 8.0  # RG-LRU decay sharpness constant (Griffin paper)


def rglru_block_init(cfg, key):
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "norm": norm_init(d, cfg.jdtype, cfg.norm),
        "w_x": dense_init(ks[0], d, w, ("embed", "mlp"), cfg.jdtype),
        "w_gate": dense_init(ks[1], d, w, ("embed", "mlp"), cfg.jdtype),
        "conv_w": Box(jax.random.normal(ks[2], (4, w), cfg.jdtype) * 0.3,
                      (None, "mlp")),
        "conv_b": Box(jnp.zeros((w,), cfg.jdtype), ("mlp",)),
        "w_a": dense_init(ks[3], w, w, ("mlp", None), cfg.jdtype, bias=True),
        "w_i": dense_init(ks[4], w, w, ("mlp", None), cfg.jdtype, bias=True),
        "lam": Box(jnp.linspace(0.5, 4.0, w).astype(jnp.float32), (None,)),
        "w_out": dense_init(ks[5], w, d, ("mlp", "embed"), cfg.jdtype),
    }


def _gates(p, u):
    """u: (..., w) conv output -> (a, b) of h_t = a*h_{t-1} + b."""
    r = jax.nn.sigmoid(dense_apply(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_i"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) \
        * i * u.astype(jnp.float32)
    return a, b


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b


def rglru_block_apply(cfg, p, x, cache=None):
    """Full sequence. x: (B,L,d). Returns (y, cache={h, conv})."""
    B, L, d = x.shape
    xn = norm_apply(p["norm"], x, cfg.norm)
    gate = jax.nn.gelu(dense_apply(p["w_gate"], xn))
    ux_pre = dense_apply(p["w_x"], xn)
    u = jax.nn.silu(_causal_conv(ux_pre, p["conv_w"], p["conv_b"]))
    a, b = _gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, b_sc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = b_sc                                     # h_t with h_0 = 0
    y = dense_apply(p["w_out"], (gate.astype(jnp.float32) * h).astype(x.dtype))
    W = p["conv_w"].shape[0]
    tail = jnp.pad(ux_pre, ((0, 0), (W - 1, 0), (0, 0)))[:, L:L + W - 1] \
        if L < W - 1 else ux_pre[:, L - (W - 1):L]
    cache_out = {"h": h[:, -1].astype(cfg.jdtype), "conv": tail}
    return x + y, cache_out


def rglru_block_step(cfg, p, x_t, cache):
    """Decode step. x_t: (B,1,d); cache: {h:(B,w) fp, conv:(B,W-1,w)}."""
    xn = norm_apply(p["norm"], x_t, cfg.norm)
    gate = jax.nn.gelu(dense_apply(p["w_gate"], xn))     # (B,1,w)
    ux_pre = dense_apply(p["w_x"], xn)                   # (B,1,w)
    conv_in = jnp.concatenate([cache["conv"], ux_pre], axis=1)
    W = p["conv_w"].shape[0]
    u = jax.nn.silu(sum(conv_in[:, i] * p["conv_w"][i] for i in range(W))
                    + p["conv_b"])                       # (B,w)
    a, b = _gates(p, u[:, None])                         # (B,1,w) fp32
    h = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
    y = dense_apply(p["w_out"],
                    (gate[:, 0].astype(jnp.float32) * h).astype(x_t.dtype))
    return x_t + y[:, None], {"h": h.astype(cfg.jdtype), "conv": conv_in[:, 1:]}


def rglru_cache_init(cfg, batch):
    w = cfg.hybrid.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), cfg.jdtype),
        "conv": jnp.zeros((batch, 3, w), cfg.jdtype),
    }
