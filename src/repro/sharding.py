"""Logical-axis sharding: params carry logical axis names; rules map them to
mesh axes with a divisibility guard so every config lowers on every mesh.

A param leaf is a ``ShardedParam`` wrapper at init-spec time: (shape, dtype,
logical_axes). ``logical_to_physical`` converts logical axes to a
PartitionSpec for a concrete mesh, pruning any mesh axis that does not divide
the corresponding dim (e.g. whisper's 6 heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical->mesh rules. Order matters for multi-axis entries: batch
# shards over ("pod","data") when present. "embed_fsdp" is used for the
# d_model dim of weight matrices only when cfg.fsdp_weights (2D sharding).
RULES = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": ("model",),        # fused head*hd output dim of attention projections
    "mlp": ("model",),        # d_ff
    "embed": (),              # activations/weights d_model: unsharded (TP on contraction)
    "embed_fsdp": ("data",),  # weight d_model dim under 2D sharding
    "experts": ("data",),     # expert-parallel when divisible
    "experts_ep": ("data",),  # EP-native weight layout (moe_ep)
    "seq": (),                # sequence: unsharded by default
    "cache_seq": ("model",),  # long KV caches: shard sequence over model
    "lora_rank": (),
    "lora_in": ("model",),    # LoRA A d_in dim: TP-shard, tiny all-reduce on xA
    "slots": (),
    "layers": (),             # scan-stacked layer dim
    "mlp_fsdp": ("data", "model"),  # MoE expert d_ff under 2D sharding: both
                              # axes on the non-contracting dim (sec Perf)
    "state": (),              # SSM state dim
    None: (),
}


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_physical(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> P:
    """Map logical axes to a PartitionSpec, pruning non-dividing mesh axes."""
    rules = rules or RULES
    sizes = mesh_axis_sizes(mesh)
    if len(logical_axes) != len(shape):
        raise ValueError(
            f"rank mismatch: axes {logical_axes} vs shape {shape}")
    spec = []
    used = set()
    for ax, dim in zip(logical_axes, shape):
        cand = rules.get(ax, ())
        picked = []
        prod = 1
        for m in cand:
            if m not in sizes or m in used:
                continue
            if dim % (prod * sizes[m]) == 0:
                picked.append(m)
                prod *= sizes[m]
        used.update(picked)
        if len(picked) == 0:
            spec.append(None)
        elif len(picked) == 1:
            spec.append(picked[0])
        else:
            spec.append(tuple(picked))
    return P(*spec)


def named_sharding(mesh: Mesh, logical_axes, shape, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_physical(logical_axes, shape, mesh, rules))


def tree_shardings(mesh: Mesh, axes_tree, shapes_tree, rules=None):
    """Zip a pytree of logical-axis tuples with a pytree of shapes -> shardings."""
    return jax.tree.map(
        lambda ax, sh: named_sharding(mesh, ax, sh.shape, rules),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def serve_rules() -> dict:
    """Inference sharding: weights TP-only (replicated over data) — FSDP
    weight all-gathers per decode step are pure waste without optimizer
    state (EXPERIMENTS.md sec Perf, hillclimb A)."""
    r = dict(RULES)
    r["embed_fsdp"] = ()
    r["mlp_fsdp"] = ("model",)
    return r


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes used for data parallelism (pod+data when multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, mesh: Mesh, *logical_axes):
    """Apply a sharding constraint from logical axes inside jit."""
    spec = logical_to_physical(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
